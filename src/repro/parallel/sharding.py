"""Logical-axis sharding rules and activation constraints.

Parameters carry *logical* axes implied by their path names; `param_spec`
maps them to mesh axes with divisibility guards (a dimension is sharded on
'model' only when divisible; otherwise replicated -- e.g. 8 KV heads on a
16-way model axis are replicated, the standard fallback).

Activation constraints (`constrain`) are no-ops outside a mesh context so
the same model code runs on a single CPU device and under pjit on 512
devices.
"""
from __future__ import annotations

import functools
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Mesh | None = None


def use_mesh(mesh: Mesh):
    """Version-compatible ``with use_mesh(mesh): ...`` context.

    ``jax.set_mesh`` only exists on recent jax; older releases spell it
    ``jax.sharding.use_mesh``; before that, ``Mesh`` itself is the context
    manager that installs the global physical mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def shard_map(f=None, **kw):
    """Version-compatible ``jax.shard_map`` (older: jax.experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, **kw) if f is not None else jax.shard_map(**kw)
    from jax.experimental.shard_map import shard_map as _sm
    if "check_vma" in kw:  # renamed to check_rep in older jax
        kw = dict(kw)
        kw["check_rep"] = kw.pop("check_vma")
    return _sm(f, **kw) if f is not None else functools.partial(_sm, **kw)


def set_active_mesh(mesh: Mesh | None) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def active_mesh() -> Mesh | None:
    return _ACTIVE_MESH


def batch_axes() -> tuple:
    """Mesh axes the global batch is sharded over."""
    if _ACTIVE_MESH is None:
        return ()
    names = _ACTIVE_MESH.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def constrain(x, *spec):
    """with_sharding_constraint that degrades to a no-op without a mesh."""
    if _ACTIVE_MESH is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACTIVE_MESH, P(*spec)))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _guard(mesh: Mesh, shape: tuple, spec: list) -> P:
    """Drop mesh axes that don't divide the corresponding dim."""
    out = []
    for dim, axis in zip(shape, spec):
        if axis is None:
            out.append(None)
        elif dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


# path-pattern -> which dim gets the 'model' axis (negative = from the end)
_MODEL_DIM_RULES: list[tuple[str, int]] = [
    (r"embed$", 0),            # (vocab, d) -> shard vocab
    (r"lm_head$", -1),         # (d, vocab) -> shard vocab
    (r"\bwq$", -1), (r"\bwk$", -1), (r"\bwv$", -1),   # (.., d, H*hd)
    (r"\bwo$", -2),            # (.., H*hd, d)
    (r"\bw_gate$", -1), (r"\bw_up$", -1),             # (.., d, f)
    (r"\bw_down$", -2),        # (.., f, d)
    (r"\be_gate$", -3), (r"\be_up$", -3), (r"\be_down$", -3),  # (L,E,..,..)
    (r"\brouter$", -1),
    (r"\bwq_b$", -1), (r"\bwkv_b$", -1),              # MLA head projections
    (r"\bmla_wo$", -2),
    (r"\bin_proj$", -1),       # mamba (d, 2*di)
    (r"\bconv_w$", -2), (r"\bA_log$", -2), (r"\bssm_D$", -1),
    (r"\bx_proj$", -2), (r"\bdt_proj$", -1), (r"\bout_proj$", -2),
    (r"\bcross_wq$", -1), (r"\bcross_wk$", -1), (r"\bcross_wv$", -1),
    (r"\bcross_wo$", -2),
]


def param_spec(path: str, shape: tuple, strategy: str = "tp") -> P:
    """PartitionSpec for a parameter identified by its tree path."""
    mesh = _ACTIVE_MESH
    if mesh is None or strategy == "dp_seq" or "model" not in mesh.axis_names:
        return P()
    for pat, dim in _MODEL_DIM_RULES:
        if re.search(pat, path):
            spec = [None] * len(shape)
            spec[dim if dim >= 0 else len(shape) + dim] = "model"
            # 'tp+ep_data': expert FFN weights additionally sharded over
            # the data axis on dim -2 (persistent storage /dp; gathered
            # per layer at the shard_map boundary) -- needed to fit
            # deepseek-v3 on v5e HBM.
            if ("ep_data" in strategy and "data" in mesh.axis_names
                    and re.search(r"\be_(gate|up|down)$", path)):
                spec[len(shape) - 2] = "data"
            return _guard(mesh, shape, spec)
    return P()


def tree_param_specs(params: Any, strategy: str = "tp") -> Any:
    """Map a params pytree to PartitionSpecs using joined key paths."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        specs.append(param_spec(name, np.shape(leaf), strategy))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(params: Any, mesh: Mesh, strategy: str = "tp") -> Any:
    specs = tree_param_specs(params, strategy)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
