from . import ops, ref
