"""Flash attention for TPU (Pallas).

Online-softmax attention with MXU-aligned BlockSpec tiling:
  grid = (B, H, Sq/block_q, Sk/block_k), k innermost (sequential on TPU),
  VMEM scratch carries the running max / normalizer / accumulator across
  k-blocks.  GQA is handled by the k/v index maps (kv head = h // G), so no
  materialized KV repeat.  Causal masking is applied per tile; fully-masked
  tiles are skipped.

Target: TPU v5e (128-lane MXU -> block sizes multiples of 128 for real
shapes); validated on CPU with interpret=True against ref.attention_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    run = True
    if causal:
        # tile participates iff some q >= some k in it
        run = (q_start + block_q - 1) >= k_start

    @pl.when(jnp.asarray(run))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd_v)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
            ki = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_cur

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd[_v]). Returns (B, Sq, H, hd_v)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, hd_v = v.shape
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad seq to block size"
    n_q, n_k = Sq // block_q, Sk // block_k
    grid = (B, H, n_q, n_k)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd_v),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd_v),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),     # running max
            pltpu.VMEM((block_q,), jnp.float32),     # running normalizer
            pltpu.VMEM((block_q, hd_v), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
