"""Batched min-cover (gain) kernel: the JAX/Pallas backend of the frontier.

The frontier layer's hot reduction is: given ``uncov`` rows (one per
(candidate, edge) pair, ``2^P`` processor-subset columns), find each row's
minimum-popcount subset with zero uncovered pins -- ``lambda_e`` under the
candidate mask.  ``engine._lambda_from_rows`` does it with an argmax over
popcount-ordered columns; here the same reduction runs as a Pallas TPU
kernel (row-tiled grid, one masked min per tile on the VPU), with a jitted
``jnp`` fallback off-TPU, dispatched by platform exactly like
``kernels/ops.py`` (same ``force``/``_use_pallas`` switch).

Because the subsets with ``uncov == 0`` always include the full processor
set (every assigned pin is covered by *some* processor), the first zero in
popcount order equals the minimum popcount over all zeros -- which is the
masked-min formulation the kernel uses, avoiding a gather.

Lambdas are small integers, so this backend feeds bit-identical values
into the frontier's float64 NumPy cost reduction: backend choice cannot
change a single heuristic decision.
"""
from __future__ import annotations

import functools

import numpy as np

_NO_COVER = 127  # > any popcount for P <= 12; returned only for all-nonzero
                 # rows, which real uncov rows never produce (see docstring)


@functools.lru_cache(maxsize=1)
def _jnp_lambda():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def lam(rows_perm, pc):
        return jnp.min(jnp.where(rows_perm == 0, pc[None, :], _NO_COVER),
                       axis=1).astype(jnp.int32)

    return lam


# pow2 padding collapses front shapes onto a logarithmic family, but a long
# multilevel run still visits many (Rp, Mp, block_r) triples across levels
# and P values; an unbounded cache would pin every jitted executable for the
# life of the process.  64 entries comfortably covers one run's working set
# (~log2(rows) x few P values) while letting stale shape families fall out.
_PALLAS_CACHE_SIZE = 64


@functools.lru_cache(maxsize=_PALLAS_CACHE_SIZE)
def _pallas_call(Rp: int, Mp: int, block_r: int, interpret: bool):
    """Jitted pallas_call for one padded shape (cached per shape family)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(rows_ref, pc_ref, out_ref):
        lam = jnp.min(jnp.where(rows_ref[:] == 0, pc_ref[:], _NO_COVER),
                      axis=1, keepdims=True)
        out_ref[:] = lam.astype(jnp.int32)

    return jax.jit(pl.pallas_call(
        kernel,
        grid=(Rp // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, Mp), lambda i: (i, 0)),
            pl.BlockSpec((1, Mp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        interpret=interpret,
    ))


@functools.lru_cache(maxsize=_PALLAS_CACHE_SIZE)
def _pallas_dlam_call(Rp: int, Mp: int, block_r: int, interpret: bool):
    """Fused front kernel: candidate uncov rows + old lambdas -> cost dlam.

    The device-resident pass (``kernels.front_pass``) feeds it the flat
    (pair, edge) expansion of a whole candidate front: each row is one
    (candidate, edge) uncov row in popcount-column order, paired with the
    edge's current lambda.  The kernel fuses the masked-min cover with the
    ``relu(lam_new - 1) - relu(lam_old - 1)`` cost difference on the VPU,
    so the XLA caller only segment-sums integer dlam terms per candidate.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(rows_ref, pc_ref, lam_old_ref, out_ref):
        lam = jnp.min(jnp.where(rows_ref[:] == 0, pc_ref[:], _NO_COVER),
                      axis=1, keepdims=True).astype(jnp.int32)
        out_ref[:] = (jnp.maximum(lam - 1, 0)
                      - jnp.maximum(lam_old_ref[:] - 1, 0))

    return jax.jit(pl.pallas_call(
        kernel,
        grid=(Rp // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, Mp), lambda i: (i, 0)),
            pl.BlockSpec((1, Mp), lambda i: (0, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        interpret=interpret,
    ))


def front_dlam(rows_perm, pc, lam_old, *, block_r: int = 512,
               interpret: bool = False):
    """Per-row integer cost deltas for a candidate front (Pallas path).

    ``rows_perm`` is a (R, M) jnp int32 array of candidate uncov rows in
    popcount-column order (column 0 = subset 0), ``pc`` the (M,) popcounts
    with a ``_NO_COVER`` sentinel at column 0, ``lam_old`` the (R,) current
    edge lambdas.  Returns the (R,) int32 ``relu(lam_new-1)-relu(lam_old-1)``
    terms.  Shapes must be pre-padded by the caller (rows to a multiple of
    ``block_r``, columns to a multiple of 128): the device-resident pass
    owns the padding, so this traces inside its jitted program.
    """
    R, M = rows_perm.shape
    call = _pallas_dlam_call(R, M, block_r, interpret)
    return call(rows_perm, pc.reshape(1, M),
                lam_old.reshape(R, 1))[:, 0]


def kernel_cache_stats() -> dict:
    """Hit/miss/size counters of the per-shape jitted-call caches.

    Exposed for the benchmarks (``device_resident`` rows record how many
    shape families a run actually compiled) and for the cache-bound tests.
    """
    out = {}
    for name, fn in (("pallas", _pallas_call), ("dlam", _pallas_dlam_call)):
        info = fn.cache_info()
        out[name] = {"hits": info.hits, "misses": info.misses,
                     "size": info.currsize, "maxsize": info.maxsize}
    return out


# One reused pow2 pad buffer per column width for the jnp fallback: the
# previous implementation np.concatenate'd a fresh padded copy per front,
# which at frontier rates (thousands of fronts per refinement pass) spends
# more time in the allocator than in the reduction.  ``_PAD_DIRTY`` tracks
# the high-water row that holds real data, so only rows a previous front
# actually overwrote are re-onesed (the sentinel value) before reuse.
_PAD_BUFS: dict[int, np.ndarray] = {}
_PAD_DIRTY: dict[int, int] = {}


def _padded_rows(rows_perm: np.ndarray, Rp: int) -> np.ndarray:
    R, M = rows_perm.shape
    buf = _PAD_BUFS.get(M)
    if buf is None or buf.shape[0] < Rp:
        buf = np.ones((Rp, M), dtype=np.int32)
        _PAD_BUFS[M] = buf
        _PAD_DIRTY[M] = 0
    dirty = _PAD_DIRTY[M]
    if dirty > R:
        buf[R:dirty] = 1
    buf[:R] = rows_perm
    _PAD_DIRTY[M] = R
    return buf[:Rp]


def _pallas_lambda(rows_perm: np.ndarray, pc: np.ndarray,
                   block_r: int = 512, interpret: bool = False):
    R, M = rows_perm.shape
    Mp = -(-M // 128) * 128
    # pow2 row padding (>= one block): ragged front sizes collapse onto a
    # logarithmic family of shapes, so the cached jitted pallas_call does
    # not recompile per front
    Rp = max(1 << max(R - 1, 1).bit_length(), block_r)
    # pad columns with a non-zero sentinel (never a cover) and rows with
    # all-ones (their lambda is dropped after the call)
    rows_p = np.ones((Rp, Mp), dtype=np.int32)
    rows_p[:R, :M] = rows_perm
    pc_p = np.full((1, Mp), _NO_COVER, dtype=np.int32)
    pc_p[0, :M] = pc
    out = _pallas_call(Rp, Mp, block_r, interpret)(rows_p, pc_p)
    return out[:R, 0]


def min_cover_lambdas(rows: np.ndarray, order: np.ndarray,
                      order_pc: np.ndarray, *,
                      interpret: bool = False) -> np.ndarray:
    """Min-cover size per uncov row (jax path of ``price_mask_front``).

    Drop-in for ``engine._lambda_from_rows``: ``rows`` is (R, 2^P) with
    column 0 the assigned-pin count, ``order``/``order_pc`` the engine's
    popcount-ordered non-empty subsets and their popcounts.  Rows with no
    assigned pin get lambda 0 (handled host-side, so the kernel is a pure
    masked min).  The row count is padded up to the next power of two
    (all-ones sentinel rows, dropped after the call) so jit sees a bounded
    family of shapes instead of recompiling per front size.
    """
    from .ops import _use_pallas

    R = rows.shape[0]
    if R == 0:
        return np.zeros(0, dtype=np.int16)
    rows_perm = np.ascontiguousarray(rows[:, order], dtype=np.int32)
    pc = np.asarray(order_pc, dtype=np.int32)
    if _use_pallas():
        lam = _pallas_lambda(rows_perm, pc, interpret=interpret)
    else:
        Rp = 1 << max(R - 1, 1).bit_length()
        if Rp != R:
            rows_perm = _padded_rows(rows_perm, Rp)
        lam = _jnp_lambda()(rows_perm, pc)[:R]
    lam = np.asarray(lam, dtype=np.int16)
    lam[rows[:, 0] == 0] = 0
    return lam
