"""Batched min-cover (gain) kernel: the JAX/Pallas backend of the frontier.

The frontier layer's hot reduction is: given ``uncov`` rows (one per
(candidate, edge) pair, ``2^P`` processor-subset columns), find each row's
minimum-popcount subset with zero uncovered pins -- ``lambda_e`` under the
candidate mask.  ``engine._lambda_from_rows`` does it with an argmax over
popcount-ordered columns; here the same reduction runs as a Pallas TPU
kernel (row-tiled grid, one masked min per tile on the VPU), with a jitted
``jnp`` fallback off-TPU, dispatched by platform exactly like
``kernels/ops.py`` (same ``force``/``_use_pallas`` switch).

Because the subsets with ``uncov == 0`` always include the full processor
set (every assigned pin is covered by *some* processor), the first zero in
popcount order equals the minimum popcount over all zeros -- which is the
masked-min formulation the kernel uses, avoiding a gather.

Lambdas are small integers, so this backend feeds bit-identical values
into the frontier's float64 NumPy cost reduction: backend choice cannot
change a single heuristic decision.
"""
from __future__ import annotations

import functools

import numpy as np

_NO_COVER = 127  # > any popcount for P <= 12; returned only for all-nonzero
                 # rows, which real uncov rows never produce (see docstring)


@functools.lru_cache(maxsize=1)
def _jnp_lambda():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def lam(rows_perm, pc):
        return jnp.min(jnp.where(rows_perm == 0, pc[None, :], _NO_COVER),
                       axis=1).astype(jnp.int32)

    return lam


@functools.lru_cache(maxsize=None)
def _pallas_call(Rp: int, Mp: int, block_r: int, interpret: bool):
    """Jitted pallas_call for one padded shape (cached per shape family)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(rows_ref, pc_ref, out_ref):
        lam = jnp.min(jnp.where(rows_ref[:] == 0, pc_ref[:], _NO_COVER),
                      axis=1, keepdims=True)
        out_ref[:] = lam.astype(jnp.int32)

    return jax.jit(pl.pallas_call(
        kernel,
        grid=(Rp // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, Mp), lambda i: (i, 0)),
            pl.BlockSpec((1, Mp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        interpret=interpret,
    ))


def _pallas_lambda(rows_perm: np.ndarray, pc: np.ndarray,
                   block_r: int = 512, interpret: bool = False):
    R, M = rows_perm.shape
    Mp = -(-M // 128) * 128
    # pow2 row padding (>= one block): ragged front sizes collapse onto a
    # logarithmic family of shapes, so the cached jitted pallas_call does
    # not recompile per front
    Rp = max(1 << max(R - 1, 1).bit_length(), block_r)
    # pad columns with a non-zero sentinel (never a cover) and rows with
    # all-ones (their lambda is dropped after the call)
    rows_p = np.ones((Rp, Mp), dtype=np.int32)
    rows_p[:R, :M] = rows_perm
    pc_p = np.full((1, Mp), _NO_COVER, dtype=np.int32)
    pc_p[0, :M] = pc
    out = _pallas_call(Rp, Mp, block_r, interpret)(rows_p, pc_p)
    return out[:R, 0]


def min_cover_lambdas(rows: np.ndarray, order: np.ndarray,
                      order_pc: np.ndarray, *,
                      interpret: bool = False) -> np.ndarray:
    """Min-cover size per uncov row (jax path of ``price_mask_front``).

    Drop-in for ``engine._lambda_from_rows``: ``rows`` is (R, 2^P) with
    column 0 the assigned-pin count, ``order``/``order_pc`` the engine's
    popcount-ordered non-empty subsets and their popcounts.  Rows with no
    assigned pin get lambda 0 (handled host-side, so the kernel is a pure
    masked min).  The row count is padded up to the next power of two
    (all-ones sentinel rows, dropped after the call) so jit sees a bounded
    family of shapes instead of recompiling per front size.
    """
    from .ops import _use_pallas

    R = rows.shape[0]
    if R == 0:
        return np.zeros(0, dtype=np.int16)
    rows_perm = np.ascontiguousarray(rows[:, order], dtype=np.int32)
    pc = np.asarray(order_pc, dtype=np.int32)
    if _use_pallas():
        lam = _pallas_lambda(rows_perm, pc, interpret=interpret)
    else:
        Rp = 1 << max(R - 1, 1).bit_length()
        if Rp != R:
            pad = np.ones((Rp - R, rows_perm.shape[1]), dtype=np.int32)
            rows_perm = np.concatenate([rows_perm, pad], axis=0)
        lam = _jnp_lambda()(rows_perm, pc)[:R]
    lam = np.asarray(lam, dtype=np.int16)
    lam[rows[:, 0] == 0] = 0
    return lam
