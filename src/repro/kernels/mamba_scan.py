"""Mamba1 selective scan for TPU (Pallas).

TPU adaptation of the CUDA selective-scan: the grid iterates (batch,
seq-chunks) with TPU's sequential grid semantics; the recurrent state
h (d_inner, N) lives in VMEM scratch and is carried across chunk steps
(re-initialized whenever the batch index advances).  Within a chunk the
recurrence runs as an on-chip fori_loop over time steps: each step is a
VPU-friendly (di, N) elementwise update followed by a row reduction.

Layout: d_inner is the lane dimension (multiples of 128 on real shapes);
the tiny state dim N (=16) stays in sublanes.  Validated with
interpret=True against ref.mamba_scan_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, last_ref,
            h_ref, *, chunk: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...]                       # (di, N) f32
    Dskip = D_ref[...]                   # (di,)

    def step(t, h):
        u_t = u_ref[0, t, :].astype(jnp.float32)        # (di,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)      # (di,)
        B_t = B_ref[0, t, :].astype(jnp.float32)        # (N,)
        C_t = C_ref[0, t, :].astype(jnp.float32)        # (N,)
        dA = jnp.exp(dt_t[:, None] * A)                 # (di, N)
        h = dA * h + (dt_t * u_t)[:, None] * B_t[None, :]
        y = (h * C_t[None, :]).sum(axis=1) + u_t * Dskip
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(c == n_chunks - 1)
    def _emit_state():
        last_ref[0, :, :] = h_ref[...]


def mamba_scan(u, dt, A, Bc, Cc, D, *, chunk: int = 64,
               interpret: bool = False):
    """u/dt: (B, S, di); A: (di, N); Bc/Cc: (B, S, N); D: (di,).
    Returns (y (B,S,di), last_state (B,di,N) f32)."""
    B, S, di = u.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to the chunk size"
    n_chunks = S // chunk
    grid = (B, n_chunks)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((di, N), lambda b, c: (0, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((di,), lambda b, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, di, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), u.dtype),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, Bc, Cc, D)
    return y, last
