"""Jit'd wrappers dispatching between Pallas TPU kernels and jnp references.

The Pallas kernels target TPU (MXU-aligned BlockSpecs, VMEM tiling); they do
not lower on the CPU backend, so dispatch is by platform (overridable with
``force(...)`` for interpret-mode testing).
"""
from __future__ import annotations

import functools

import jax

from . import ref

_FORCE: str | None = None  # None = auto, 'pallas' | 'ref'


def force(which: str | None) -> None:
    global _FORCE
    _FORCE = which


def _use_pallas() -> bool:
    if _FORCE is not None:
        return _FORCE == "pallas"
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=0, q_pos=None, k_pos=None,
              scale=None):
    if _use_pallas() and window == 0 and q_pos is None and k_pos is None:
        from .flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, scale=scale)
    return ref.attention_reference(q, k, v, causal=causal, window=window,
                                   q_pos=q_pos, k_pos=k_pos, scale=scale)


def mamba_scan(u, dt, A, Bc, Cc, D, init_state=None):
    if _use_pallas() and init_state is None:
        from .mamba_scan import mamba_scan as pallas_scan
        return pallas_scan(u, dt, A, Bc, Cc, D)
    return ref.mamba_scan_reference(u, dt, A, Bc, Cc, D, init_state=init_state)


def grouped_matmul(x, w, group_sizes):
    return ref.grouped_matmul_reference(x, w, group_sizes)


def grouped_matmul_aligned(x, w, capacity: int):
    """Block-aligned layout (G*capacity rows): Pallas-eligible fast path."""
    import jax.numpy as jnp
    if _use_pallas():
        from .moe_gmm import grouped_matmul as pallas_gmm
        return pallas_gmm(x, w, capacity)
    G = w.shape[0]
    sizes = jnp.full((G,), capacity, jnp.int32)
    return ref.grouped_matmul_reference(x, w, sizes)
