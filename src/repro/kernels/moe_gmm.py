"""Grouped (block-diagonal) matmul for MoE expert FFNs on TPU (Pallas).

Computes y[t] = x[t] @ w[group(t)] for rows grouped contiguously with a
*block-aligned* layout: the MoE dispatch buffers are (n_groups, capacity, D)
with fixed capacity, so group boundaries always fall on row-block borders
and the expert id of a row block is ``row_block // (capacity//block_rows)``
-- no ragged bookkeeping, every tile is a dense MXU matmul.

grid = (row_blocks, col_blocks, k_blocks) with k innermost; the f32
partial-product accumulator lives in VMEM scratch.  Validated with
interpret=True against ref.grouped_matmul_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, capacity: int, *,
                   block_rows: int = 128, block_cols: int = 128,
                   block_k: int = 512, interpret: bool = False) -> jax.Array:
    """x: (G*capacity, D) rows grouped by expert; w: (G, D, F).
    Returns (G*capacity, F)."""
    T, D = x.shape
    G, _, F = w.shape
    assert T == G * capacity
    block_rows = min(block_rows, capacity)
    block_cols = min(block_cols, F)
    block_k = min(block_k, D)
    assert capacity % block_rows == 0, "capacity must align to block_rows"
    assert F % block_cols == 0 and D % block_k == 0
    rpg = capacity // block_rows  # row blocks per group
    grid = (T // block_rows, F // block_cols, D // block_k)
    kernel = functools.partial(_kernel, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_k), lambda r, c, k: (r, k)),
            pl.BlockSpec((1, block_k, block_cols),
                         lambda r, c, k: (r // rpg, k, c)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols),
                               lambda r, c, k: (r, c)),
        out_shape=jax.ShapeDtypeStruct((T, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_rows, block_cols), jnp.float32)],
        interpret=interpret,
    )(x, w)
