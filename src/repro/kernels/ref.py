"""Pure-jnp oracles for the Pallas kernels.

These are the semantic references: the Pallas kernels must match them
(``tests/test_kernels.py`` sweeps shapes/dtypes with interpret=True), and
they are also the XLA execution path on non-TPU backends (the dry-run
lowers these; Pallas TPU kernels do not lower on the CPU backend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,            # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,         # 0 = full; >0 = sliding window
    q_pos: jax.Array | None = None,   # (B, Sq) absolute positions
    k_pos: jax.Array | None = None,   # (B, Sk) absolute positions (<0 = pad)
    scale: float | None = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(k.shape[1])[None], (B, k.shape[1]))
    qf = q.reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qf, k,
                        preferred_element_type=jnp.float32) * scale
    mask = k_pos[:, None, None, None, :] >= 0
    if causal:
        mask &= q_pos[:, None, None, :, None] >= k_pos[:, None, None, None, :]
    if window:
        mask &= (q_pos[:, None, None, :, None]
                 - k_pos[:, None, None, None, :]) < window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def mamba_scan_reference(
    u: jax.Array,        # (B, S, di)    input sequence
    dt: jax.Array,       # (B, S, di)    softplus'd step sizes
    A: jax.Array,        # (di, N)       negative-definite state matrix (=-exp(A_log))
    Bc: jax.Array,       # (B, S, N)     input->state projection (per step)
    Cc: jax.Array,       # (B, S, N)     state->output projection (per step)
    D: jax.Array,        # (di,)         skip connection
    init_state: jax.Array | None = None,   # (B, di, N)
) -> tuple[jax.Array, jax.Array]:
    """Selective scan (mamba1): h' = exp(dt*A) h + dt*B u ; y = C h + D u."""
    B, S, di = u.shape
    N = A.shape[1]
    if init_state is None:
        init_state = jnp.zeros((B, di, N), dtype=jnp.float32)

    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None, None])  # (B,S,di,N)
    dBu = (dt.astype(jnp.float32) * u.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[:, :, None, :]                      # (B,S,di,N)

    def step(h, xs):
        da, dbu, c = xs
        h = da * h + dbu
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBu, 1, 0),
          jnp.moveaxis(Cc.astype(jnp.float32), 1, 0))
    last, ys = jax.lax.scan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1) + u.astype(jnp.float32) * D[None, None]
    return y.astype(u.dtype), last


def grouped_matmul_reference(
    x: jax.Array,            # (T, D) tokens sorted by group
    w: jax.Array,            # (G, D, F) one matrix per group
    group_sizes: jax.Array,  # (G,) int32, sum == T
) -> jax.Array:
    """Block-diagonal GEMM: rows of x hit the weight of their group."""
    T, D = x.shape
    G, _, F = w.shape
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(T)
    gid = jnp.sum(row[:, None] >= ends[None, :], axis=1)  # group of each row
    wx = w[gid]                                           # (T, D, F) gather
    return jnp.einsum("td,tdf->tf", x, wx,
                      preferred_element_type=jnp.float32).astype(x.dtype)
