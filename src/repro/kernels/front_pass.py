"""Device-resident refinement passes: whole FM / replication sweeps on JAX.

PR 3 gave the frontier layer a jax backend, but it ships one front to the
device at a time: every priced node pays a host->device round trip, so on
CPU the jax path merely ties numpy.  This module keeps the engine's state
resident on the device across an entire refinement pass and fuses the whole
per-visit pipeline -- row gather, popcount-ordered masked-min lambda
pricing, integer cost reduction, winner argmin -- into one jitted program
that *scans* the visit permutation and stops at the first committed event.
The host then reads back exactly one (position, kind, processor) triple per
committed move (plus one terminal read per pass scan), applies the move to
both the host engine and the device mirror, and re-enters the scan at the
next position.

Correctness contract (same as PR 3, property-tested in interpret mode):

  * **Bit-identical decisions.**  The device program is all-integer: when
    ``mu`` is integer-valued (every shipped instance), cost deltas are
    exact int32, the host's float64 thresholds collapse to integer ones
    (``delta < -1e-12``  <=>  ``delta <= -1``;  drop ``delta <= 1e-12``
    <=>  ``delta <= 0``), and ``argmin`` picks the first minimum on both
    sides -- so the committed trajectory equals the numpy frontier path's,
    move for move.  Non-integer weights fall back to the per-front path.
  * **Feasibility stays on the host.**  Capacity tests compare float64
    loads exactly as ``PartitionState.fits`` does; the host uploads the
    (n, P) feasibility mask (recomputing only columns whose load changed),
    so no device float compare can flip a knife-edge decision.
  * **One host sync per committed move.**  Each ``find`` call performs one
    blocking device->host read; a pass with M commits issues at most M + 1
    finds (the extra one proves the scan is dry; it is skipped when the
    final commit lands on the last visit position).  The counters obey
    ``commits <= syncs <= commits + pass_scans``, assertable in tests.
  * **One device dispatch per committed move.**  The engine hook *queues*
    mutations instead of dispatching them; the next ``find`` program folds
    the newest queued mutation into its own dispatch (a no-op fold when
    the queue is empty), so the commit->find cadence costs a single
    dispatch where PR 6 paid two.  Only host-side phases that mutate
    without a following find (the replication edge-guided phase) fall back
    to standalone apply programs, counted in ``apply_dispatches`` -- zero
    across any pure FM / node-sweep pass.

Layout: candidate fronts are the flat (pair, edge) expansion -- for each
visited node, P candidate masks x its incident edges -- packed into fixed
power-of-two blocks (``R_BLK`` rows, ``R_BLK // P`` node slots, a node
never split) that a ``lax.while_loop`` walks in visit order.  Blocks whose
nodes are neither boundary-at-pass-start nor dirtied by a committed move
are skipped on-device (``lax.cond``), which restores the output-sensitivity
the numpy ``GainCache`` gets from adjacency invalidation.  The per-row
lambda + cost-difference reduction optionally runs as the Pallas kernel
``gain.front_dlam`` (TPU; interpret mode on CPU) under the same
``ops._use_pallas`` switch as every other kernel in this package.

The schedule side gets the same treatment at window granularity:
``DeviceScheduleWindows`` keeps the per-superstep load rows, top-2 triples
and step costs as persistent padded device arrays and fuses the
``price_comm_moves`` / ``price_comp_moves`` gathers and the node-move
(P x P) delta-matrix fold into single jitted programs (int32, same integer
contract; float-weight instances fall back to the numpy fronts).
"""
from __future__ import annotations

import functools

import numpy as np

from .gain import _NO_COVER, front_dlam

# Below this node count the per-front numpy path wins (device dispatch and
# block padding dominate); tests monkeypatch it to exercise the device path
# on small instances.
DEVICE_MIN_NODES = 4096

# Minimum schedule-window length for the fused device pricers (mirrors
# list_sched._COMM_FRONT_MIN_WINDOW's role for the numpy fronts).
DEVICE_MIN_WINDOW = 16

# Minimum touched-superstep count for the fused node-move fold.
DEVICE_MIN_STEPS = 8

_R_BLK_MIN = 2048
_INT32_BUDGET = 2 ** 30  # headroom below int32 max for any partial sum


def _try_jax():
    try:
        import jax
        import jax.numpy as jnp
        return jax, jnp
    except ImportError:  # pragma: no cover - exercised on jax-less CI
        return None, None


def _integer_valued(a: np.ndarray) -> bool:
    a = np.asarray(a, dtype=np.float64)
    return bool(np.all(np.isfinite(a)) and np.all(a == np.rint(a)))


def _pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 1).bit_length()


# ==========================================================================
# Partition side
# ==========================================================================

def attach(state, cap: float, *, min_nodes: int | None = None,
           interpret: bool | None = None):
    """Build a ``DevicePartitionPass`` mirroring ``state``, or None.

    Returns None -- caller falls back to the per-front path -- when jax is
    unavailable, the instance is too small to pay for device dispatch, mu
    is not integer-valued (the all-integer device program would not be
    bit-identical), or an int32 partial sum could overflow.  On success the
    engine's ``device`` hook is set so every ``apply``/``undo`` keeps the
    device mirror in lockstep.
    """
    jax, _ = _try_jax()
    if jax is None:
        return None
    if state.backend != "numpy" or state.device is not None:
        return None
    hg = state.hg
    floor = DEVICE_MIN_NODES if min_nodes is None else min_nodes
    if hg.n < floor:
        return None
    if not _integer_valued(state.mu) or np.any(state.mu < 0):
        return None
    if np.any(state.masks == 0):
        # host derives a -1 primary for unassigned nodes, the device table
        # cannot; refinement never unassigns, so the check holds for a pass
        return None
    mu_i = np.rint(state.mu).astype(np.int64)
    # worst-case |delta| for one candidate: sum of incident mu * (P - 1)
    deg = np.diff(state.xinc)
    if len(state.inc_edges):
        wsum = np.bincount(
            np.repeat(np.arange(hg.n), deg), weights=mu_i[state.inc_edges],
            minlength=hg.n)
    else:
        wsum = np.zeros(hg.n)
    if wsum.max(initial=0.0) * max(state.P - 1, 1) >= _INT32_BUDGET:
        return None
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dev = DevicePartitionPass(state, cap, interpret=interpret)
    state.device = dev
    return dev


class DevicePartitionPass:
    """Device mirror of a ``PartitionState`` plus the fused pass programs.

    Columns of ``uncov``/``contrib`` are stored pre-permuted in popcount
    order (column 0 = subset 0), so lambda pricing is a pure masked min
    with no per-call gather.  A dummy edge row E (mu 0, all-zero uncov) and
    a dummy node row n (infeasible everywhere) absorb all padding.
    """

    def __init__(self, state, cap: float, *, interpret: bool) -> None:
        jax, jnp = _try_jax()
        self._jax, self._jnp = jax, jnp
        self.state = state
        self.cap = float(cap)
        self.interpret = bool(interpret)
        from .ops import _use_pallas
        self.use_pallas = _use_pallas()
        hg = state.hg
        self.n = hg.n
        self.P = state.P
        self.nsub = 1 << state.P
        self.E = len(hg.edges)
        self.xinc = np.asarray(state.xinc, dtype=np.int64)
        self.inc_edges_np = np.asarray(state.inc_edges, dtype=np.int64)
        self.deg = np.diff(self.xinc).astype(np.int64)
        self.Dmax = int(self.deg.max(initial=0))
        max_rows = self.P * max(self.Dmax, 1)
        self.R_blk = max(_R_BLK_MIN, _pow2(max_rows))
        self.B_blk = self.R_blk // self.P
        # column permutation: subset 0 first, then popcount order
        self.colmap = np.concatenate(
            ([0], np.asarray(state._order, dtype=np.int64)))
        pc_p = np.concatenate(
            ([_NO_COVER], np.asarray(state._order_pc, dtype=np.int64)))
        self._pc = jnp.asarray(pc_p.astype(np.int32))
        self._contrib = jnp.asarray(
            np.ascontiguousarray(state._contrib[:, self.colmap],
                                 dtype=np.int32))
        popc = np.asarray(state.popcnt, dtype=np.int32)
        self._popcnt = jnp.asarray(popc)
        prim = np.maximum(
            np.array([int(m).bit_length() - 1 for m in range(self.nsub)],
                     dtype=np.int32), 0)
        self._prim = jnp.asarray(prim)
        mu_i = np.zeros(self.E + 1, dtype=np.int32)
        mu_i[:self.E] = np.rint(state.mu).astype(np.int32)
        self._mu = jnp.asarray(mu_i)
        self._owner = np.repeat(np.arange(self.n), self.deg)  # bnd scatter
        # mutation queue: host applies are *deferred* and fused into the
        # next find program, so a committed move costs one dispatch, not two
        self._pending: list[tuple[int, int, int]] = []
        self._refresh_from_host()
        self._fits = np.zeros((self.n + 1, self.P), dtype=bool)
        self._last_loads = None
        self._dirty = np.zeros(self.n, dtype=bool)
        self._apply_fn = self._make_apply()
        self._find_fm = self._make_find("fm")
        self._find_rep = self._make_find("rep")
        # instrumentation (sync = blocking device->host read)
        self.syncs = 0
        self.commits = 0
        self.pass_scans = 0
        self.apply_dispatches = 0  # standalone apply programs dispatched

    # ------------------------------------------------------------ buffers
    def _refresh_from_host(self) -> None:
        """Full host -> device upload of uncov / lambdas / masks."""
        jnp = self._jnp
        st = self.state
        self._pending.clear()   # host state already includes queued moves
        uncov_p = np.zeros((self.E + 1, self.nsub), dtype=np.int32)
        uncov_p[:self.E] = st.uncov[:, self.colmap]
        self._uncov = jnp.asarray(uncov_p)
        # device lambda: masked-min value; differs from the engine's only
        # on rows with no assigned pins (engine 0, masked-min 1) -- the
        # relu(cost) terms agree, so deltas are unaffected
        lam = np.ones(self.E + 1, dtype=np.int32)
        lam[:self.E] = np.where(st.uncov[:, 0] == 0, 1, st.edge_lambda)
        self._lam = jnp.asarray(lam)
        masks = np.ones(self.n + 1, dtype=np.int32)
        masks[:self.n] = st.masks
        self._masks = jnp.asarray(masks)

    def detach(self) -> None:
        self.state.device = None

    # -------------------------------------------------------- engine hook
    def apply(self, v: int, old: int, new: int) -> None:
        """Mirror one host ``apply``/``undo`` mutation.

        Deferred: the mutation is queued and fused into the *next* find
        program (``_call_find``), so the common commit->find cadence costs
        one device dispatch per move instead of two.  ``flush`` forces the
        queue down when device buffers must be current with no find in
        sight (tests, detach-and-inspect).
        """
        self._pending.append((int(v), int(old), int(new)))

    def _edge_window(self, v: int) -> np.ndarray:
        """v's incident edges padded to Dmax with the dummy edge E."""
        w = np.full(self.Dmax if self.Dmax else 1, self.E, dtype=np.int32)
        if v < self.n:
            d = int(self.deg[v])
            if d:
                w[:d] = self.inc_edges_np[self.xinc[v]:self.xinc[v] + d]
        return w

    def _dispatch_apply(self, v: int, old: int, new: int) -> None:
        jnp = self._jnp
        self._uncov, self._lam, self._masks = self._apply_fn(
            self._uncov, self._lam, self._masks,
            jnp.int32(v), jnp.int32(old), jnp.int32(new),
            jnp.asarray(self._edge_window(v)), self._contrib, self._pc)
        self.apply_dispatches += 1

    def flush(self) -> None:
        """Dispatch every queued mutation as standalone apply programs."""
        pending, self._pending = self._pending, []
        for v, old, new in pending:
            self._dispatch_apply(v, old, new)

    def _make_apply(self):
        jax, jnp = self._jax, self._jnp
        E = self.E

        @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
        def apply_(uncov, lam, masks, v, old, new, e_win, contrib, pc):
            diff = contrib[new] - contrib[old]
            valid = e_win < E
            uncov = uncov.at[e_win].add(
                jnp.where(valid[:, None], diff[None, :], 0))
            rows = uncov[e_win]
            lam_new = jnp.min(
                jnp.where(rows == 0, pc[None, :], _NO_COVER),
                axis=1).astype(jnp.int32)
            lam = lam.at[e_win].set(jnp.where(valid, lam_new, lam[e_win]))
            masks = masks.at[v].set(new)
            return uncov, lam, masks

        return apply_

    # ------------------------------------------------------- find programs
    def _make_find(self, mode: str):
        jax, jnp = self._jax, self._jnp
        P, nsub = self.P, self.nsub
        R_blk, B_blk = self.R_blk, self.B_blk
        n = self.n
        BIG = np.int32(np.iinfo(np.int32).max)
        qbits = jnp.asarray((np.int64(1) << np.arange(P)).astype(np.int32))
        allq = jnp.arange(P, dtype=jnp.int32)
        use_pallas, interpret = self.use_pallas, self.interpret
        Mp = -(-nsub // 128) * 128
        is_rep = mode == "rep"

        def dlam_of(rows, lam_old):
            if use_pallas:
                if Mp != nsub:
                    rows = jnp.pad(rows, ((0, 0), (0, Mp - nsub)),
                                   constant_values=1)
                    pc = jnp.pad(self._pc, (0, Mp - nsub),
                                 constant_values=_NO_COVER)
                else:
                    pc = self._pc
                return front_dlam(rows, pc, lam_old, interpret=interpret)
            lam_new = jnp.min(
                jnp.where(rows == 0, self._pc[None, :], _NO_COVER),
                axis=1).astype(jnp.int32)
            return jnp.maximum(lam_new - 1, 0) - jnp.maximum(lam_old - 1, 0)

        def find(uncov, lam, masks, mu, contrib, fits, prim, popcnt,
                 blk_edge, blk_pair, blk_node, blk_pos, active,
                 nb, b0, start_pos, resume_p, maxrep,
                 av, aold, anew, ae_win):
            # fused apply: fold the last queued host mutation into this
            # program (av = n with aold == anew encodes "nothing pending" --
            # diff is all zeros, ae_win all-dummy, masks[n] is the dummy
            # row), then run the scan on the updated buffers
            adiff = contrib[anew] - contrib[aold]
            avalid = ae_win < self.E
            uncov = uncov.at[ae_win].add(
                jnp.where(avalid[:, None], adiff[None, :], 0))
            arows = uncov[ae_win]
            alam = jnp.min(
                jnp.where(arows == 0, self._pc[None, :], _NO_COVER),
                axis=1).astype(jnp.int32)
            lam = lam.at[ae_win].set(jnp.where(avalid, alam, lam[ae_win]))
            masks = masks.at[av].set(anew)

            def eval_block(b):
                edges = blk_edge[b]
                pairs = blk_pair[b]
                nodes = blk_node[b]
                poss = blk_pos[b]
                m_old = masks[nodes]
                qof = pairs % P
                slot = pairs // P
                m_row = m_old[slot]
                rows0 = uncov[edges]
                lam_old = lam[edges]
                mu_row = mu[edges]
                in_win = (poss >= start_pos) & (poss < n)

                def deltas_for(cand_row):
                    rows = (rows0 + contrib[cand_row] - contrib[m_row])
                    terms = dlam_of(rows, lam_old) * mu_row
                    return jax.ops.segment_sum(
                        terms, pairs,
                        num_segments=B_blk * P).reshape(B_blk, P)

                if not is_rep:
                    # FM: candidate masks 1 << q, primary excluded
                    d_move = deltas_for(qbits[qof])
                    feas = fits[nodes] & (allq[None, :]
                                          != prim[m_old][:, None])
                    masked = jnp.where(feas, d_move, BIG)
                    bestq = jnp.argmin(masked, axis=1).astype(jnp.int32)
                    bestd = jnp.take_along_axis(
                        masked, bestq[:, None], axis=1)[:, 0]
                    elig = (bestd <= -1) & in_win
                    sel = jnp.argmax(elig)
                    found = elig[sel]
                    return (jnp.where(found, poss[sel], n),
                            jnp.int32(0),
                            jnp.where(found, bestq[sel], 0))

                # replication: add step then drop step, host visit order
                k = popcnt[m_old]
                unset = ((m_old[:, None] >> allq[None, :]) & 1) == 0
                d_add = deltas_for(m_row | qbits[qof])
                feas_add = fits[nodes] & unset & (k < maxrep)[:, None]
                masked = jnp.where(feas_add, d_add, BIG)
                bestq = jnp.argmin(masked, axis=1).astype(jnp.int32)
                bestd = jnp.take_along_axis(
                    masked, bestq[:, None], axis=1)[:, 0]
                resuming = resume_p >= 0
                add_sup = resuming & (poss == start_pos)
                has_add = (bestd <= -1) & in_win & ~add_sup
                d_drop = deltas_for(m_row & ~qbits[qof])
                minp = jnp.where(add_sup, resume_p, 0)
                elig_drop = (~unset & (k > 1)[:, None] & (d_drop <= 0)
                             & (allq[None, :] >= minp[:, None])
                             & in_win[:, None])
                dropp = jnp.argmax(elig_drop, axis=1).astype(jnp.int32)
                has_drop = jnp.take_along_axis(
                    elig_drop, dropp[:, None], axis=1)[:, 0]
                event = has_add | has_drop
                sel = jnp.argmax(event)
                found = event[sel]
                kind = jnp.where(has_add[sel], 0, 1).astype(jnp.int32)
                q = jnp.where(has_add[sel], bestq[sel], dropp[sel])
                return (jnp.where(found, poss[sel], n), kind,
                        jnp.where(found, q, 0))

            def cond(c):
                b, pos, _, _ = c
                return (b < nb) & (pos >= n)

            def body(c):
                b = c[0]
                pos, kind, q = jax.lax.cond(
                    active[b], eval_block,
                    lambda _b: (jnp.int32(n), jnp.int32(0), jnp.int32(0)), b)
                return b + 1, pos, kind, q

            _, pos, kind, q = jax.lax.while_loop(
                cond, body,
                (b0, jnp.int32(n), jnp.int32(0), jnp.int32(0)))
            # donated buffers ride back out; the stacked triple keeps the
            # host read down to a single transfer
            return uncov, lam, masks, jnp.stack([pos, kind, q])

        return functools.partial(jax.jit, donate_argnums=(0, 1, 2))(find)

    # ------------------------------------------------------- block builder
    def _build_blocks(self, perm: np.ndarray) -> None:
        """Pack the pass's flat (pair, edge) expansion into device blocks."""
        jnp = self._jnp
        P, R_blk, B_blk = self.P, self.R_blk, self.B_blk
        n = len(perm)
        deg = self.deg[perm]
        d = np.maximum(deg, 1)
        rpn = P * d
        cum = np.cumsum(rpn)
        bounds = [0]
        while bounds[-1] < n:
            i = bounds[-1]
            base = int(cum[i - 1]) if i else 0
            j = int(np.searchsorted(cum, base + R_blk, side="right"))
            bounds.append(min(max(j, i + 1), i + B_blk, n))
        NB = len(bounds) - 1
        NBp = _pow2(NB)
        bounds = np.asarray(bounds, dtype=np.int64)
        total = int(cum[-1])
        owner = np.repeat(np.arange(n, dtype=np.int64), rpn)
        starts = cum - rpn
        off = np.arange(total, dtype=np.int64) - starts[owner]
        q = off // d[owner]
        eoff = off % d[owner]
        vo = perm[owner]
        has = deg[owner] > 0
        if len(self.inc_edges_np):
            src = np.minimum(self.xinc[vo] + eoff,
                             len(self.inc_edges_np) - 1)
            edges = np.where(has, self.inc_edges_np[src], self.E)
        else:
            edges = np.full(total, self.E, dtype=np.int64)
        blk_of = np.searchsorted(bounds, owner, side="right") - 1
        pair = (owner - bounds[blk_of]) * P + q
        rows_at = np.concatenate(([0], cum))[bounds]
        blk_edge = np.full((NBp, R_blk), self.E, dtype=np.int32)
        # padding rows funnel into the last (slot, q) segment; their edge is
        # the dummy E (mu 0), so they add exact zeros wherever they land
        blk_pair = np.full((NBp, R_blk), B_blk * P - 1, dtype=np.int32)
        blk_node = np.full((NBp, B_blk), self.n, dtype=np.int32)
        blk_pos = np.full((NBp, B_blk), self.n, dtype=np.int32)
        for b in range(NB):
            r0, r1 = int(rows_at[b]), int(rows_at[b + 1])
            blk_edge[b, :r1 - r0] = edges[r0:r1]
            blk_pair[b, :r1 - r0] = pair[r0:r1]
            i0, i1 = int(bounds[b]), int(bounds[b + 1])
            blk_node[b, :i1 - i0] = perm[i0:i1]
            blk_pos[b, :i1 - i0] = np.arange(i0, i1)
        self._bounds = bounds
        self._nb = NB
        self._blk_edge = jnp.asarray(blk_edge)
        self._blk_pair = jnp.asarray(blk_pair)
        self._blk_node = jnp.asarray(blk_node)
        self._blk_pos = jnp.asarray(blk_pos)

    # --------------------------------------------------------- host helpers
    def _boundary_start(self, rep: bool) -> np.ndarray:
        """Nodes that can hold an event at pass start (visit-time exact
        elsewhere: any other node must be dirtied first -- see module
        docstring)."""
        st = self.state
        flag = np.asarray(st.edge_lambda > 1)
        if len(self._owner):
            cnt = np.bincount(self._owner[flag[self.inc_edges_np]],
                              minlength=self.n)
            bnd = cnt > 0
        else:
            bnd = np.zeros(self.n, dtype=bool)
        if rep:
            bnd = bnd | (np.asarray(st.popcnt[st.masks]) > 1)
        return bnd

    def _fits_now(self):
        """(n+1, P) feasibility, recomputing only load-shifted columns."""
        st = self.state
        loads = np.asarray(st.loads, dtype=np.float64)
        if self._last_loads is None:
            changed = np.ones(self.P, dtype=bool)
        else:
            changed = loads != self._last_loads
        for p in np.flatnonzero(changed):
            self._fits[:self.n, p] = st.omega + loads[p] <= self.cap
        self._last_loads = loads.copy()
        return self._jnp.asarray(self._fits)

    def _active_blocks(self, bnd_start: np.ndarray):
        av = (bnd_start | self._dirty)[self._perm]
        counts = np.add.reduceat(av.astype(np.int64), self._bounds[:-1])
        active = np.zeros(len(self._blk_edge), dtype=bool)
        active[:self._nb] = counts[:self._nb] > 0
        return self._jnp.asarray(active)

    def _mark_dirty(self, v: int) -> None:
        hg = self.state.hg
        self._dirty[hg.adj_nodes[hg.xadj[v]:hg.xadj[v + 1]]] = True
        self._dirty[v] = True

    def _call_find(self, fn, b0: int, start_pos: int, resume_p: int,
                   maxrep: int, bnd_start: np.ndarray):
        jnp = self._jnp
        # fold the newest queued mutation into this find (one dispatch per
        # committed move); older queue entries -- only possible after host-
        # side phases between passes -- still go out as standalone applies
        if self._pending:
            *older, (av, aold, anew) = self._pending
            self._pending = []
            for ov, oold, onew in older:
                self._dispatch_apply(ov, oold, onew)
        else:
            av, aold, anew = self.n, 1, 1   # no-op: dummy row, zero diff
        self._uncov, self._lam, self._masks, out = fn(
            self._uncov, self._lam, self._masks, self._mu,
            self._contrib, self._fits_now(), self._prim, self._popcnt,
            self._blk_edge, self._blk_pair, self._blk_node,
            self._blk_pos, self._active_blocks(bnd_start),
            jnp.int32(self._nb), jnp.int32(b0), jnp.int32(start_pos),
            jnp.int32(resume_p), jnp.int32(maxrep),
            jnp.int32(av), jnp.int32(aold), jnp.int32(anew),
            jnp.asarray(self._edge_window(av)))
        pos, kind, q = (int(x) for x in np.asarray(out))  # THE host sync
        self.syncs += 1
        return pos, kind, q

    def _block_of(self, pos: int) -> int:
        return int(np.searchsorted(self._bounds, pos, side="right")) - 1

    # ------------------------------------------------------------ FM pass
    def run_fm(self, rng: np.random.Generator, passes: int) -> None:
        """Device-resident ``fm_refine`` sweep (decision-identical)."""
        st = self.state
        for _ in range(passes):
            perm = rng.permutation(self.n)
            if not self.fm_pass(perm):
                break
        return st.masks

    def fm_pass(self, perm: np.ndarray) -> bool:
        st = self.state
        self._perm = np.asarray(perm, dtype=np.int64)
        self._dirty[:] = False
        bnd = self._boundary_start(rep=False)
        self._build_blocks(self._perm)
        pos, improved = 0, False
        while pos < self.n:
            fpos, _, q = self._call_find(self._find_fm, self._block_of(pos),
                                         pos, -1, 0, bnd)
            if fpos >= self.n:
                self.pass_scans += 1
                break
            v = int(self._perm[fpos])
            st.apply(v, 1 << q)
            st.commit()
            self.commits += 1
            self._mark_dirty(v)
            improved = True
            pos = fpos + 1
        else:
            self.pass_scans += 1
        return improved

    # ----------------------------------------------------- replication pass
    def rep_pass(self, perm: np.ndarray, max_replicas: int | None) -> bool:
        """Device-resident add/drop node sweep of ``replicate_local_search``
        (the edge-guided phase stays on the host engine; its mutations reach
        the device through the engine hook)."""
        st = self.state
        self._perm = np.asarray(perm, dtype=np.int64)
        self._dirty[:] = False
        bnd = self._boundary_start(rep=True)
        self._build_blocks(self._perm)
        maxrep = self.P + 1 if max_replicas is None else int(max_replicas)
        pos, resume_p, improved = 0, -1, False
        while pos < self.n:
            fpos, kind, q = self._call_find(
                self._find_rep, self._block_of(pos), pos, resume_p, maxrep,
                bnd)
            if fpos >= self.n:
                self.pass_scans += 1
                break
            v = int(self._perm[fpos])
            m = int(st.masks[v])
            if kind == 0:  # add replica q, then move on (host `continue`)
                st.apply(v, m | (1 << q))
                pos, resume_p = fpos + 1, -1
            else:          # drop replica q, resume same node at p = q + 1
                st.apply(v, m & ~(1 << q))
                pos, resume_p = fpos, q + 1
            st.commit()
            self.commits += 1
            self._mark_dirty(v)
            improved = True
        else:
            self.pass_scans += 1
        return improved


# ==========================================================================
# Schedule side
# ==========================================================================

def schedule_device_supported(sched) -> bool:
    """Integer contract check: the fused int32 programs are bit-identical
    to the float64 numpy fronts only for integral weights/parameters."""
    jax, _ = _try_jax()
    if jax is None:
        return False
    inst = sched.inst
    return (_integer_valued(inst.dag.mu) and _integer_valued(inst.dag.omega)
            and float(inst.L) == int(inst.L) and float(inst.g) == int(inst.g))


class DeviceScheduleWindows:
    """Persistent device mirror of the schedule's per-superstep rows.

    Holds ``sent``/``recv``/``work`` (S, P), the top-2 triples and step
    costs as padded int32 jnp arrays, refreshed lazily after each commit
    (``mark_dirty``).  The window pricers return the same float64 deltas as
    ``schedule_front.price_comm_moves`` / ``price_comp_moves`` /
    ``price_node_moves`` -- integer device arithmetic plus the host's exact
    float64 scalar terms -- so every decision matches the numpy fronts.
    """

    def __init__(self, sched) -> None:
        jax, jnp = _try_jax()
        self._jax, self._jnp = jax, jnp
        self.sched = sched
        self.P = sched.inst.P
        self.L = int(sched.inst.L)
        self.g = int(sched.inst.g)
        self._dirty = True
        self._win_fns: dict = {}
        self.syncs = 0
        self.refreshes = 0

    def mark_dirty(self) -> None:
        self._dirty = True

    def _refresh(self) -> None:
        jnp = self._jnp
        s = self.sched
        self.S = s.S
        self.Sp = _pow2(self.S)
        P = self.P

        def rows(ll):
            a = np.zeros((self.Sp, P), dtype=np.int32)
            a[:self.S] = np.asarray(ll[:self.S])
            return jnp.asarray(a)

        def tops(tt):
            a = np.zeros((self.Sp, 3), dtype=np.int32)
            a[:self.S] = np.asarray(tt[:self.S])
            return jnp.asarray(a)

        self._sent, self._recv, self._work = (
            rows(s.sent), rows(s.recv), rows(s.work))
        self._stop, self._rtop, self._wtop = (
            tops(s._stop), tops(s._rtop), tops(s._wtop))
        sc = np.zeros(self.Sp, dtype=np.int32)
        sc[:self.S] = np.asarray(s._scost[:self.S])
        self._scost = jnp.asarray(sc)
        self._dirty = False
        self.refreshes += 1

    def _win_fn(self, kind: str, Wp: int):
        key = (kind, Wp)
        fn = self._win_fns.get(key)
        if fn is not None:
            return fn
        jax, jnp = self._jax, self._jnp
        L, g = self.L, self.g

        def step_cost(w1, h):
            return jnp.where(h >= 1, w1 + L + g * h, w1)

        if kind == "comm":
            def win(sent, recv, stop, rtop, wtop, scost, lo, src, dst, mu):
                idx = jnp.clip(lo + jnp.arange(Wp), 0, sent.shape[0] - 1)
                s_alt = jnp.where(stop[idx, 1] == src, stop[idx, 2],
                                  stop[idx, 0])
                s_new = sent[idx, src] + mu
                r_alt = jnp.where(rtop[idx, 1] == dst, rtop[idx, 2],
                                  rtop[idx, 0])
                r_new = recv[idx, dst] + mu
                h = jnp.maximum(jnp.maximum(s_alt, s_new),
                                jnp.maximum(r_alt, r_new))
                return step_cost(wtop[idx, 0], h) - scost[idx]
        else:
            def win(sent, recv, stop, rtop, wtop, scost, lo, src, dst, mu):
                # comp re-timing: src slot carries p, mu carries omega
                idx = jnp.clip(lo + jnp.arange(Wp), 0, sent.shape[0] - 1)
                w_alt = jnp.where(wtop[idx, 1] == src, wtop[idx, 2],
                                  wtop[idx, 0])
                w_new = sent[idx, src] + mu  # sent slot carries work rows
                w1 = jnp.maximum(w_alt, w_new)
                h = jnp.maximum(stop[idx, 0], rtop[idx, 0])
                return step_cost(w1, h) - scost[idx]

        fn = jax.jit(win)
        self._win_fns[key] = fn
        return fn

    def price_comm_moves(self, v: int, dst: int, ts: np.ndarray) -> np.ndarray:
        """Fused-window twin of ``schedule_front.price_comm_moves``."""
        if self._dirty:
            self._refresh()
        jnp = self._jnp
        sched = self.sched
        src, s = sched.comms[(v, dst)]
        mu = sched.inst.dag.mu[v]
        d0 = sched._comm_step_delta(s, src, dst, -mu)
        ts = np.asarray(ts, dtype=np.int64)
        lo, W = int(ts[0]), len(ts)
        fn = self._win_fn("comm", _pow2(W))
        out = fn(self._sent, self._recv, self._stop, self._rtop, self._wtop,
                 self._scost, jnp.int32(lo), jnp.int32(src), jnp.int32(dst),
                 jnp.int32(int(mu)))
        self.syncs += 1
        deltas = d0 + np.asarray(out[:W], dtype=np.float64)
        deltas[ts == s] = 0.0
        return deltas

    def price_comp_moves(self, v: int, p: int, ts: np.ndarray) -> np.ndarray:
        """Fused-window twin of ``schedule_front.price_comp_moves``."""
        if self._dirty:
            self._refresh()
        jnp = self._jnp
        sched = self.sched
        s = sched.assign[v][p]
        om = sched.inst.dag.omega[v]
        w1_minus = sched._kind_max_if("work", s, p, -om)
        d_s = sched._step_cost(w1_minus, sched.h_of(s)) - sched._scost[s]
        ts = np.asarray(ts, dtype=np.int64)
        lo, W = int(ts[0]), len(ts)
        fn = self._win_fn("comp", _pow2(W))
        out = fn(self._work, self._recv, self._stop, self._rtop, self._wtop,
                 self._scost, jnp.int32(lo), jnp.int32(p), jnp.int32(0),
                 jnp.int32(int(om)))
        self.syncs += 1
        deltas = d_s + np.asarray(out[:W], dtype=np.float64)
        deltas[ts == s] = 0.0
        return deltas

    def _node_fn(self, Tp: int):
        key = ("node", Tp)
        fn = self._win_fns.get(key)
        if fn is not None:
            return fn
        jax, jnp = self._jax, self._jnp
        L, g = self.L, self.g

        def fold(work, sent, recv, scost, ts, dw, ds, dr):
            # ts: (Tp,) touched steps; d*: (Tp, P, P) candidate x processor
            w1 = (work[ts][:, None, :] + dw).max(axis=2)
            s1 = (sent[ts][:, None, :] + ds).max(axis=2)
            r1 = (recv[ts][:, None, :] + dr).max(axis=2)
            h = jnp.maximum(s1, r1)
            step = jnp.where(h >= 1, w1 + L + g * h, w1)
            return (step - scost[ts][:, None]).sum(axis=0)

        fn = jax.jit(fold)
        self._win_fns[key] = fn
        return fn

    def price_node_moves(self, v: int) -> np.ndarray:
        """Fused twin of ``schedule_front.price_node_moves``: the per-
        superstep (P x P) delta matrices fold on device in one program.
        Falls back to the numpy front when few supersteps are touched."""
        from ..core.frontier.schedule_front import price_node_moves
        sched = self.sched
        P = self.P
        (p, _), = sched.assign[v].items()
        cells = _node_move_cells(sched, v)
        if len(cells) < DEVICE_MIN_STEPS:
            return price_node_moves(sched, v)
        if self._dirty:
            self._refresh()
        jnp = self._jnp
        steps = sorted(cells)
        T = len(steps)
        Tp = _pow2(T)
        ts = np.zeros(Tp, dtype=np.int64)
        ts[:T] = steps
        dw = np.zeros((Tp, P, P), dtype=np.int32)
        ds = np.zeros((Tp, P, P), dtype=np.int32)
        dr = np.zeros((Tp, P, P), dtype=np.int32)
        for i, t in enumerate(steps):
            w, se, r = cells[t]
            dw[i], ds[i], dr[i] = w, se, r
        out = self._node_fn(Tp)(self._work, self._sent, self._recv,
                                self._scost, jnp.asarray(ts),
                                jnp.asarray(dw), jnp.asarray(ds),
                                jnp.asarray(dr))
        self.syncs += 1
        deltas = np.asarray(out, dtype=np.float64)
        deltas[p] = 0.0
        return deltas


def _node_move_cells(sched, v: int) -> dict:
    """Per-superstep (work, sent, recv) (P, P) int delta matrices of the
    compound node move -- the same cells ``price_node_moves`` accumulates,
    in the same fill order (int32; caller guarantees integral weights)."""
    P = sched.inst.P
    (p, s), = sched.assign[v].items()
    dag = sched.inst.dag
    mu, om = int(dag.mu[v]), int(dag.omega[v])
    allq = np.arange(P)
    cells: dict[int, list] = {}

    def at(t):
        got = cells.get(t)
        if got is None:
            got = [np.zeros((P, P), dtype=np.int32) for _ in range(3)]
            cells[t] = got
        return got

    for dst in sorted(sched.src_index.get((v, p), ())):
        _, t = sched.comms[(v, dst)]
        _, se, r = at(t)
        se[:, p] -= mu
        r[dst, dst] -= mu
        keep = allq != dst
        se[allq[keep], allq[keep]] += mu
    for q in range(P):
        c0 = sched.comms.get((v, q))
        if c0 is not None and c0[0] != p:
            src0, t0 = c0
            at(t0)[1][q, src0] -= mu
            at(t0)[2][q, q] -= mu
    w = at(s)[0]
    w[:, p] -= om
    w[allq, allq] += om
    uses_p = sched.uses_on(v, p)
    if uses_p:
        tf = min(uses_p) - 1
        at(tf)[1][allq, allq] += mu
        at(tf)[2][:, p] += mu
    return cells
