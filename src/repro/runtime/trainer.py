"""Fault-tolerant training driver.

Production behaviors implemented and exercised by tests:
  * checkpoint/restart: atomic checkpoints every N steps (async IO
    overlapped with compute); on (re)start the latest step is restored,
    including the data-pipeline cursor -> byte-identical resume;
  * failure handling: any exception in a step triggers restore-from-last-
    checkpoint with bounded retries (``max_failures``), mirroring how a
    TPU pod coordinator restarts after a chip/ICI failure.  A hook lets
    tests inject failures deterministically;
  * straggler mitigation: per-step wall-time watchdog; steps slower than
    ``straggler_factor``x the trailing median are logged and counted --
    on a real pod this signal drives hot-spare swap-in, here it feeds
    metrics (and is unit-tested);
  * elastic re-scaling: ``restore`` accepts a different mesh; the
    checkpointer re-places every shard under the new topology.
"""
from __future__ import annotations

import dataclasses
import statistics
import time

import jax
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..data.pipeline import DataConfig, SyntheticTokenStream
from ..models.config import ModelConfig
from ..optim import adamw
from ..parallel import sharding as shd
from ..train import step as step_lib


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_failures: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, data_cfg: DataConfig,
                 tcfg: TrainerConfig, opt_cfg: adamw.AdamWConfig | None = None,
                 failure_hook=None):
        self.cfg = cfg
        self.mesh = mesh
        self.tcfg = tcfg
        self.data = SyntheticTokenStream(cfg, data_cfg)
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep)
        self.failure_hook = failure_hook or (lambda step: None)
        self.step_times: list[float] = []
        self.stragglers = 0
        shd.set_active_mesh(mesh)
        self.ts = step_lib.build_train_step(cfg, mesh, opt_cfg=opt_cfg)
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()

    # ------------------------------------------------------------- state
    def fresh_state(self, seed: int = 0):
        from ..models.model import Model
        model = Model(self.cfg,
                      n_ep_shards=self.mesh.shape.get("model", 1))
        with shd.use_mesh(self.mesh):
            params = jax.jit(
                model.init,
                out_shardings=self.ts.state_shardings["params"])(
                jax.random.PRNGKey(seed))
            opt = jax.jit(
                lambda p: adamw.init_state(self.opt_cfg, p),
                out_shardings=self.ts.state_shardings["opt"])(params)
        return {"params": params, "opt": opt}

    def try_restore(self, state):
        last = self.ckpt.latest_step()
        if last is None:
            return state, 0
        restored, extra = self.ckpt.restore(
            last, self.ts.abstract_state, self.ts.state_shardings)
        self.data.restore(extra["data"])
        return restored, int(extra["step"])

    # -------------------------------------------------------------- loop
    def run(self, state=None, seed: int = 0):
        state = state if state is not None else self.fresh_state(seed)
        state, start = self.try_restore(state)
        step = start
        failures = 0
        metrics_hist = []
        while step < self.tcfg.steps:
            try:
                batch_np = self.data.next_batch()
                self.failure_hook(step)  # test injection point
                t0 = time.monotonic()
                with shd.use_mesh(self.mesh):
                    batch = jax.device_put(batch_np)
                    state, metrics = self.ts.step_fn(state, batch)
                    loss = float(metrics["loss"])
                dt = time.monotonic() - t0
                self._watch_straggler(dt, step)
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
                metrics_hist.append({"step": step, "loss": loss,
                                     "seconds": dt})
                step += 1
                if step % self.tcfg.ckpt_every == 0 or step == self.tcfg.steps:
                    self.ckpt.save_async(
                        step, state,
                        extra={"step": step, "data": self.data.state()})
                if step % self.tcfg.log_every == 0:
                    print(f"[train] step {step} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)", flush=True)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # noqa: BLE001 -- pod-level restart path
                failures += 1
                print(f"[train] step {step} FAILED ({type(e).__name__}: {e}); "
                      f"restart {failures}/{self.tcfg.max_failures}",
                      flush=True)
                if failures > self.tcfg.max_failures:
                    raise
                self.ckpt.wait()
                state = self.fresh_state(seed)
                state, step = self.try_restore(state)
        self.ckpt.wait()
        return state, metrics_hist

    def _watch_straggler(self, dt: float, step: int) -> None:
        if len(self.step_times) >= 5:
            med = statistics.median(self.step_times[-20:])
            if dt > self.tcfg.straggler_factor * med:
                self.stragglers += 1
                print(f"[train] straggler at step {step}: {dt*1e3:.0f}ms "
                      f"vs median {med*1e3:.0f}ms", flush=True)
        self.step_times.append(dt)
