"""Assigned architectures (exact configs from the task pool) + reductions.

Every entry is selectable via ``--arch <id>`` in the launchers.  Sources are
cited in the assignment; deviations are noted inline and in DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig, Segment

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# LM-family transformers (10 archs)
# --------------------------------------------------------------------------

# [audio] encoder-only, wav2vec2/HuBERT arch [arXiv:2106.07447]
register(ModelConfig(
    name="hubert-xlarge", family="audio",
    d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504,
    segments=(Segment("dense", 48, attn="gqa", causal=False),),
    frame_input=True, rope_theta=1e4,
))

# [dense] llama-arch GQA [arXiv:2403.04652]
register(ModelConfig(
    name="yi-34b", family="dense",
    d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000,
    segments=(Segment("dense", 60),),
    rope_theta=5e6,
))

# [dense] llama-arch [arXiv:2401.14196]
register(ModelConfig(
    name="deepseek-coder-33b", family="dense",
    d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200, vocab=32256,
    segments=(Segment("dense", 62),),
    rope_theta=1e5,
))

# [dense] llama-arch small [hf:HuggingFaceTB/SmolLM-135M]
register(ModelConfig(
    name="smollm-135m", family="dense",
    d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536, vocab=49152,
    segments=(Segment("dense", 30),),
    tie_embeddings=True,
    strategy="dp_seq",   # tiny model: batch+sequence parallel, replicated params
))

# [dense] llama-arch MHA [arXiv:2401.02954]
register(ModelConfig(
    name="deepseek-7b", family="dense",
    d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008, vocab=102400,
    segments=(Segment("dense", 30),),
))

# [moe] 64 experts top-8, expert d_ff=1024, no shared [arXiv:2409.02060]
register(ModelConfig(
    name="olmoe-1b-7b", family="moe",
    d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    segments=(Segment("moe", 16),),
    n_experts=64, top_k=8, moe_d_ff=1024, n_shared_experts=0,
))

# [moe] MLA + 1 shared + 256 routed top-8 + MTP [arXiv:2412.19437]
# assigned d_ff=2048 is the routed-expert dim; the first 3 layers are dense
# with d_ff=18432 as in the released model.
register(ModelConfig(
    name="deepseek-v3-671b", family="moe",
    d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432, vocab=129280,
    segments=(Segment("dense", 3, attn="mla"),
              Segment("moe", 58, attn="mla")),
    n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    mtp_depth=1, mtp_loss_weight=0.1,
))

# [vlm] cross-attn image layers every 5th layer (8 of 40)
# [hf:meta-llama/Llama-3.2-11B-Vision]; vision frontend is a STUB
# (precomputed patch embeddings from input_specs).
register(ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
    segments=(Segment("vision_group", 8, sub_layers=5, cross_attn=True),),
    n_image_tokens=1024, rope_theta=5e5,
))

# [ssm] mamba1, attn-free [arXiv:2410.05355]
register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0, vocab=65024,
    segments=(Segment("mamba", 64, attn="none"),),
    ssm_state=16, d_conv=4, ssm_expand=2,
))

# [hybrid] parallel attn+mamba heads [arXiv:2411.13676]; SWA 1024 with
# full-attention first/middle/last layers (Hymba's global/local pattern);
# meta tokens not modeled (DESIGN.md §4).
register(ModelConfig(
    name="hymba-1.5b", family="hybrid",
    d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001,
    segments=(Segment("hybrid", 1, sliding_window=0),
              Segment("hybrid", 15, sliding_window=1024),
              Segment("hybrid", 1, sliding_window=0),
              Segment("hybrid", 14, sliding_window=1024),
              Segment("hybrid", 1, sliding_window=0)),
    ssm_state=16, d_conv=4, ssm_expand=2,
))


# --------------------------------------------------------------------------
# Reductions for CPU smoke tests
# --------------------------------------------------------------------------

def reduce_config(cfg: ModelConfig, layers_per_segment: int = 1) -> ModelConfig:
    """Small same-family config: few layers, narrow dims, tiny vocab."""
    heads = max(2, min(4, cfg.n_heads)) if cfg.n_heads else 0
    kv = heads if cfg.n_kv_heads == cfg.n_heads else max(1, heads // 2)
    if cfg.n_heads == 0:
        heads = kv = 0
    segs = tuple(dataclasses.replace(
        s, n_layers=min(s.n_layers, layers_per_segment),
        sliding_window=min(s.sliding_window, 16) if s.sliding_window else 0,
        sub_layers=min(s.sub_layers, 3)) for s in cfg.segments)
    return cfg.with_(
        d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
        d_ff=96 if cfg.d_ff else 0, vocab=128, segments=segs,
        n_experts=8 if cfg.n_experts else 0,
        top_k=2 if cfg.n_experts else 0,
        moe_d_ff=32 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        q_lora_rank=24 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=4 if cfg.ssm_state else 0,
        dt_rank=8 if cfg.ssm_state else 0,
        n_image_tokens=8 if cfg.n_image_tokens else 0,
        mtp_depth=min(cfg.mtp_depth, 1),
        remat="none",
    )
