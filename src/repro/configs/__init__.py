from .registry import get_config, list_archs, reduce_config, register
from .shapes import SHAPES, cell_is_applicable, input_specs

__all__ = ["get_config", "list_archs", "reduce_config", "register",
           "SHAPES", "cell_is_applicable", "input_specs"]
