"""Assigned input shapes and abstract input specs for the dry-run.

Shapes (per assignment):
    train_4k      seq_len=4096    global_batch=256   (train_step)
    prefill_32k   seq_len=32768   global_batch=32    (prefill)
    decode_32k    seq_len=32768   global_batch=128   (decode: 1 new token,
                                                      KV cache of seq_len)
    long_500k     seq_len=524288  global_batch=1     (long-context decode)

Applicability (DESIGN.md §4): ``long_500k`` requires sub-quadratic
attention -> only SSM/hybrid archs; encoder-only archs have no decode.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def cell_is_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    shape = SHAPES[shape_name]
    encoder_only = all(not s.causal for s in cfg.segments)
    if encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k needs sub-quadratic attention (skip for full-attention archs)"
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    shape = SHAPES[shape_name]
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        out = {}
        if cfg.frame_input:
            out["frames"] = sds((B, S, cfg.d_model), dt)
        else:
            out["tokens"] = sds((B, S), i32)
        if shape.kind == "train":
            out["labels"] = sds((B, S), i32)
        if cfg.n_image_tokens:
            out["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model), dt)
        return out
    # decode: one new token with a cache of seq_len
    out = {"tokens": sds((B, 1), i32)}
    return out
