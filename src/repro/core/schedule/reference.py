"""Seed full-recompute scheduling stack, preserved as the equivalence oracle.

This module is the pre-engine implementation: ``Schedule`` keeps numpy load
matrices and re-derives superstep costs through a dirty-set sweep, and every
compound trial move (superstep merging, superstep replication) prices itself
by ``copy()`` + mutate + compare + discard.  The engine-backed stack in
``bsp.py`` / ``replication.py`` / ``list_sched.py`` must produce *identical
final costs* on the same instances -- ``tests/test_schedule_engine.py`` and
``benchmarks/scheduling.py`` hold the two paths together, and the only
intended difference is wall-clock.

To make that equivalence exact, the deliberate deviations from the seed
are deterministic tie-breaking (sorted iteration over comms/compute sets,
``(superstep, processor)`` keys for source selection) and -- since the
frontier-pricing refactor -- the SR pass's commit-the-winner rule: per
superstep the whole ``(p1, p2)`` front is priced by its *pre-prune* cost
delta and the best improving candidate commits (ties to the smallest
pair).  The engine drivers apply the same rules, so container iteration
order can never split the two search trajectories.  With integer-valued
weights (all shipped datasets) every cost comparison is exact, making the
trajectories bit-identical.

Use as a namespace: ``from repro.core.schedule import reference as ref`` and
drive ``ref.bspg_schedule`` / ``ref.hill_climb`` / ``ref.basic_heuristic`` /
``ref.advanced_heuristic`` on ``ref.Schedule`` objects.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from .bsp import EPS, INF, BspInstance  # noqa: F401  (re-exported)
# The SR mutation sequence is *decision* logic shared verbatim with the
# engine path -- one home keeps the two trajectories in lockstep (the PR 2
# contract: same decisions, independent mechanics).  What this oracle
# still checks independently is everything below the decisions: full-
# recompute numpy load rows and dirty-set costs vs the engine's top-2 /
# undo-log bookkeeping.  The SR sequence itself is cross-checked the
# other way, against the frontier's *pure* cell simulation, by
# tests/test_frontier.py's pricing-vs-replay property test.
from ..frontier.schedule_front import (apply_sm_mutations,
                                       apply_sr_mutations, split_front)
from .engine import apply_split_mutations


class Schedule:
    """Seed BSP schedule: numpy rows, dirty-set incremental total."""

    def __init__(self, inst: BspInstance, S: int):
        self.inst = inst
        P = inst.P
        self.S = S
        self.comp: list[list[set[int]]] = [[set() for _ in range(P)] for _ in range(S)]
        # (v, dst) -> (src, superstep)
        self.comms: dict[tuple[int, int], tuple[int, int]] = {}
        # (v, src) -> set of dsts, for O(deg) use queries
        self.src_index: dict[tuple[int, int], set[int]] = defaultdict(set)
        # v -> {p: superstep computed}  (at most one superstep per (v,p))
        self.assign: list[dict[int, int]] = [dict() for _ in range(inst.dag.n)]
        self.work = np.zeros((S, P))
        self.sent = np.zeros((S, P))
        self.recv = np.zeros((S, P))
        self._cost_arr = np.zeros(S)
        self._total = 0.0
        self._dirty: set[int] = set()

    # ------------------------------------------------------------- mutation
    def _grow(self, s: int) -> None:
        while s >= self.S:
            self.comp.append([set() for _ in range(self.inst.P)])
            self.work = np.vstack([self.work, np.zeros((1, self.inst.P))])
            self.sent = np.vstack([self.sent, np.zeros((1, self.inst.P))])
            self.recv = np.vstack([self.recv, np.zeros((1, self.inst.P))])
            self._cost_arr = np.append(self._cost_arr, 0.0)
            self.S += 1

    def add_comp(self, v: int, p: int, s: int) -> None:
        self._grow(s)
        assert p not in self.assign[v], f"node {v} already on proc {p}"
        self.comp[s][p].add(v)
        self.assign[v][p] = s
        self.work[s, p] += self.inst.dag.omega[v]
        self._dirty.add(s)

    def remove_comp(self, v: int, p: int) -> None:
        s = self.assign[v].pop(p)
        self.comp[s][p].discard(v)
        self.work[s, p] -= self.inst.dag.omega[v]
        self._dirty.add(s)

    def add_comm(self, v: int, src: int, dst: int, s: int) -> None:
        self._grow(s)
        assert (v, dst) not in self.comms
        self.comms[(v, dst)] = (src, s)
        self.src_index[(v, src)].add(dst)
        mu = self.inst.dag.mu[v]
        self.sent[s, src] += mu
        self.recv[s, dst] += mu
        self._dirty.add(s)

    def remove_comm(self, v: int, dst: int) -> None:
        src, s = self.comms.pop((v, dst))
        self.src_index[(v, src)].discard(dst)
        mu = self.inst.dag.mu[v]
        self.sent[s, src] -= mu
        self.recv[s, dst] -= mu
        self._dirty.add(s)

    def move_comm(self, v: int, dst: int, new_s: int) -> None:
        src, _ = self.comms[(v, dst)]
        self.remove_comm(v, dst)
        self.add_comm(v, src, dst, new_s)

    # ------------------------------------------------------------- presence
    def compute_sstep(self, v: int, p: int) -> float:
        return self.assign[v].get(p, INF)

    def recv_sstep(self, v: int, p: int) -> float:
        c = self.comms.get((v, p))
        return c[1] if c is not None else INF

    def present_at(self, v: int, p: int, s: int) -> bool:
        """Usable on p in superstep s (for compute or as a send source)."""
        return self.compute_sstep(v, p) <= s or self.recv_sstep(v, p) < s

    # ----------------------------------------------------------------- cost
    def superstep_cost(self, s: int) -> float:
        c = float(self.work[s].max())
        h = max(self.sent[s].max(), self.recv[s].max())
        if h > EPS:
            c += self.inst.L + self.inst.g * h
        return c

    def cost(self) -> float:
        return sum(self.superstep_cost(s) for s in range(self.S))

    def current_cost(self) -> float:
        """Incrementally maintained total cost (O(dirty supersteps))."""
        for s in self._dirty:
            c = self.superstep_cost(s)
            self._total += c - self._cost_arr[s]
            self._cost_arr[s] = c
        self._dirty.clear()
        return self._total

    # ------------------------------------------------------ use / windows
    def uses_on(self, v: int, p: int) -> list[int]:
        """Supersteps where v's value is consumed on p (compute or send)."""
        out = []
        for c in self.inst.dag.children[v]:
            s = self.assign[c].get(p)
            if s is not None:
                out.append(s)
        for dst in self.src_index.get((v, p), ()):
            out.append(self.comms[(v, dst)][1])
        return sorted(out)

    def first_use_on(self, v: int, p: int) -> float:
        u = self.uses_on(v, p)
        return u[0] if u else INF

    def earliest_replication(self, v: int, p: int) -> float:
        """First superstep where all parents of v are present on p."""
        e = 0
        for u in self.inst.dag.parents[v]:
            cs = self.compute_sstep(u, p)
            rs = self.recv_sstep(u, p)
            e = max(e, min(cs, rs + 1))
        return e

    # -------------------------------------------------------------- cleanup
    def prune_useless_comms(self) -> int:
        """Drop comms whose value is never used on the destination after
        arrival (can appear after replication rewrites)."""
        drop = []
        for (v, dst), (src, s) in self.comms.items():
            cs = self.compute_sstep(v, dst)
            needed = any(t > s and not cs <= t for t in self.uses_on(v, dst))
            if not needed:
                drop.append((v, dst))
        for key in drop:
            self.remove_comm(*key)
        return len(drop)

    def compact(self) -> None:
        """Remove empty supersteps (no compute and no comm anywhere)."""
        keep = [s for s in range(self.S)
                if self.work[s].any() or self.sent[s].any() or self.recv[s].any()
                or any(self.comp[s][p] for p in range(self.inst.P))]
        remap = {old: new for new, old in enumerate(keep)}
        self.comp = [self.comp[s] for s in keep]
        self.work = self.work[keep]
        self.sent = self.sent[keep]
        self.recv = self.recv[keep]
        self.S = len(keep)
        self._cost_arr = np.array([self.superstep_cost(s) for s in range(self.S)])
        self._total = float(self._cost_arr.sum())
        self._dirty = set()
        for v in range(self.inst.dag.n):
            self.assign[v] = {p: remap[s] for p, s in self.assign[v].items()}
        self.comms = {k: (src, remap[s]) for k, (src, s) in self.comms.items()}

    def copy(self) -> "Schedule":
        other = Schedule.__new__(Schedule)
        other.inst = self.inst
        other.S = self.S
        other.comp = [[set(ps) for ps in row] for row in self.comp]
        other.comms = dict(self.comms)
        other.src_index = defaultdict(set)
        for k, dsts in self.src_index.items():
            if dsts:
                other.src_index[k] = set(dsts)
        other.assign = [dict(a) for a in self.assign]
        other.work = self.work.copy()
        other.sent = self.sent.copy()
        other.recv = self.recv.copy()
        other._cost_arr = self._cost_arr.copy()
        other._total = self._total
        other._dirty = set(self._dirty)
        return other

    def stats(self) -> dict:
        return {
            "cost": self.cost(),
            "supersteps": self.S,
            "comms": len(self.comms),
            "replicas": sum(len(a) - 1 for a in self.assign if len(a) > 1),
        }


# ==========================================================================
# Replication heuristics (seed mechanics: mutate + compare + revert / copy)
# ==========================================================================

def _replication_window(sched: Schedule, v: int, dst: int) -> tuple[int, int]:
    e = sched.earliest_replication(v, dst)
    if e == INF:  # some parent never becomes available on dst
        return 1, 0
    first = sched.first_use_on(v, dst)
    hi = int(first) if first is not INF else sched.S - 1
    return int(e), min(hi, sched.S - 1)


def _best_replication_sstep(sched: Schedule, v: int, dst: int) -> tuple[int, float] | None:
    """Cheapest superstep (by compute-cost increase) to replicate v on dst."""
    lo, hi = _replication_window(sched, v, dst)
    if lo > hi:
        return None
    w = sched.inst.dag.omega[v]
    best_t, best_inc = None, INF
    for t in range(lo, hi + 1):
        cur_max = sched.work[t].max()
        inc = max(0.0, sched.work[t, dst] + w - cur_max)
        if inc < best_inc - EPS:
            best_inc, best_t = inc, t
        if inc <= EPS:
            break  # cannot do better than free
    return (best_t, best_inc) if best_t is not None else None


def try_replicate_for_comm(sched: Schedule, v: int, dst: int) -> bool:
    """Basic move: drop comm (v -> dst), replicate v on dst instead."""
    if dst in sched.assign[v]:
        return False
    cand = _best_replication_sstep(sched, v, dst)
    if cand is None:
        return False
    t, _ = cand
    src, s_comm = sched.comms[(v, dst)]
    before = sched.current_cost()
    sched.remove_comm(v, dst)
    sched.add_comp(v, dst, t)
    after = sched.current_cost()
    if after < before - EPS:
        return True
    sched.remove_comp(v, dst)
    sched.add_comm(v, src, dst, s_comm)
    sched.current_cost()
    return False


def basic_heuristic(sched: Schedule, max_passes: int = 50) -> Schedule:
    for _ in range(max_passes):
        improved = False
        for (v, dst) in sorted(sched.comms.keys()):
            if (v, dst) not in sched.comms:
                continue
            if try_replicate_for_comm(sched, v, dst):
                improved = True
        if not improved:
            break
    sched.prune_useless_comms()
    sched.compact()
    return sched


def batch_replication_pass(sched: Schedule) -> bool:
    """BR: per superstep, simultaneously remove one comm from every
    saturated send/recv side, replicating the carried values."""
    improved_any = False
    for s in range(sched.S):
        while True:
            h = max(sched.sent[s].max(), sched.recv[s].max())
            if h <= EPS:
                break
            comms_at_s = sorted((v, dst, src)
                                for (v, dst), (src, t) in sched.comms.items()
                                if t == s)
            if not comms_at_s:
                break
            sat = [("sent", p) for p in range(sched.inst.P)
                   if sched.sent[s, p] >= h - EPS] + \
                  [("recv", p) for p in range(sched.inst.P)
                   if sched.recv[s, p] >= h - EPS]
            before = sched.current_cost()
            log: list = []
            chosen: set[tuple[int, int]] = set()
            feasible = True
            for side, p in sat:
                # already covered by a chosen comm?
                covered = any((side == "sent" and src == p) or
                              (side == "recv" and dst == p)
                              for (v, dst) in chosen
                              for (vv, dd, src) in comms_at_s
                              if (vv, dd) == (v, dst))
                if covered:
                    continue
                # cheapest replication among comms on this side
                best = None
                for (v, dst, src) in comms_at_s:
                    if (v, dst) in chosen or (v, dst) not in sched.comms:
                        continue
                    if (side == "sent" and src != p) or (side == "recv" and dst != p):
                        continue
                    if dst in sched.assign[v]:
                        continue
                    cand = _best_replication_sstep(sched, v, dst)
                    if cand is None:
                        continue
                    if best is None or cand[1] < best[2]:
                        best = (v, dst, cand[1], cand[0], src)
                if best is None:
                    feasible = False
                    break
                v, dst, _, t, src = best
                s_comm = sched.comms[(v, dst)][1]
                sched.remove_comm(v, dst)
                sched.add_comp(v, dst, t)
                log.append((v, dst, src, s_comm))
                chosen.add((v, dst))
            after = sched.current_cost()
            if feasible and chosen and after < before - EPS:
                improved_any = True
                continue  # try to shave the new maximum too
            for (v, dst, src, s_comm) in reversed(log):
                sched.remove_comp(v, dst)
                sched.add_comm(v, src, dst, s_comm)
            sched.current_cost()
            break
    return improved_any


def try_merge_with_replication(sched: Schedule, s: int) -> float | None:
    """Price SM (merge superstep s+1 into s) on a copy.

    Returns the pre-prune cost delta (the quantity both search paths rank
    winners by; pruning after a commit only lowers it further), or None
    when the merge is infeasible.  The mutation sequence is the shared
    ``frontier.apply_sm_mutations``; the engine path prices the same
    sequence purely (``frontier.price_superstep_merge``).
    """
    if s + 1 >= sched.S:
        return None
    trial = sched.copy()
    if not apply_sm_mutations(trial, s):
        return None
    return trial.current_cost() - sched.current_cost()


def superstep_merge_pass(sched: Schedule) -> tuple[Schedule, bool]:
    """SM sweep, winner rule: price every adjacent-pair merge and commit
    the best improving candidate (ties to the smallest s), repeating until
    dry -- the oracle mirror of the engine path's frontier-based pass."""
    improved = False
    while sched.S > 1:
        best = None
        for s in range(sched.S - 1):
            priced = try_merge_with_replication(sched, s)
            if priced is not None and priced < -EPS:
                if best is None or priced < best[0]:
                    best = (priced, s)
        if best is None:
            break
        ok = apply_sm_mutations(sched, best[1])
        assert ok, "priced SM became infeasible"
        sched.prune_useless_comms()
        sched.current_cost()
        sched.compact()
        improved = True
    return sched, improved


def try_split(sched: Schedule, s: int, late) -> float | None:
    """Price a superstep split (``late`` pairs delay into a new superstep
    s+1) on a copy.

    Returns the pre-prune cost delta (the quantity both search paths rank
    winners by; pruning after a commit only lowers it further), or None
    when the candidate is infeasible.  The mutation sequence is the shared
    ``engine.apply_split_mutations``; the engine path prices the same
    sequence purely (``frontier.price_superstep_split``).
    """
    trial = sched.copy()
    if not apply_split_mutations(trial, s, late):
        return None
    return trial.current_cost() - sched.current_cost()


def superstep_split_pass(sched: Schedule) -> tuple[Schedule, bool]:
    """Superstep-split sweep, winner rule: price every level-cut
    bipartition of every superstep's compute phase and commit the best
    improving candidate (ties to the smallest ``(s, cut)`` by ascending
    enumeration with a strict comparison), repeating until dry -- the
    oracle mirror of the engine path's frontier-based pass."""
    level = dag_levels(sched.inst.dag)
    improved = False
    while True:
        best = None
        for s in range(sched.S):
            for _cut, late in split_front(sched, s, level):
                priced = try_split(sched, s, late)
                if priced is not None and priced < -EPS:
                    if best is None or priced < best[0]:
                        best = (priced, s, late)
        if best is None:
            break
        ok = apply_split_mutations(sched, best[1], best[2])
        assert ok, "priced split became infeasible"
        sched.prune_useless_comms()
        sched.current_cost()
        sched.compact()
        improved = True
    return sched, improved


def try_superstep_replication(sched: Schedule, s: int, p1: int, p2: int) -> float | None:
    """Price SR (replicate the useful part of V_{p1,s} onto p2) on a copy.

    Returns the pre-prune cost delta (the quantity both search paths rank
    winners by; pruning after a commit only lowers it further), or None
    when the candidate is empty or infeasible.
    """
    nodes = [v for v in sorted(sched.comp[s][p1])
             if p2 not in sched.assign[v] and sched.uses_on(v, p2)]
    if not nodes:
        return None
    trial = sched.copy()
    if not apply_sr_mutations(trial, s, p1, p2, nodes):
        return None
    return trial.current_cost() - sched.current_cost()


def superstep_replication_pass(sched: Schedule) -> tuple[Schedule, bool]:
    """SR sweep, winner rule: per superstep, price the whole (p1, p2) front
    and commit the best improving candidate (ties to the lexicographically
    smallest pair), repeating the superstep until dry -- the oracle mirror
    of the engine path's frontier-based pass."""
    improved = False
    P = sched.inst.P
    s = 0
    while s < sched.S:
        best = None
        for p1 in range(P):
            for p2 in range(P):
                if p1 == p2:
                    continue
                priced = try_superstep_replication(sched, s, p1, p2)
                if priced is not None and priced < -EPS:
                    if best is None or priced < best[0]:
                        best = (priced, p1, p2)
        if best is None:
            s += 1
            continue
        _, p1, p2 = best
        nodes = [v for v in sorted(sched.comp[s][p1])
                 if p2 not in sched.assign[v] and sched.uses_on(v, p2)]
        ok = apply_sr_mutations(sched, s, p1, p2, nodes)
        assert ok, "priced SR became infeasible"
        sched.prune_useless_comms()
        sched.current_cost()
        improved = True
    return sched, improved


@dataclasses.dataclass
class AdvancedOptions:
    batch_replication: bool = True
    superstep_merging: bool = True
    superstep_replication: bool = True
    max_rounds: int = 8
    # appended last to keep positional construction stable
    superstep_splitting: bool = False


def advanced_heuristic(sched: Schedule, opts: AdvancedOptions | None = None) -> Schedule:
    opts = opts or AdvancedOptions()
    sched = basic_heuristic(sched)
    for _ in range(opts.max_rounds):
        improved = False
        # SM before BR: batch replication fills compute slack that merging
        # would otherwise exploit (ablations show SM is the bigger lever,
        # cf. paper Table 14)
        if opts.superstep_merging:
            sched, imp = superstep_merge_pass(sched)
            improved |= imp
        # splits directly after merges (same alternation as the engine path)
        if opts.superstep_splitting:
            sched, imp = superstep_split_pass(sched)
            improved |= imp
        if opts.batch_replication:
            improved |= batch_replication_pass(sched)
        if opts.superstep_replication:
            sched, imp = superstep_replication_pass(sched)
            improved |= imp
        # interleave the basic move as cleanup (cheap local improvements)
        before = sched.current_cost()
        sched = basic_heuristic(sched, max_passes=5)
        improved |= sched.current_cost() < before - EPS
        if not improved:
            break
    sched.prune_useless_comms()
    sched.compact()
    return sched


# ==========================================================================
# Non-replicating baseline (seed list scheduling + hill climbing)
# ==========================================================================

def dag_levels(dag) -> list[int]:
    level = [0] * dag.n
    for v in dag.topo_order():
        for c in dag.children[v]:
            level[c] = max(level[c], level[v] + 1)
    return level


def bspg_schedule(inst: BspInstance, seed: int = 0, slack: float = 0.15) -> Schedule:
    dag, P = inst.dag, inst.P
    rng = np.random.default_rng(seed)
    level = dag_levels(dag)
    n_levels = max(level) + 1 if dag.n else 1
    by_level: list[list[int]] = [[] for _ in range(n_levels)]
    for v in range(dag.n):
        by_level[level[v]].append(v)

    sched = Schedule(inst, n_levels)
    owner = np.full(dag.n, -1, dtype=np.int64)
    for s, nodes in enumerate(by_level):
        total_w = float(sum(dag.omega[v] for v in nodes))
        cap = (1.0 + slack) * total_w / P + float(dag.omega.max())
        load = np.zeros(P)
        # heavy nodes first; random tiebreak
        nodes = sorted(nodes, key=lambda v: (-dag.omega[v], rng.random()))
        for v in nodes:
            # affinity: communication we avoid by co-locating with parents
            aff = np.zeros(P)
            for u in dag.parents[v]:
                aff[owner[u]] += inst.g * dag.mu[u]
            score = aff - load * (total_w / P / max(cap, 1e-9))
            # prefer procs under the cap
            order = np.argsort(-score)
            chosen = next((p for p in order if load[p] + dag.omega[v] <= cap),
                          int(np.argmin(load)))
            sched.add_comp(v, int(chosen), s)
            owner[v] = chosen
            load[chosen] += dag.omega[v]

    derive_comms(sched)
    return sched


def derive_comms(sched: Schedule) -> None:
    """(Re)build the canonical comm set for the current assignment.

    Delegates to the shared (vectorized) ``engine.canonical_comm_plan``;
    the plan's sorted-(value, dst) row order is exactly the
    ``sorted(first_use.items())`` add order of the seed's scalar loop,
    which survives as ``engine._canonical_comm_plan_scalar`` and pins the
    vectorized output bit-for-bit.
    """
    from .engine import canonical_comm_plan
    for (v, dst) in list(sched.comms.keys()):
        sched.remove_comm(v, dst)
    for (v, src, p, t) in canonical_comm_plan(sched.inst.dag, sched.assign):
        sched.add_comm(v, src, p, t)


def _comm_window(sched: Schedule, v: int, dst: int) -> tuple[int, int]:
    src, _ = sched.comms[(v, dst)]
    lo = sched.assign[v][src]  # computed on src at lo -> can send from lo on
    first = sched.first_use_on(v, dst)
    hi = int(first) - 1 if first is not INF else sched.S - 1
    return lo, hi


def rebalance_comms(sched: Schedule, max_passes: int = 4) -> bool:
    """Move each comm within its window to the cheapest superstep."""
    improved_any = False
    for _ in range(max_passes):
        improved = False
        for (v, dst) in sorted(sched.comms.keys()):
            src, s = sched.comms[(v, dst)]
            lo, hi = _comm_window(sched, v, dst)
            if hi < lo:
                continue
            base = sched.current_cost()
            best_s, best_c = s, base
            for t in range(lo, hi + 1):
                if t == s:
                    continue
                sched.move_comm(v, dst, t)
                c = sched.current_cost()
                if c < best_c - EPS:
                    best_c, best_s = c, t
                sched.move_comm(v, dst, s)
                sched.current_cost()
            if best_s != s:
                sched.move_comm(v, dst, best_s)
                sched.current_cost()
                improved = improved_any = True
        if not improved:
            break
    return improved_any


def try_node_move(sched: Schedule, v: int, q: int) -> bool:
    """Move node v (single assignment) to processor q, same superstep."""
    assert len(sched.assign[v]) == 1
    (p, s), = sched.assign[v].items()
    if q == p:
        return False
    dag = sched.inst.dag
    # parents must be present on q at s
    for u in dag.parents[v]:
        if not sched.present_at(u, q, s):
            return False
    # v must not be used on p in superstep s itself (comm can't arrive in time)
    uses_p = [t for t in sched.uses_on(v, p)]
    if uses_p and min(uses_p) <= s:
        return False
    before = sched.current_cost()
    log: list = []  # (fn, args) inverse ops
    # retarget outgoing comms from p to q
    for dst in sorted(sched.src_index.get((v, p), ())):
        _, t = sched.comms[(v, dst)]
        sched.remove_comm(v, dst)
        log.append(("add_comm", (v, p, dst, t)))
        if dst != q:
            sched.add_comm(v, q, dst, t)
            log.append(("remove_comm", (v, dst)))
    # drop incoming comm to q (v becomes local there)
    if (v, q) in sched.comms:
        src0, t0 = sched.comms[(v, q)]
        sched.remove_comm(v, q)
        log.append(("add_comm", (v, src0, q, t0)))
    sched.remove_comp(v, p)
    log.append(("add_comp", (v, p, s)))
    sched.add_comp(v, q, s)
    log.append(("remove_comp", (v, q)))
    # consumers on p now need a comm
    if uses_p:
        t_first = min(uses_p)
        sched.add_comm(v, q, p, t_first - 1)
        log.append(("remove_comm", (v, p)))
    after = sched.current_cost()
    if after < before - EPS:
        return True
    for fn, args in reversed(log):
        getattr(sched, fn)(*args)
    sched.current_cost()
    return False


def node_move_pass(sched: Schedule, seed: int = 0) -> bool:
    rng = np.random.default_rng(seed)
    improved = False
    P = sched.inst.P
    for v in rng.permutation(sched.inst.dag.n):
        if len(sched.assign[v]) != 1:
            continue
        for q in range(P):
            if try_node_move(sched, int(v), q):
                improved = True
                break
    return improved


def try_merge_no_repl(sched: Schedule, s: int) -> bool:
    """Merge superstep s+1 into s if feasible without replication."""
    if s + 1 >= sched.S:
        return False
    P = sched.inst.P
    # comms at s whose value is used at s+1 must be movable to s-1
    moves = []
    for (v, dst), (src, t) in sorted(sched.comms.items()):
        if t != s:
            continue
        uses = [x for x in sched.uses_on(v, dst)
                if x > t and not sched.compute_sstep(v, dst) <= x]
        if uses and min(uses) == s + 1:
            if sched.assign[v][src] <= s - 1 and s - 1 >= 0:
                moves.append((v, dst))
            else:
                return False  # would need replication
    before = sched.current_cost()
    log: list = []
    for (v, dst) in moves:
        _, t = sched.comms[(v, dst)]
        sched.move_comm(v, dst, s - 1)
        log.append(("move_comm", (v, dst, t)))
    # shift compute s+1 -> s
    for p in range(P):
        for v in sorted(sched.comp[s + 1][p]):
            sched.remove_comp(v, p)
            sched.add_comp(v, p, s)
            log.append(("__move_comp_back", (v, p, s + 1)))
    # shift comms at s+1 -> s
    for (v, dst), (src, t) in sorted(sched.comms.items()):
        if t == s + 1:
            sched.move_comm(v, dst, s)
            log.append(("move_comm", (v, dst, s + 1)))
    after = sched.current_cost()
    if after < before - EPS:
        return True
    for fn, args in reversed(log):
        if fn == "__move_comp_back":
            v, p, old_s = args
            sched.remove_comp(v, p)
            sched.add_comp(v, p, old_s)
        else:
            getattr(sched, fn)(*args)
    sched.current_cost()
    return False


def merge_pass(sched: Schedule) -> bool:
    improved = False
    s = 0
    while s < sched.S - 1:
        if not try_merge_no_repl(sched, s):
            s += 1
        else:
            improved = True
    if improved:
        sched.compact()
    return improved


def hill_climb(sched: Schedule, rounds: int = 6, seed: int = 0) -> Schedule:
    for r in range(rounds):
        improved = False
        improved |= rebalance_comms(sched)
        improved |= node_move_pass(sched, seed=seed + r)
        improved |= merge_pass(sched)
        if not improved:
            break
    sched.compact()
    return sched


def sequential_schedule(inst: BspInstance) -> Schedule:
    """Everything on processor 0, one superstep, zero communication."""
    sched = Schedule(inst, 1)
    for v in inst.dag.topo_order():
        sched.add_comp(v, 0, 0)
    return sched


def baseline_schedule(inst: BspInstance, seed: int = 0, hc_rounds: int = 6,
                      restarts: int = 1) -> Schedule:
    """Strong non-replicating baseline: best of list-scheduling restarts
    (each followed by hill climbing) and the sequential schedule."""
    best = sequential_schedule(inst)
    for r in range(restarts):
        sched = bspg_schedule(inst, seed=seed + r)
        sched = hill_climb(sched, rounds=hc_rounds, seed=seed + r)
        if sched.current_cost() < best.current_cost() - EPS:
            best = sched
    return best
