"""Near-exact BSP scheduling for tiny DAGs (the paper's §6.2.1 ILP role).

The paper embeds BSP scheduling in an ILP (COPT, hours of solve time) for
40-80-node DAGs.  Offline we provide a branch-and-bound search over node
assignments (v -> (processor, superstep)) with:

  * exhaustive enumeration of compute-phase assignments (symmetry-broken
    over processors, pruned by work + partial-comm lower bounds);
  * for each complete assignment, communications are derived canonically
    and then improved with the comm re-placement local search.

Without replication this certifies the assignment choice exactly; the comm
phase placement is a (very tight in practice) upper bound.  For replication
we take the exact non-replicating solution as the starting point and apply
the full replication machinery, mirroring the paper's suggestion (§C.1.1)
of warm-starting the replicating ILP with the non-replicating optimum.

The bound evaluation is incremental, in the spirit of the schedule engine:
instead of re-reducing the whole (S, P) work matrix at every search node
(O(S*P) per expansion), the DFS maintains each superstep's work maximum and
their running sum with O(1) updates on assign/unassign -- the same
undo-on-backtrack discipline the partition engine uses for its B&B, and the
leaf evaluation (derive + rebalance + prune + compact) runs on the
engine-backed ``Schedule``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .bsp import EPS, BspInstance, Schedule
from .list_sched import derive_comms, rebalance_comms


@dataclasses.dataclass
class ExactScheduleResult:
    schedule: Schedule
    cost: float
    assignments_optimal: bool
    explored: int


def exact_schedule(inst: BspInstance, max_supersteps: int = 4,
                   time_limit: float = 60.0,
                   ub_sched: Schedule | None = None) -> ExactScheduleResult:
    dag, P = inst.dag, inst.P
    n = dag.n
    topo = dag.topo_order()
    t0 = time.monotonic()

    best = {"cost": np.inf, "sched": None, "explored": 0, "timed_out": False}
    if ub_sched is not None:
        best["cost"] = ub_sched.current_cost()
        best["sched"] = ub_sched.copy()

    assign_p = np.full(n, -1, dtype=np.int64)
    assign_s = np.full(n, -1, dtype=np.int64)
    work = [[0.0] * P for _ in range(max_supersteps)]
    # incremental work lower bound: per-superstep max + running sum
    step_max = [0.0] * max_supersteps
    state = {"work_lb": 0.0}
    # crude comm lower bound: each cross-processor edge costs >= g * mu / P
    # (it contributes mu to someone's sent and recv h-relation)

    def finish() -> None:
        sched = Schedule(inst, max_supersteps)
        for i, v in enumerate(topo):
            sched.add_comp(int(v), int(assign_p[i]), int(assign_s[i]))
        derive_comms(sched)
        rebalance_comms(sched, max_passes=3)
        sched.prune_useless_comms()
        sched.compact()
        c = sched.current_cost()
        if c < best["cost"] - EPS:
            best["cost"] = c
            best["sched"] = sched

    def lb_partial(cross_mu: float) -> float:
        comm_lb = inst.g * cross_mu / P + (inst.L if cross_mu > 0 else 0.0)
        return state["work_lb"] + comm_lb

    pos = {v: i for i, v in enumerate(topo)}
    parent_positions = [[pos[u] for u in dag.parents[v]] for v in topo]

    def dfs2(idx: int, used_procs: int, cross_mu: float) -> None:
        if best["timed_out"]:
            return
        best["explored"] += 1
        if best["explored"] % 4096 == 0 and time.monotonic() - t0 > time_limit:
            best["timed_out"] = True
            return
        if idx == n:
            finish()
            return
        v = topo[idx]
        omega_v = float(dag.omega[v])
        pidx = parent_positions[idx]
        min_s = 0
        for pi in pidx:
            if assign_s[pi] > min_s:
                min_s = int(assign_s[pi])
        for s in range(min_s, max_supersteps):
            for p in range(min(P, used_procs + 1)):
                ok = True
                add_mu = 0.0
                for pi in pidx:
                    if assign_p[pi] != p:
                        if assign_s[pi] >= s:
                            ok = False
                            break
                        add_mu += dag.mu[topo[pi]]
                if not ok:
                    continue
                assign_p[idx] = p
                assign_s[idx] = s
                old_w = work[s][p]
                new_w = old_w + omega_v
                work[s][p] = new_w
                old_max = step_max[s]
                old_lb = state["work_lb"]
                if new_w > old_max:
                    step_max[s] = new_w
                    state["work_lb"] = old_lb + (new_w - old_max)
                if lb_partial(cross_mu + add_mu) < best["cost"] - EPS:
                    dfs2(idx + 1, max(used_procs, p + 1), cross_mu + add_mu)
                work[s][p] = old_w
                step_max[s] = old_max
                state["work_lb"] = old_lb
                assign_p[idx] = -1
                assign_s[idx] = -1
                if best["timed_out"]:
                    return
        return

    dfs2(0, 0, 0.0)
    return ExactScheduleResult(
        schedule=best["sched"],
        cost=float(best["cost"]),
        assignments_optimal=not best["timed_out"],
        explored=best["explored"],
    )
