"""Non-replicating baseline scheduler (the role of BSPg + hill climbing in
Papp et al. [44], which the paper uses as the starting point in §6.1).

``bspg_schedule``  -- wavefront list scheduling: nodes are placed level by
level (ASAP topological depth); within a level, nodes are assigned greedily
to the processor with the best (communication-affinity - load) score, under
a per-level balance cap.  Communications are derived canonically afterwards:
one comm per (value, consumer-processor), sourced at the computing processor
and placed at the latest valid superstep (first use - 1).

``hill_climb``     -- local search on the non-replicating schedule:
  * comm re-placement within its valid window (h-relation balancing),
  * node moves to a different processor in the same superstep,
  * superstep merging when feasible *without* replication.

All moves are priced through the incremental-delta engine: comm
re-placement uses the pure ``delta_move_comm``, node moves price their
whole target front at once through the frontier layer
(``core.frontier.price_node_moves`` -- bit-equal to per-target
``delta_node_move``, so the first-feasible-improving-q decision is
unchanged; ``use_fronts=False`` keeps the per-target loop), and the
no-replication merge runs inside a ``begin()``/``rollback()`` transaction.
Tie-breaking is deterministic (sorted iteration, ``(superstep, processor)``
keys), matching ``reference.py`` decision-for-decision.
"""
from __future__ import annotations

import numpy as np

from ..hypergraph import Dag
from .bsp import EPS, INF, BspInstance, Schedule


def dag_levels(dag: Dag) -> list[int]:
    level = [0] * dag.n
    for v in dag.topo_order():
        for c in dag.children[v]:
            level[c] = max(level[c], level[v] + 1)
    return level


def bspg_schedule(inst: BspInstance, seed: int = 0, slack: float = 0.15) -> Schedule:
    dag, P = inst.dag, inst.P
    rng = np.random.default_rng(seed)
    level = dag_levels(dag)
    n_levels = max(level) + 1 if dag.n else 1
    by_level: list[list[int]] = [[] for _ in range(n_levels)]
    for v in range(dag.n):
        by_level[level[v]].append(v)

    sched = Schedule(inst, n_levels)
    owner = np.full(dag.n, -1, dtype=np.int64)
    for s, nodes in enumerate(by_level):
        total_w = float(sum(dag.omega[v] for v in nodes))
        cap = (1.0 + slack) * total_w / P + float(dag.omega.max())
        load = np.zeros(P)
        # heavy nodes first; random tiebreak
        nodes = sorted(nodes, key=lambda v: (-dag.omega[v], rng.random()))
        for v in nodes:
            # affinity: communication we avoid by co-locating with parents
            aff = np.zeros(P)
            for u in dag.parents[v]:
                aff[owner[u]] += inst.g * dag.mu[u]
            score = aff - load * (total_w / P / max(cap, 1e-9))
            # prefer procs under the cap
            order = np.argsort(-score)
            chosen = next((p for p in order if load[p] + dag.omega[v] <= cap),
                          int(np.argmin(load)))
            sched.add_comp(v, int(chosen), s)
            owner[v] = chosen
            load[chosen] += dag.omega[v]

    derive_comms(sched)
    return sched


def derive_comms(sched: Schedule) -> None:
    """(Re)build the canonical comm set for the current assignment (one
    comm per (value, proc), earliest-replica source, latest valid
    superstep -- the shared ``engine.canonical_comm_plan`` rule)."""
    from .engine import canonical_comm_plan

    for (v, dst) in list(sched.comms.keys()):
        sched.remove_comm(v, dst)
    for (v, src, p, t) in canonical_comm_plan(sched.inst.dag, sched.assign):
        sched.add_comm(v, src, p, t)


# --------------------------------------------------------------------------
# Hill climbing (non-replicating moves)
# --------------------------------------------------------------------------

def _comm_window(sched: Schedule, v: int, dst: int) -> tuple[int, int]:
    src, _ = sched.comms[(v, dst)]
    lo = sched.assign[v][src]  # computed on src at lo -> can send from lo on
    first = sched.first_use_on(v, dst)
    hi = int(first) - 1 if first is not INF else sched.S - 1
    return lo, hi


_COMM_FRONT_MIN_WINDOW = 12


def _best_window_move(sched, s: int, lo: int, hi: int, deltas,
                      scalar_delta) -> tuple[int, float]:
    """Shared argmin rule of the window-rebalancing sweeps: ascending t,
    skip the current superstep, accept only strict EPS improvements over
    the running best (ties to the earliest superstep).  ``deltas`` is the
    batched front (or None for the scalar path, pricing via
    ``scalar_delta(t)``) -- one home for the decision rule keeps the two
    paths identical by construction."""
    best_s, best_d = s, 0.0
    for t in range(lo, hi + 1):
        if t == s:
            continue
        d = deltas[t - lo] if deltas is not None else scalar_delta(t)
        if d < best_d - EPS:
            best_d, best_s = d, t
    return best_s, best_d


def rebalance_comms(sched: Schedule, max_passes: int = 4,
                    use_fronts: bool = True,
                    backend: str | None = None) -> bool:
    """Move each comm within its window to the cheapest superstep.

    Long windows (at least ``_COMM_FRONT_MIN_WINDOW`` supersteps -- the
    common case after multilevel projection, where a value's producer and
    first use can sit a whole wavefront apart) price through the batched
    ``frontier.price_comm_moves`` front, bit-equal to per-superstep
    ``delta_move_comm``; short windows keep the scalar loop (numpy
    dispatch would dominate).  Decisions are identical on both paths.
    ``backend="jax"`` (on integer-weight instances) routes long windows
    through the device-resident fused pricer (``frontier.device_windows``)
    instead -- same deltas bit-for-bit, ``_best_window_move`` stays the
    single decision home.
    """
    from ..frontier import device_windows, price_comm_moves

    win = device_windows(sched, backend)
    improved_any = False
    for _ in range(max_passes):
        improved = False
        for (v, dst) in sorted(sched.comms.keys()):
            src, s = sched.comms[(v, dst)]
            lo, hi = _comm_window(sched, v, dst)
            if hi < lo:
                continue
            if use_fronts and hi - lo + 1 >= _COMM_FRONT_MIN_WINDOW:
                ts = np.arange(lo, hi + 1)
                deltas = (win.price_comm_moves(v, dst, ts)
                          if win is not None
                          else price_comm_moves(sched, v, dst, ts))
            else:
                deltas = None
            best_s, _ = _best_window_move(
                sched, s, lo, hi, deltas,
                lambda t: sched.delta_move_comm(v, dst, t))
            if best_s != s:
                sched.move_comm(v, dst, best_s)
                if win is not None:
                    win.mark_dirty()
                improved = improved_any = True
        if not improved:
            break
    return improved_any


def _comp_window(sched: Schedule, v: int, p: int) -> tuple[int, int]:
    """Feasible supersteps to compute v on p, keeping everything else
    fixed: earliest = all parents present (same-superstep local parents
    count), latest = first use of v on p (compute uses allow the same
    superstep, send uses require presence at the send)."""
    lo = sched.earliest_replication(v, p)
    if lo == INF:
        return 1, 0
    uses = sched.uses_on(v, p)
    hi = min(uses) if uses else sched.S - 1
    return int(lo), min(int(hi), sched.S - 1)


def comp_rebalance_pass(sched: Schedule, max_passes: int = 4,
                        use_fronts: bool = True,
                        backend: str | None = None) -> bool:
    """Re-time each single-assigned node within its feasible superstep
    window on its own processor (work-max balancing across supersteps).

    The complement of ``rebalance_comms`` for the compute phase: the
    multilevel projection inherits the coarse superstep structure, which
    packs cluster chains into few supersteps -- same-superstep node moves
    cannot spread them (a chain member's parent is computed in the same
    superstep, so no other processor can host it), but sliding the chain
    tail into later slack and iterating unrolls it across supersteps.
    Windows price through the batched ``frontier.price_comp_moves`` when
    long, the scalar two-cell ``_delta_cells`` fold otherwise -- bit-equal,
    so both paths take identical decisions.  Only strictly improving
    re-timings are applied.

    Passes alternate traversal direction: reverse topological order first
    (a node is visited before its parents, so a chain pushed into later
    slack unrolls end-to-end within ONE pass -- each member's window has
    already been extended by its successor's move), then forward (pulling
    chains into earlier slack), and so on.
    """
    from ..frontier import device_windows, price_comp_moves

    win = device_windows(sched, backend)
    improved_any = False
    dag = sched.inst.dag
    topo = dag.topo_order()
    for pno in range(max_passes):
        improved = False
        for v in (reversed(topo) if pno % 2 == 0 else topo):
            if len(sched.assign[v]) != 1:
                continue
            (p, s), = sched.assign[v].items()
            if (v, p) in sched.comms:
                continue  # compute + incoming comm on one proc: out of scope
            lo, hi = _comp_window(sched, v, p)
            if hi <= lo and s == lo:
                continue
            om = dag.omega[v]
            if use_fronts and hi - lo + 1 >= _COMM_FRONT_MIN_WINDOW:
                ts = np.arange(lo, hi + 1)
                deltas = (win.price_comp_moves(v, p, ts) if win is not None
                          else price_comp_moves(sched, v, p, ts))
            else:
                deltas = None
            best_t, _ = _best_window_move(
                sched, s, lo, hi, deltas,
                lambda t: sched._delta_cells([("work", s, p, -om),
                                              ("work", t, p, om)]))
            if best_t != s:
                sched.remove_comp(v, p)
                sched.add_comp(v, p, best_t)
                if win is not None:
                    win.mark_dirty()
                improved = improved_any = True
        if not improved:
            break
    return improved_any


def try_node_move(sched: Schedule, v: int, q: int) -> bool:
    """Move node v (single assignment) to processor q, same superstep."""
    assert len(sched.assign[v]) == 1
    (p, s), = sched.assign[v].items()
    if q == p:
        return False
    dag = sched.inst.dag
    # parents must be present on q at s
    for u in dag.parents[v]:
        if not sched.present_at(u, q, s):
            return False
    # v must not be used on p in superstep s itself (comm can't arrive in time)
    uses_p = sched.uses_on(v, p)
    if uses_p and min(uses_p) <= s:
        return False
    if sched.delta_node_move(v, q) < -EPS:
        sched.apply_node_move(v, q)
        return True
    return False


def node_move_pass(sched: Schedule, seed: int = 0,
                   use_fronts: bool = True,
                   backend: str | None = None) -> bool:
    """One pass of node moves: first feasible improving target wins.

    Default path prices every target processor in one frontier front
    (``price_node_moves``); ``use_fronts=False`` keeps the pre-frontier
    per-target ``try_node_move`` loop.  ``backend="jax"`` folds the move's
    per-superstep (P x P) delta matrices on device when many supersteps
    are touched (``frontier.device_windows``).  All paths take identical
    decisions.
    """
    rng = np.random.default_rng(seed)
    improved = False
    P = sched.inst.P
    if not use_fronts:
        for v in rng.permutation(sched.inst.dag.n):
            if len(sched.assign[v]) != 1:
                continue
            for q in range(P):
                if try_node_move(sched, int(v), q):
                    improved = True
                    break
        return improved
    from ..frontier import device_windows, node_move_targets, price_node_moves
    win = device_windows(sched, backend)
    for v in rng.permutation(sched.inst.dag.n):
        v = int(v)
        if len(sched.assign[v]) != 1:
            continue
        feas = node_move_targets(sched, v)
        nq = sum(feas)
        if nq == 0:
            continue
        if nq == 1:  # batching one candidate would just pay numpy dispatch
            q = feas.index(True)
            if sched.delta_node_move(v, q) < -EPS:
                sched.apply_node_move(v, q)
                if win is not None:
                    win.mark_dirty()
                improved = True
            continue
        deltas = (win.price_node_moves(v) if win is not None
                  else price_node_moves(sched, v))
        for q in range(P):
            if feas[q] and deltas[q] < -EPS:
                sched.apply_node_move(v, q)
                if win is not None:
                    win.mark_dirty()
                improved = True
                break
    return improved


def try_merge_no_repl(sched: Schedule, s: int) -> bool:
    """Merge superstep s+1 into s if feasible without replication."""
    if s + 1 >= sched.S:
        return False
    P = sched.inst.P
    # comms at s whose value is used at s+1 must be movable to s-1
    moves = []
    for (v, dst), (src, t) in sorted(sched.comms.items()):
        if t != s:
            continue
        uses = [x for x in sched.uses_on(v, dst)
                if x > t and not sched.compute_sstep(v, dst) <= x]
        if uses and min(uses) == s + 1:
            if sched.assign[v][src] <= s - 1 and s - 1 >= 0:
                moves.append((v, dst))
            else:
                return False  # would need replication
    before = sched.current_cost()
    sched.begin()
    for (v, dst) in moves:
        sched.move_comm(v, dst, s - 1)
    # shift compute s+1 -> s
    for p in range(P):
        for v in sorted(sched.comp[s + 1][p]):
            sched.remove_comp(v, p)
            sched.add_comp(v, p, s)
    # shift comms at s+1 -> s
    for (v, dst), (src, t) in sorted(sched.comms.items()):
        if t == s + 1:
            sched.move_comm(v, dst, s)
    if sched.current_cost() < before - EPS:
        sched.commit()
        return True
    sched.rollback()
    return False


def merge_pass(sched: Schedule) -> bool:
    improved = False
    s = 0
    while s < sched.S - 1:
        if not try_merge_no_repl(sched, s):
            s += 1
        else:
            improved = True
    if improved:
        sched.compact()
    return improved


def hill_climb(sched: Schedule, rounds: int = 6, seed: int = 0,
               use_fronts: bool = True,
               backend: str | None = None) -> Schedule:
    for r in range(rounds):
        improved = False
        improved |= rebalance_comms(sched, backend=backend)
        improved |= node_move_pass(sched, seed=seed + r,
                                   use_fronts=use_fronts, backend=backend)
        improved |= merge_pass(sched)
        if not improved:
            break
    sched.compact()
    return sched


def sequential_schedule(inst: BspInstance) -> Schedule:
    """Everything on processor 0, one superstep, zero communication."""
    sched = Schedule(inst, 1)
    for v in inst.dag.topo_order():
        sched.add_comp(v, 0, 0)
    return sched


def baseline_schedule(inst: BspInstance, seed: int = 0, hc_rounds: int = 6,
                      restarts: int = 1) -> Schedule:
    """Strong non-replicating baseline: best of list-scheduling restarts
    (each followed by hill climbing) and the sequential schedule (often
    optimal for tiny DAGs with large g, cf. paper §C.2.2)."""
    best = sequential_schedule(inst)
    for r in range(restarts):
        sched = bspg_schedule(inst, seed=seed + r)
        sched = hill_climb(sched, rounds=hc_rounds, seed=seed + r)
        if sched.current_cost() < best.current_cost() - EPS:
            best = sched
    return best
