from .bsp import EPS, BspInstance, Schedule
from .engine import ScheduleState
from .exact import ExactScheduleResult, exact_schedule
from .list_sched import (baseline_schedule, bspg_schedule, derive_comms,
                         hill_climb, rebalance_comms)
from .multilevel import MultilevelScheduleOptions, multilevel_schedule
from .replication import (AdvancedOptions, advanced_heuristic,
                          best_replicated_schedule,
                          basic_heuristic, batch_replication_pass,
                          superstep_merge_pass, superstep_replication_pass,
                          superstep_split_pass)

__all__ = [
    "EPS", "BspInstance", "Schedule", "ScheduleState",
    "ExactScheduleResult", "exact_schedule",
    "baseline_schedule", "bspg_schedule", "derive_comms", "hill_climb",
    "rebalance_comms", "AdvancedOptions", "advanced_heuristic",
    "basic_heuristic", "batch_replication_pass", "best_replicated_schedule",
    "MultilevelScheduleOptions", "multilevel_schedule",
    "superstep_merge_pass",
    "superstep_replication_pass",
    "superstep_split_pass",
]
