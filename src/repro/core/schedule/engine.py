"""Incremental-delta BSP schedule engine (mirror of the partition engine).

The seed costed most candidate moves by ``Schedule.copy()`` + mutate +
discard -- an O(n + S*P + comms) copy per trial -- and re-derived superstep
costs through a dirty-set sweep.  ``ScheduleState`` replaces both: it owns
the compute phases (``comp``/``assign``), the communication phase
(``comms``/``src_index``) and the per-superstep ``work``/``sent``/``recv``
load rows, and keeps just enough derived state to price and apply any
primitive move in O(touched supersteps):

  * per superstep s and per row kind (work / sent / recv) the **top-2
    maxima** ``[m1, i1, m2]`` -- the row maximum, one argmax, and the
    maximum over the remaining processors -- so "what is the row max if
    entry p changed to x" is an O(1) query and maintenance needs an O(P)
    rescan only when the leader drops below the runner-up;
  * the cached superstep cost ``_scost[s] = m1_work + [h > EPS] * (L + g*h)``
    with ``h = max(m1_sent, m1_recv)``, and their running total ``_total``,
    so ``current_cost()`` is O(1).

Pricing vs applying
-------------------
``delta_add_comp`` / ``delta_remove_comp`` / ``delta_add_comm`` /
``delta_remove_comm`` / ``delta_move_comm`` / ``delta_replicate_for_comm`` /
``delta_node_move`` are **pure**: they fold the move's cell changes per
touched superstep and return the exact total-cost change without mutating
anything.  The mutation methods (``add_comp``, ``remove_comm``, ...) keep
every invariant eagerly, in O(1) amortized per touched cell.

Transactions
------------
Compound trial moves (superstep merging, superstep replication, batch
replication, node moves) wrap their mutations in ``begin()`` ...
``commit()`` / ``rollback()``.  While a frame is open every mutation pushes
an undo record carrying the *overwritten values* (cells, top-2 triples,
step costs, total), so ``rollback`` restores the numeric state bit-for-bit
-- no inverse arithmetic, hence exact for arbitrary float weights -- and
re-inserts/removes the structural entries (comp sets, assign/comms dicts,
src_index).  Frames nest; an inner ``commit`` folds its records into the
enclosing frame.  Outside any frame, mutations skip logging entirely.

Invariants (asserted by ``check()``):
  * each row's top-2 triple matches a from-scratch scan;
  * ``_scost[s] == superstep_cost(s)`` recomputed from the rows;
  * ``_total == sum(_scost)``;
  * ``work``/``sent``/``recv`` match a rebuild from ``assign``/``comms``.

Complexity per operation (P = #processors, deg = node degree):
mutations and single-move deltas O(P) worst case, O(1) typical;
``delta_node_move`` O(out-comms + deg); ``rollback`` O(ops in the frame);
``compact`` O(nodes in shifted supersteps + comms + S*P).
"""
from __future__ import annotations

import math
from collections import defaultdict

EPS = 1e-12
"""Shared cost-comparison tolerance for every accept/threshold test in the
scheduling stack (moves are kept only when they improve by more than EPS)."""

INF = math.inf

_KINDS = ("work", "sent", "recv")


def _retop(row):
    """Fresh top-2 triple [m1, i1, m2] of a non-negative row."""
    m1, i1 = row[0], 0
    for q in range(1, len(row)):
        if row[q] > m1:
            m1, i1 = row[q], q
    m2 = 0.0
    for q, x in enumerate(row):
        if q != i1 and x > m2:
            m2 = x
    return [m1, i1, m2]


def _canonical_comm_plan_scalar(dag, assign) -> list[tuple[int, int, int, int]]:
    """Scalar reference implementation of ``canonical_comm_plan`` (kept as
    the pinned oracle for the vectorized path; see tests)."""
    first_use: dict[tuple[int, int], int] = {}
    parents = dag.parents
    for c in range(dag.n):
        for p, s in assign[c].items():
            for u in parents[c]:
                key = (u, p)
                t = first_use.get(key)
                if t is None or s < t:
                    first_use[key] = s
    plan = []
    for (v, p), s_use in sorted(first_use.items()):
        av = assign[v]
        if av.get(p, INF) <= s_use:
            continue  # locally computed in time
        src, s_src = min(((pp, ss) for pp, ss in av.items()),
                         key=lambda x: (x[1], x[0]))
        assert s_src < s_use, \
            f"value {v} for proc {p} not producible in time"
        plan.append((v, src, p, s_use - 1))
    return plan


# cap on the dense (value, processor) scratch tables of the vectorized plan;
# past it (n * P ~ 2^27 cells ~ 1 GiB of int64) fall back to the dict path
_PLAN_DENSE_CAP = 1 << 27
# expanded (assignment x parent) rows are processed in blocks of this many
# entries so peak scratch memory stays bounded at million-node projections
_PLAN_BLOCK = 1 << 22


def _canonical_comm_plan_arrays(dag, assign):
    """Vectorized core of ``canonical_comm_plan``: returns four flat int64
    arrays ``(value, src, dst, superstep)``, rows sorted by (value, dst) --
    bit-identical content to ``_canonical_comm_plan_scalar``.

    One bincount/sort pass over the flat parents-CSR instead of a python
    loop per (assignment x parent): per-(value, proc) first uses fold via a
    blocked ``np.minimum.at`` (min is order-independent, so blocking cannot
    change results), the earliest replica per value comes from one lexsort
    by (value, superstep, proc), and ascending ``np.flatnonzero`` over the
    dense first-use table reproduces the scalar ``sorted(first_use)``
    emission order exactly.
    """
    import numpy as np

    n = dag.n
    counts = np.fromiter((len(a) for a in assign), dtype=np.int64, count=n)
    m = int(counts.sum())
    z = np.zeros(0, dtype=np.int64)
    if m == 0:
        return z, z, z, z
    an_node = np.repeat(np.arange(n, dtype=np.int64), counts)
    an_p = np.fromiter((p for a in assign for p in a),
                       dtype=np.int64, count=m)
    an_s = np.fromiter((s for a in assign for s in a.values()),
                       dtype=np.int64, count=m)
    P = int(an_p.max()) + 1
    if n * P > _PLAN_DENSE_CAP:
        plan = _canonical_comm_plan_scalar(dag, assign)
        if not plan:
            return z, z, z, z
        arr = np.asarray(plan, dtype=np.int64)
        return arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]
    xpar, par_arr = dag.xpar, dag.par_arr
    indeg = np.diff(xpar)
    sentinel = np.iinfo(np.int64).max
    first_use = np.full(n * P, sentinel, dtype=np.int64)
    reps = indeg[an_node]
    cum = np.zeros(m + 1, dtype=np.int64)
    np.cumsum(reps, out=cum[1:])
    start = 0
    while start < m:
        end = int(np.searchsorted(cum, cum[start] + _PLAN_BLOCK, "left"))
        end = min(m, max(end, start + 1))
        tot = int(cum[end] - cum[start])
        if tot:
            rows = np.repeat(np.arange(start, end, dtype=np.int64),
                             reps[start:end])
            within = cum[start] + np.arange(tot, dtype=np.int64) - cum[rows]
            par = par_arr[xpar[an_node[rows]] + within]
            np.minimum.at(first_use, par * P + an_p[rows], an_s[rows])
        start = end
    # local compute superstep per (value, proc); at most one s per pair
    comp_s = np.full(n * P, sentinel, dtype=np.int64)
    comp_s[an_node * P + an_p] = an_s
    # earliest replica per value: min (superstep, proc)
    order = np.lexsort((an_p, an_s, an_node))
    lead = np.ones(m, dtype=bool)
    lead[1:] = an_node[order][1:] != an_node[order][:-1]
    src_of = np.full(n, -1, dtype=np.int64)
    ssrc_of = np.full(n, sentinel, dtype=np.int64)
    src_of[an_node[order][lead]] = an_p[order][lead]
    ssrc_of[an_node[order][lead]] = an_s[order][lead]
    keys = np.flatnonzero(first_use != sentinel)  # ascending == sorted (v, p)
    v_k, p_k = keys // P, keys % P
    s_use = first_use[keys]
    need = comp_s[keys] > s_use  # no local compute in time
    v_k, p_k, s_use = v_k[need], p_k[need], s_use[need]
    late = ssrc_of[v_k] >= s_use
    assert not late.any(), \
        f"value {int(v_k[late.argmax()]) if late.any() else -1} " \
        "not producible in time"
    return v_k, src_of[v_k], p_k, s_use - 1


def canonical_comm_plan(dag, assign) -> list[tuple[int, int, int, int]]:
    """The canonical communication set of a compute assignment, as
    ``(value, src, dst, superstep)`` rows sorted by ``(value, dst)``.

    One comm per (value, consuming processor): skipped when the consumer
    computes the value locally in time, sourced at the earliest replica
    (ties to the lowest processor id), placed at the latest valid
    superstep (first use - 1).  Single home of the rule -- both
    ``list_sched.derive_comms`` (live rebuild) and
    ``ScheduleState.from_projection`` (bulk expansion) consume it, so the
    two paths cannot drift.  The body is the vectorized
    ``_canonical_comm_plan_arrays`` (one bincount/sort pass over flat edge
    arrays); ``_canonical_comm_plan_scalar`` pins its output bit-for-bit.
    """
    v, src, dst, t = _canonical_comm_plan_arrays(dag, assign)
    return list(zip(v.tolist(), src.tolist(), dst.tolist(), t.tolist()))


def apply_split_mutations(sched, s: int, late, pre=None) -> bool:
    """Execute the superstep-split mutation sequence on any schedule object
    exposing the primitive-op protocol (engine ``ScheduleState``, reference
    ``Schedule``, or the pricing sim) -- shared so the engine and oracle
    trajectories stay bit-identical, exactly the SM/SR contract.

    The split is the inverse of the SM merge: every compute phase after
    ``s`` shifts one superstep later (opening an empty superstep ``s + 1``),
    the ``late`` pairs -- sorted ``(node, proc)`` compute entries of
    superstep ``s`` -- delay into the new superstep, and the comms of every
    *affected* value (delayed nodes, parents of delayed nodes, and values
    with a comm in phase ``s``) are re-derived canonically per the
    ``derive_comms`` rule.  The re-derivation is the gain mechanism: the
    merged comm phase at ``s`` redistributes between phases ``s`` and
    ``s + 1`` (an h-relation split, trading ``g*h`` against ``L``), while
    delayed values' phase-``s`` comms -- whose source would no longer be
    computed in time -- are re-placed at later, valid phases.  Returns
    False when some affected value cannot reach a consumer in time (the
    candidate is infeasible); the caller prices on a sim or rolls back.

    Determinism contract: supersteps shift in descending order with nodes
    ascending per cell, pre-mutation comms are walked in sorted key order,
    and affected values re-derive ascending -- every consumer (engine
    transaction, oracle copy, pricing sim) sees the identical sequence.
    ``pre`` optionally supplies the sorted pre-mutation comm snapshot so a
    pricing sweep sorts the comm dict once per round, not per candidate.
    """
    dag = sched.inst.dag
    P = sched.inst.P
    if pre is None:
        pre = sorted(sched.comms.items())
    dsts_of: dict[int, list[int]] = {}
    affected = set()
    for (v, dst), (_src, t) in pre:
        dsts_of.setdefault(v, []).append(dst)
        if t == s:
            affected.add(v)
    for (v, _p) in late:
        affected.add(v)
        affected.update(dag.parents[v])
    S0 = sched.S
    bulk = getattr(sched, "shift_tail_bulk", None)
    if bulk is not None:
        bulk(s)  # pricing sim: zero-delta renumbering, no per-node traffic
    else:
        for t in range(S0 - 1, s, -1):
            for p in range(P):
                for v in sorted(sched.comp[t][p]):
                    sched.remove_comp(v, p)
                    sched.add_comp(v, p, t + 1)
        for (v, dst), (_src, t) in pre:
            if t > s:
                sched.move_comm(v, dst, t + 1)
    for (v, p) in late:
        sched.remove_comp(v, p)
        sched.add_comp(v, p, s + 1)
    for u in sorted(affected):
        for dst in dsts_of.get(u, ()):
            sched.remove_comm(u, dst)
        first_use: dict[int, int] = {}
        for c in dag.children[u]:
            for q, t in sched.assign[c].items():
                cur = first_use.get(q)
                if cur is None or t < cur:
                    first_use[q] = t
        av = sched.assign[u]
        for q, s_use in sorted(first_use.items()):
            if av.get(q, INF) <= s_use:
                continue  # locally computed in time
            src, s_src = min(av.items(), key=lambda x: (x[1], x[0]))
            if s_src >= s_use:
                return False
            sched.add_comm(u, src, q, s_use - 1)
    return True


class ScheduleState:
    """Mutable BSP schedule with O(touched-supersteps) incremental costing.

    Structure mirrors the seed ``Schedule``: compute phases ``comp[s][p]``
    (sets of nodes), canonical comms ``(v, dst) -> (src, s)``, the reverse
    ``src_index[(v, src)] -> set of dsts``, and ``assign[v]: {p: s}``.
    ``work``/``sent``/``recv`` are plain S x P list-of-list rows (scalar
    updates dominate; numpy per-element dispatch would, as in the partition
    engine's scalar backend, cost more than it saves).
    """

    def __init__(self, inst, S: int):
        self.inst = inst
        P = inst.P
        self.S = S
        self.comp: list[list[set[int]]] = [[set() for _ in range(P)]
                                           for _ in range(S)]
        # (v, dst) -> (src, superstep)
        self.comms: dict[tuple[int, int], tuple[int, int]] = {}
        # (v, src) -> set of dsts, for O(deg) use queries
        self.src_index: dict[tuple[int, int], set[int]] = defaultdict(set)
        # v -> {p: superstep computed}  (at most one superstep per (v,p))
        self.assign: list[dict[int, int]] = [dict() for _ in range(inst.dag.n)]
        self.work = [[0.0] * P for _ in range(S)]
        self.sent = [[0.0] * P for _ in range(S)]
        self.recv = [[0.0] * P for _ in range(S)]
        self._wtop = [[0.0, 0, 0.0] for _ in range(S)]
        self._stop = [[0.0, 0, 0.0] for _ in range(S)]
        self._rtop = [[0.0, 0, 0.0] for _ in range(S)]
        self._scost = [0.0] * S
        self._total = 0.0
        # transaction machinery: undo records + open-frame start indices
        self._undo: list = []
        self._frames: list[int] = []
        self._replaying = False
        # values whose comms may have changed needed-status since the last
        # prune_useless_comms (see there); start conservatively dirty
        self._prune_dirty: set[int] = set(range(inst.dag.n))

    # ----------------------------------------------------------- row helpers
    def _rows_top(self, kind: str):
        if kind == "work":
            return self.work, self._wtop
        if kind == "sent":
            return self.sent, self._stop
        return self.recv, self._rtop

    def work_max(self, s: int) -> float:
        return self._wtop[s][0]

    def h_of(self, s: int) -> float:
        return max(self._stop[s][0], self._rtop[s][0])

    def _step_cost(self, w1: float, h: float) -> float:
        if h > EPS:
            return w1 + self.inst.L + self.inst.g * h
        return w1

    def superstep_cost(self, s: int) -> float:
        """Superstep cost recomputed from the raw rows (oracle path)."""
        c = max(self.work[s])
        h = max(max(self.sent[s]), max(self.recv[s]))
        if h > EPS:
            c += self.inst.L + self.inst.g * h
        return c

    def cost(self) -> float:
        """Full-recompute total cost (O(S*P); for tests and assertions)."""
        return sum(self.superstep_cost(s) for s in range(self.S))

    def current_cost(self) -> float:
        """Incrementally maintained total cost (O(1))."""
        return self._total

    # ------------------------------------------------------------- cell edit
    def _cell_add(self, kind: str, s: int, p: int, dv: float,
                  saves: list | None) -> None:
        """row[s][p] += dv, maintaining top-2, step cost and total."""
        rows, tops = self._rows_top(kind)
        row, top = rows[s], tops[s]
        old = row[p]
        if saves is not None:
            saves.append((kind, s, p, old, top.copy(), self._scost[s]))
        new = old + dv
        row[p] = new
        m1, i1, m2 = top
        if p == i1:
            if new >= m2:
                top[0] = new
            else:
                top[:] = _retop(row)
        elif new > m1:
            top[0], top[1], top[2] = new, p, m1
        elif new > m2:
            top[2] = new
        elif new < m2 and old == m2:
            P = self.inst.P
            top[2] = max((row[q] for q in range(P) if q != i1), default=0.0)
        c = self._step_cost(self._wtop[s][0],
                            max(self._stop[s][0], self._rtop[s][0]))
        self._total += c - self._scost[s]
        self._scost[s] = c

    # ------------------------------------------------------------- mutations
    def _grow(self, s: int) -> None:
        P = self.inst.P
        if s >= self.S and self._frames and not self._replaying:
            self._undo.append(("S", self.S, None))
        while s >= self.S:
            self.comp.append([set() for _ in range(P)])
            self.work.append([0.0] * P)
            self.sent.append([0.0] * P)
            self.recv.append([0.0] * P)
            self._wtop.append([0.0, 0, 0.0])
            self._stop.append([0.0, 0, 0.0])
            self._rtop.append([0.0, 0, 0.0])
            self._scost.append(0.0)
            self.S += 1

    def _log(self, inverse: tuple) -> list | None:
        """Open an undo record; returns the saves list or None (no frame)."""
        if not self._frames or self._replaying:
            return None
        saves: list = []
        self._undo.append((inverse[0], inverse[1:], saves, self._total))
        return saves

    def _mark_comp_dirty(self, v: int) -> None:
        self._prune_dirty.add(v)
        self._prune_dirty.update(self.inst.dag.parents[v])

    def add_comp(self, v: int, p: int, s: int) -> None:
        self._grow(s)
        assert p not in self.assign[v], f"node {v} already on proc {p}"
        saves = self._log(("-comp", v, p))
        self.comp[s][p].add(v)
        self.assign[v][p] = s
        self._mark_comp_dirty(v)
        self._cell_add("work", s, p, self.inst.dag.omega[v], saves)

    def remove_comp(self, v: int, p: int) -> None:
        s = self.assign[v].pop(p)
        saves = self._log(("+comp", v, p, s))
        self.comp[s][p].discard(v)
        self._mark_comp_dirty(v)
        self._cell_add("work", s, p, -self.inst.dag.omega[v], saves)

    def add_comm(self, v: int, src: int, dst: int, s: int) -> None:
        self._grow(s)
        assert (v, dst) not in self.comms
        saves = self._log(("-comm", v, dst))
        self.comms[(v, dst)] = (src, s)
        self.src_index[(v, src)].add(dst)
        self._prune_dirty.add(v)
        mu = self.inst.dag.mu[v]
        self._cell_add("sent", s, src, mu, saves)
        self._cell_add("recv", s, dst, mu, saves)

    def remove_comm(self, v: int, dst: int) -> None:
        src, s = self.comms.pop((v, dst))
        saves = self._log(("+comm", v, src, dst, s))
        self.src_index[(v, src)].discard(dst)
        self._prune_dirty.add(v)
        mu = self.inst.dag.mu[v]
        self._cell_add("sent", s, src, -mu, saves)
        self._cell_add("recv", s, dst, -mu, saves)

    def move_comm(self, v: int, dst: int, new_s: int) -> None:
        src, _ = self.comms[(v, dst)]
        self.remove_comm(v, dst)
        self.add_comm(v, src, dst, new_s)

    # ----------------------------------------------------------- transactions
    def begin(self) -> None:
        """Open a transaction frame; mutations log undo records until the
        matching ``commit`` (keep) or ``rollback`` (revert)."""
        self._frames.append(len(self._undo))

    def commit(self) -> None:
        """Accept the innermost frame.  Records fold into the enclosing
        frame (if any) so an outer rollback still reverts them."""
        start = self._frames.pop()
        if not self._frames:
            del self._undo[start:]

    def rollback(self) -> None:
        """Revert every mutation of the innermost frame, exactly."""
        start = self._frames.pop()
        records = self._undo[start:]
        del self._undo[start:]
        self._replaying = True
        try:
            for rec in reversed(records):
                tag = rec[0]
                if tag == "S":
                    old_S = rec[1]
                    del self.comp[old_S:]
                    del self.work[old_S:]
                    del self.sent[old_S:]
                    del self.recv[old_S:]
                    del self._wtop[old_S:]
                    del self._stop[old_S:]
                    del self._rtop[old_S:]
                    del self._scost[old_S:]
                    self.S = old_S
                    continue
                _, args, saves, total_before = rec
                # structural inverse
                if tag == "-comp":
                    v, p = args
                    s = self.assign[v].pop(p)
                    self.comp[s][p].discard(v)
                    self._mark_comp_dirty(v)
                elif tag == "+comp":
                    v, p, s = args
                    self.comp[s][p].add(v)
                    self.assign[v][p] = s
                    self._mark_comp_dirty(v)
                elif tag == "-comm":
                    v, dst = args
                    src, _ = self.comms.pop((v, dst))
                    self.src_index[(v, src)].discard(dst)
                    self._prune_dirty.add(v)
                elif tag == "+comm":
                    v, src, dst, s = args
                    self.comms[(v, dst)] = (src, s)
                    self.src_index[(v, src)].add(dst)
                    self._prune_dirty.add(v)
                # numeric restore: overwrite with the saved values
                for kind, s, p, old, top, scost in reversed(saves):
                    rows, tops = self._rows_top(kind)
                    rows[s][p] = old
                    tops[s][:] = top
                    self._scost[s] = scost
                self._total = total_before
        finally:
            self._replaying = False

    @property
    def depth(self) -> int:
        """Number of open transaction frames."""
        return len(self._frames)

    # ------------------------------------------------------------- presence
    def compute_sstep(self, v: int, p: int) -> float:
        return self.assign[v].get(p, INF)

    def recv_sstep(self, v: int, p: int) -> float:
        c = self.comms.get((v, p))
        return c[1] if c is not None else INF

    def present_at(self, v: int, p: int, s: int) -> bool:
        """Usable on p in superstep s (for compute or as a send source)."""
        return self.compute_sstep(v, p) <= s or self.recv_sstep(v, p) < s

    # ------------------------------------------------------ use / windows
    def uses_on(self, v: int, p: int) -> list[int]:
        """Supersteps where v's value is consumed on p (compute or send)."""
        out = []
        for c in self.inst.dag.children[v]:
            s = self.assign[c].get(p)
            if s is not None:
                out.append(s)
        for dst in self.src_index.get((v, p), ()):
            out.append(self.comms[(v, dst)][1])
        return sorted(out)

    def has_use_on(self, v: int, p: int) -> bool:
        """O(deg) short-circuit version of ``bool(uses_on(v, p))``."""
        for c in self.inst.dag.children[v]:
            if p in self.assign[c]:
                return True
        return bool(self.src_index.get((v, p)))

    def first_use_on(self, v: int, p: int) -> float:
        u = self.uses_on(v, p)
        return u[0] if u else INF

    def earliest_replication(self, v: int, p: int) -> float:
        """First superstep where all parents of v are present on p."""
        e = 0
        for u in self.inst.dag.parents[v]:
            cs = self.compute_sstep(u, p)
            rs = self.recv_sstep(u, p)
            e = max(e, min(cs, rs + 1))
        return e

    # ----------------------------------------------------------- delta pricing
    def _delta_cells(self, cells) -> float:
        """Exact total-cost change of applying ``cells`` — an iterable of
        ``(kind, s, p, dv)`` — without mutating anything.  O(touched
        supersteps), O(1) per superstep unless several cells hit the same
        row (then one O(P) scan).  Per-superstep deltas are summed in
        ascending superstep order, so batched pricers (the frontier layer)
        can reproduce the result bit-for-bit."""
        by_s: dict[int, dict[str, dict[int, float]]] = {}
        for kind, s, p, dv in cells:
            d = by_s.setdefault(s, {}).setdefault(kind, {})
            d[p] = d.get(p, 0.0) + dv
        delta = 0.0
        for s in sorted(by_s):
            kinds = by_s[s]
            if s < self.S:
                w1 = self._max_with("work", s, kinds.get("work"))
                s1 = self._max_with("sent", s, kinds.get("sent"))
                r1 = self._max_with("recv", s, kinds.get("recv"))
                delta += self._step_cost(w1, max(s1, r1)) - self._scost[s]
            else:  # beyond current horizon: all-zero virtual rows
                w1 = max(0.0, max(kinds.get("work", {}).values(),
                                  default=0.0))
                h = max(max(kinds.get("sent", {}).values(), default=0.0),
                        max(kinds.get("recv", {}).values(), default=0.0),
                        0.0)
                delta += self._step_cost(w1, h)
        return delta

    def _max_with(self, kind: str, s: int, dvs: dict[int, float] | None):
        """Row max of ``kind`` at s if each p in dvs changed by dvs[p]."""
        rows, tops = self._rows_top(kind)
        top = tops[s]
        if not dvs:
            return top[0]
        row = rows[s]
        if len(dvs) == 1:
            (p, dv), = dvs.items()
            new = row[p] + dv
            return max(top[2], new) if p == top[1] else max(top[0], new)
        return max(row[q] + dvs.get(q, 0.0) for q in range(self.inst.P))

    def _kind_max_if(self, kind: str, s: int, p: int, dv: float) -> float:
        """Row max of ``kind`` at s if entry p changed by dv (O(1))."""
        rows, tops = self._rows_top(kind)
        top = tops[s]
        new = rows[s][p] + dv
        return max(top[2], new) if p == top[1] else max(top[0], new)

    def _comm_step_delta(self, s: int, src: int, dst: int, mu: float) -> float:
        """Step-cost change at s if sent[src] and recv[dst] change by mu."""
        s1 = self._kind_max_if("sent", s, src, mu)
        r1 = self._kind_max_if("recv", s, dst, mu)
        return self._step_cost(self._wtop[s][0], max(s1, r1)) - self._scost[s]

    def delta_add_comp(self, v: int, p: int, s: int) -> float:
        if s >= self.S:
            return self._step_cost(self.inst.dag.omega[v], 0.0)
        w1 = self._kind_max_if("work", s, p, self.inst.dag.omega[v])
        return self._step_cost(w1, self.h_of(s)) - self._scost[s]

    def delta_remove_comp(self, v: int, p: int) -> float:
        s = self.assign[v][p]
        w1 = self._kind_max_if("work", s, p, -self.inst.dag.omega[v])
        return self._step_cost(w1, self.h_of(s)) - self._scost[s]

    def delta_add_comm(self, v: int, src: int, dst: int, s: int) -> float:
        mu = self.inst.dag.mu[v]
        if s >= self.S:
            return self._step_cost(0.0, mu)
        return self._comm_step_delta(s, src, dst, mu)

    def delta_remove_comm(self, v: int, dst: int) -> float:
        src, s = self.comms[(v, dst)]
        return self._comm_step_delta(s, src, dst, -self.inst.dag.mu[v])

    def delta_move_comm(self, v: int, dst: int, new_s: int) -> float:
        src, s = self.comms[(v, dst)]
        if new_s == s:
            return 0.0
        mu = self.inst.dag.mu[v]
        d = self._comm_step_delta(s, src, dst, -mu)
        if new_s >= self.S:
            return d + self._step_cost(0.0, mu)
        return d + self._comm_step_delta(new_s, src, dst, mu)

    def delta_replicate_for_comm(self, v: int, dst: int, t: int) -> float:
        """Composite basic move: drop comm (v -> dst), compute v on dst at
        superstep t instead."""
        src, s = self.comms[(v, dst)]
        mu = self.inst.dag.mu[v]
        om = self.inst.dag.omega[v]
        if s == t:  # both phases of the same superstep change
            return self._delta_cells([("sent", s, src, -mu),
                                      ("recv", s, dst, -mu),
                                      ("work", t, dst, om)])
        d = self._comm_step_delta(s, src, dst, -mu)
        if t >= self.S:
            return d + self._step_cost(om, 0.0)
        w1 = self._kind_max_if("work", t, dst, om)
        return d + self._step_cost(w1, self.h_of(t)) - self._scost[t]

    def _node_move_cells(self, v: int, q: int):
        """Cell changes of moving single-assigned node v to processor q in
        the same superstep, mirroring the hill-climbing move: outgoing comms
        retarget src p -> q (the one to q itself is dropped), an incoming
        comm to q is dropped, and consumers left on p get one comm q -> p
        before their first use.  Feasibility is the caller's concern."""
        (p, s), = self.assign[v].items()
        dag = self.inst.dag
        mu, om = dag.mu[v], dag.omega[v]
        cells = []
        for dst in sorted(self.src_index.get((v, p), ())):
            _, t = self.comms[(v, dst)]
            cells.append(("sent", t, p, -mu))
            if dst == q:
                cells.append(("recv", t, q, -mu))
            else:
                cells.append(("sent", t, q, mu))
        c0 = self.comms.get((v, q))
        if c0 is not None and c0[0] != p:
            src0, t0 = c0
            cells += [("sent", t0, src0, -mu), ("recv", t0, q, -mu)]
        cells += [("work", s, p, -om), ("work", s, q, om)]
        uses_p = self.uses_on(v, p)
        if uses_p:
            tf = min(uses_p) - 1
            cells += [("sent", tf, q, mu), ("recv", tf, p, mu)]
        return cells

    def delta_node_move(self, v: int, q: int) -> float:
        """Price the compound node move v -> q (pure, O(out-comms + deg))."""
        return self._delta_cells(self._node_move_cells(v, q))

    def apply_node_move(self, v: int, q: int) -> None:
        """Execute the node move priced by ``delta_node_move``."""
        (p, s), = self.assign[v].items()
        uses_p = self.uses_on(v, p)
        for dst in sorted(self.src_index.get((v, p), ())):
            _, t = self.comms[(v, dst)]
            self.remove_comm(v, dst)
            if dst != q:
                self.add_comm(v, q, dst, t)
        if (v, q) in self.comms:
            self.remove_comm(v, q)
        self.remove_comp(v, p)
        self.add_comp(v, q, s)
        if uses_p:
            self.add_comm(v, q, p, min(uses_p) - 1)

    # -------------------------------------------------------------- cleanup
    def prune_useless_comms(self) -> int:
        """Drop comms whose value is never used on the destination after
        arrival (can appear after replication rewrites).

        Incremental: a comm (v, dst)'s needed-status depends only on its own
        placement, v's local compute on dst, v's children's assignments and
        v's onward sends -- every mutation marks the affected value dirty
        (``_prune_dirty``), so only comms of dirty values are re-examined.
        Comms of clean values were needed at the previous prune and their
        status cannot have changed, making this exactly equivalent to (and
        interchangeable with) the reference full scan."""
        drop = []
        dirty = self._prune_dirty
        children = self.inst.dag.children
        assign = self.assign
        comms = self.comms
        src_index = self.src_index
        for (v, dst), (src, s) in comms.items():
            if v not in dirty:
                continue
            cs = assign[v].get(dst)
            # a use at superstep t is satisfied by this comm iff s < t, and
            # does not need it at all when covered by local compute (cs <= t)
            needed = False
            if cs is None:
                for c in children[v]:
                    t = assign[c].get(dst)
                    if t is not None and t > s:
                        needed = True
                        break
                if not needed:
                    for dd in src_index.get((v, dst), ()):
                        if comms[(v, dd)][1] > s:
                            needed = True
                            break
            else:
                for c in children[v]:
                    t = assign[c].get(dst)
                    if t is not None and t > s and cs > t:
                        needed = True
                        break
                if not needed:
                    for dd in src_index.get((v, dst), ()):
                        t = comms[(v, dd)][1]
                        if t > s and cs > t:
                            needed = True
                            break
            if not needed:
                drop.append((v, dst))
        dirty.clear()
        for key in drop:
            self.remove_comm(*key)
        return len(drop)

    def compact(self) -> None:
        """Remove empty supersteps (no compute and no comm anywhere).

        Renumbers through ``comp`` membership -- O(nodes in shifted
        supersteps + comms) -- instead of rebuilding every assign dict.
        Must not run inside an open transaction."""
        assert not self._frames, "compact inside an open transaction"
        P = self.inst.P
        keep = [s for s in range(self.S)
                if any(self.work[s]) or any(self.sent[s]) or any(self.recv[s])
                or any(self.comp[s][p] for p in range(P))]
        if len(keep) == self.S:
            return
        remap = {old: new for new, old in enumerate(keep)}
        for old_s in keep:
            new_s = remap[old_s]
            if new_s == old_s:
                continue
            for p in range(P):
                for v in self.comp[old_s][p]:
                    self.assign[v][p] = new_s
        self.comp = [self.comp[s] for s in keep]
        self.work = [self.work[s] for s in keep]
        self.sent = [self.sent[s] for s in keep]
        self.recv = [self.recv[s] for s in keep]
        self._wtop = [self._wtop[s] for s in keep]
        self._stop = [self._stop[s] for s in keep]
        self._rtop = [self._rtop[s] for s in keep]
        self._scost = [self._scost[s] for s in keep]
        self.S = len(keep)
        self._total = sum(self._scost)
        self.comms = {k: (src, remap[s])
                      for k, (src, s) in self.comms.items()}

    # ------------------------------------------------------------ projection
    @classmethod
    def from_projection(cls, inst, coarse: "ScheduleState",
                        cmap) -> "ScheduleState":
        """Expand a coarse schedule onto the fine DAG (multilevel V-cycle).

        ``cmap[v]`` is the coarse cluster of fine node v.  Every member of
        a cluster inherits every coarse ``(processor, superstep)``
        assignment of that cluster -- replica sets project member-wise --
        and communications are re-derived **canonically** from the expanded
        assignment (one comm per (value, consuming processor), sourced at
        the earliest replica, placed at the latest valid superstep: the
        same rule as ``list_sched.derive_comms``).  Coarse comms are *not*
        projected: one coarse comm stands for one comm per boundary member
        at the fine level, so re-derivation is the only canonical choice.

        The load rows are rebuilt in one vectorized pass (``np.bincount``
        per kind) whose accumulation order matches a from-scratch
        primitive-op build (ascending node id, then sorted assignments,
        then sorted comm keys) cell for cell, so rows, step costs, total
        and comms are **bit-identical** to one -- property-tested by
        ``tests/test_schedule_multilevel.py``.  (The top-2 argmax may pick
        a different processor among *tied* maxima than the incremental
        maintenance would; any tied index is a valid triple, and when two
        choices exist the runner-up equals the maximum, so every delta
        prices identically either way.)  Validity of the coarse
        schedule implies validity of the expansion: cluster-internal
        dependencies land in the same compute phase on the same processor,
        cross-cluster dependencies inherit the coarse presence guarantees.
        """
        import numpy as np

        cmap = np.asarray(cmap, dtype=np.int64)
        dag, P = inst.dag, inst.P
        if cmap.shape != (dag.n,):
            raise ValueError("cmap must have shape (n,)")
        assert coarse.inst.P == P, "fine and coarse instances disagree on P"
        sched = cls(inst, coarse.S)
        # per-cluster assignment lists, sorted once (deterministic order),
        # flattened so the member-wise expansion is one vectorized gather
        # (ascending node id, then sorted (p, s) -- the exact input order
        # the bincounts below need for bit-identity with a primitive build)
        cl_items = [sorted(a.items()) for a in coarse.assign]
        k_arr = np.fromiter((len(ci) for ci in cl_items), dtype=np.int64,
                            count=len(cl_items))
        cl_off = np.zeros(len(cl_items) + 1, dtype=np.int64)
        np.cumsum(k_arr, out=cl_off[1:])
        cl_p = np.fromiter((p for ci in cl_items for p, _ in ci),
                           dtype=np.int64, count=int(cl_off[-1]))
        cl_s = np.fromiter((s for ci in cl_items for _, s in ci),
                           dtype=np.int64, count=int(cl_off[-1]))
        counts = k_arr[cmap]
        node_rep = np.repeat(np.arange(dag.n, dtype=np.int64), counts)
        cum = np.zeros(dag.n + 1, dtype=np.int64)
        np.cumsum(counts, out=cum[1:])
        pos = cl_off[cmap[node_rep]] \
            + np.arange(len(node_rep), dtype=np.int64) - cum[node_rep]
        p_arr, s_arr = cl_p[pos], cl_s[pos]
        assign, comp = sched.assign, sched.comp
        for v, p, s in zip(node_rep.tolist(), p_arr.tolist(),
                           s_arr.tolist()):
            assign[v][p] = s
            comp[s][p].add(v)
        idx_w = s_arr * P + p_arr
        comms, src_index = sched.comms, sched.src_index
        c_v, c_src, c_dst, c_t = _canonical_comm_plan_arrays(dag, assign)
        for v, src, p, t in zip(c_v.tolist(), c_src.tolist(),
                                c_dst.tolist(), c_t.tolist()):
            comms[(v, p)] = (src, t)
            src_index[(v, src)].add(p)
        idx_s = c_t * P + c_src
        idx_r = c_t * P + c_dst
        # bulk row rebuild: bincount accumulates in input order, which is
        # exactly the sequential add_comp/add_comm order above
        cells = coarse.S * P
        work = np.bincount(idx_w, weights=dag.omega[node_rep],
                           minlength=cells)
        mu_c = dag.mu[c_v]
        sent = np.bincount(idx_s, weights=mu_c, minlength=cells)
        recv = np.bincount(idx_r, weights=mu_c, minlength=cells)
        sched.work = work.reshape(coarse.S, P).tolist()
        sched.sent = sent.reshape(coarse.S, P).tolist()
        sched.recv = recv.reshape(coarse.S, P).tolist()
        sched._wtop = [_retop(r) for r in sched.work]
        sched._stop = [_retop(r) for r in sched.sent]
        sched._rtop = [_retop(r) for r in sched.recv]
        sched._scost = [sched._step_cost(sched._wtop[s][0],
                                         max(sched._stop[s][0],
                                             sched._rtop[s][0]))
                        for s in range(sched.S)]
        sched._total = sum(sched._scost)
        return sched

    def copy(self):
        """Deep copy (undo log excluded; not allowed mid-transaction)."""
        assert not self._frames, "copy inside an open transaction"
        other = type(self).__new__(type(self))
        other.inst = self.inst
        other.S = self.S
        other.comp = [[set(ps) for ps in row] for row in self.comp]
        other.comms = dict(self.comms)
        other.src_index = defaultdict(set)
        for k, dsts in self.src_index.items():
            if dsts:
                other.src_index[k] = set(dsts)
        other.assign = [dict(a) for a in self.assign]
        other.work = [list(r) for r in self.work]
        other.sent = [list(r) for r in self.sent]
        other.recv = [list(r) for r in self.recv]
        other._wtop = [list(t) for t in self._wtop]
        other._stop = [list(t) for t in self._stop]
        other._rtop = [list(t) for t in self._rtop]
        other._scost = list(self._scost)
        other._total = self._total
        other._undo = []
        other._frames = []
        other._replaying = False
        other._prune_dirty = set(self._prune_dirty)
        return other

    # ------------------------------------------------------------ invariants
    def check(self, require_compact: bool = False) -> None:
        """Assert every derived quantity against a from-scratch rebuild.

        With ``require_compact=True`` additionally assert the no-empty-
        superstep invariant: every superstep holds at least one compute
        entry or comm, so superstep indices cannot drift between the
        engine and the oracle across winner-commit rounds (split/merge
        passes run ``compact()`` after each committed winner)."""
        P = self.inst.P
        dag = self.inst.dag
        work = [[0.0] * P for _ in range(self.S)]
        sent = [[0.0] * P for _ in range(self.S)]
        recv = [[0.0] * P for _ in range(self.S)]
        for v in range(dag.n):
            for p, s in self.assign[v].items():
                work[s][p] += dag.omega[v]
        for (v, dst), (src, s) in self.comms.items():
            sent[s][src] += dag.mu[v]
            recv[s][dst] += dag.mu[v]
        for s in range(self.S):
            for p in range(P):
                assert abs(work[s][p] - self.work[s][p]) < 1e-9, \
                    f"work[{s}][{p}] drifted"
                assert abs(sent[s][p] - self.sent[s][p]) < 1e-9, \
                    f"sent[{s}][{p}] drifted"
                assert abs(recv[s][p] - self.recv[s][p]) < 1e-9, \
                    f"recv[{s}][{p}] drifted"
        for kind in _KINDS:
            rows, tops = self._rows_top(kind)
            for s in range(self.S):
                m1, i1, m2 = tops[s]
                assert m1 == max(rows[s]), f"{kind} top1 drifted at s={s}"
                assert rows[s][i1] == m1, f"{kind} argmax drifted at s={s}"
                want2 = max((rows[s][q] for q in range(P) if q != i1),
                            default=0.0)
                assert m2 == want2, f"{kind} top2 drifted at s={s}"
        for s in range(self.S):
            assert abs(self._scost[s] - self.superstep_cost(s)) < 1e-9, \
                f"step cost drifted at s={s}"
        assert abs(self._total - sum(self._scost)) < 1e-9, "total drifted"
        for (v, dst), (src, s) in self.comms.items():
            assert dst in self.src_index[(v, src)], "src_index drifted"
        for (v, src), dsts in self.src_index.items():
            for dst in dsts:
                assert self.comms.get((v, dst), (None,))[0] == src, \
                    "src_index stale entry"
        if require_compact:
            for s in range(self.S):
                assert any(self.comp[s][p] for p in range(P)) \
                    or any(work[s]) or any(sent[s]) or any(recv[s]), \
                    f"empty superstep {s} survived compact"
