"""Multilevel DAG scheduling (acyclic V-cycle, PR 5 tentpole).

The flat replication stack tops out around n ~ 6000: every heuristic pass
walks all nodes/comms of the full DAG, and the baseline list scheduler
builds one superstep per topological level (depth ~ n/width for the solver
DAGs), so wall-clock grows superlinearly with n.  The paper's headline
scheduling claim -- "a sophisticated heuristic that is also applicable to
much larger workloads" (up to 175k-node DAGs) -- lives exactly in the
regime this module opens: coarse-grained scheduling via **acyclic
clustering**, the approach of Papp et al.'s multi-processor scheduling
line of work.

Pipeline (one V-cycle)::

    coarsen   acyclicity-safe clustering, alternating two vectorized
              rules over the DAG's flat edge arrays:
                * same-level heavy-edge matching -- pair nodes at the
                  same topological level that share a parent (score
                  ``mu[parent]``: co-locating them deduplicates the
                  parent's delivery) or a child (score the mean of their
                  own ``mu``); any path strictly increases the level, so
                  clusters of same-level nodes can never close a cycle;
                * funnel clustering -- attach each in-degree-1 node to
                  its unique parent's cluster (clusters grow as
                  unique-parent trees: every external in-edge enters at
                  the root, so a contracted cycle would imply a fine
                  cycle through the root);
              both under a cluster work cap (a fraction of W/P) so the
              coarse compute phases stay balanceable.
    contract  ``Dag.contract``: vectorized cross-edge collapse, boundary
              ``mu`` sums, eager acyclicity validation.
    solve     flat ``best_replicated_schedule`` (baseline list scheduling
              + hill climbing + ``advanced_heuristic``) at the coarsest
              level, where restarts are cheap.
    project   ``Schedule.from_projection``: coarse ``(processor,
              superstep)`` assignments and replica sets expand to cluster
              members, comms re-derived canonically -- bit-identical to a
              from-scratch build of the expanded schedule.
    refine    per refinement stop (every ``refine_every``-th level;
              skipped hops project through composed cluster maps): comm
              rebalancing and node moves priced through the frontier
              layer, then bounded rounds of the advanced heuristic's
              winner-commit SM/BR/SR fronts.

Cost safety: refinement only ever applies strictly improving moves, and at
or below ``coarsest_n`` the driver *is* the flat heuristic (exact-equality
fallthrough).  The ``flat_guard_n`` hedge -- run the flat path too and keep
the cheaper schedule -- is retired by default (``flat_guard_n = 0``, PR 9):
with the superstep-split front in per-level refinement the pure V-cycle
matches or beats flat on every benched instance (split widens the basin
the projection lands in; the psdd circuits that used to need the hedge no
longer do), pinned by ``tests/test_schedule_multilevel.py`` and measured
by ``benchmarks/scheduling.py::split_scale``.  Setting ``flat_guard_n``
back to a positive n restores the old cost-not-worse-than-flat hedge at
the old price of one full flat run.

Scale: coarsening's same-level scoring pass shards over node ranges
through PR 7's ``ParallelContext`` (``workers=`` on
``multilevel_schedule``); per-shard pair blocks concatenate to the serial
arrays byte-for-byte, so the matching -- and the whole V-cycle -- is
bit-identical for every worker count.  With the vectorized
``Schedule.from_projection`` rebuilds this takes the cycle to n = 10^6
DAGs end to end (``benchmarks/scheduling.py::split_scale``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..hypergraph import Dag
from .bsp import BspInstance, Schedule
from .list_sched import (comp_rebalance_pass, dag_levels, node_move_pass,
                         rebalance_comms)
from .replication import (AdvancedOptions, advanced_heuristic,
                          best_replicated_schedule, replica_prune_pass)


@dataclasses.dataclass
class MultilevelScheduleOptions:
    """Knobs of the scheduling V-cycle (defaults tuned for sptrsv/psdd)."""

    coarsest_n: int = 1536     # stop coarsening at this many nodes
    max_levels: int = 32       # hard cap on the level stack depth
    stagnation: float = 0.9    # stop when a round shrinks less than this
    cluster_cap_frac: float = 0.01  # max cluster work, fraction of W/P
    max_fanout: int = 16       # larger child/parent groups don't score pairs
    refine_every: int = 2      # refine every k-th level (finest always)
    hc_rounds: int = 3         # rebalance+retime+node-move rounds per stop
    level_rounds: int = 1      # advanced-heuristic rounds per mid level
    final_rounds: int = 4      # advanced-heuristic rounds at the finest
    flat_guard_n: int = 0      # up to here ALSO run the flat path, keep the
    #                            cheaper schedule.  0 (default since the
    #                            split front landed, PR 9) disables the
    #                            hedge -- the pure V-cycle stands on its own
    superstep_splits: bool = True  # superstep-split front in per-level
    #                            refinement (the move that retired the guard)


# --------------------------------------------------------------- coarsening

def _pair_parts(xch: np.ndarray, ch_arr: np.ndarray, xpar: np.ndarray,
                par_arr: np.ndarray, mu: np.ndarray, level: np.ndarray,
                max_fanout: int, lo: int, hi: int) -> tuple:
    """Pair-candidate blocks for group-owner nodes in ``[lo, hi)``.

    One vectorized pass over the flat CSR group arrays: all ordered pairs
    within each owner's child group (weighted by the owner's ``mu``) and
    within each owner's parent group (weighted by the pair's mean ``mu``),
    kept only when distinct and on the same level.  Returns the six
    arrays ``(cv, cu, cw, pv, pu, pw)`` -- child-group then parent-group
    ``(v, u, weight)`` blocks.

    Bit-identity contract (what lets ``parallel_pair_parts`` shard this):
    restricting ``[lo, hi)`` restricts *owners* only, and owners are
    visited in ascending id order, so concatenating shard blocks in shard
    order -- all child blocks first, then all parent blocks, exactly the
    serial append order -- reproduces the full ``(0, n)`` arrays
    byte-for-byte.  Takes raw arrays (not a ``Dag``) so pool workers can
    call it on shared-memory attaches.
    """
    out = []
    for xg, arr, per_group_mu in ((xch, ch_arr, True),
                                  (xpar, par_arr, False)):
        lens = np.diff(xg)
        sel = np.flatnonzero((lens >= 2) & (lens <= max_fanout))
        sel = sel[(sel >= lo) & (sel < hi)]
        if not len(sel):
            z = np.zeros(0, dtype=np.int64)
            out += [z, z, np.zeros(0)]
            continue
        L = lens[sel]
        L2 = L * L
        rep = np.repeat(sel, L2)
        offs = np.arange(int(L2.sum()), dtype=np.int64)
        offs -= np.repeat(np.cumsum(L2) - L2, L2)
        Lr = np.repeat(L, L2)
        base = xg[rep]
        a = arr[base + offs // Lr]
        b = arr[base + offs % Lr]
        w = (np.repeat(mu[sel], L2) if per_group_mu
             else 0.5 * (mu[a] + mu[b]))
        keep = (a != b) & (level[a] == level[b])
        out += [a[keep], b[keep], w[keep]]
    return tuple(out)


def same_level_matching(dag: Dag, level: np.ndarray, max_weight: float,
                        rng: np.random.Generator, max_fanout: int = 16,
                        ctx=None) -> tuple[np.ndarray, int]:
    """Cluster map from heavy-edge matching of same-topological-level nodes.

    Pair candidates are generated in one vectorized pass over the edge
    arrays (``_pair_parts``): all ordered pairs within each node's child
    group (scored by the shared parent's ``mu`` -- a merged pair needs the
    parent's value delivered once, not twice) and within each node's
    parent group (scored by the mean of the pair's own ``mu`` -- a merged
    pair keeps the shared consumer local to both), restricted to pairs on
    the *same* level.  Groups larger than ``max_fanout`` are skipped (hub
    nodes would expand quadratically and their pairs are weak signals
    anyway).  Every node's best partner (max score, ties to the smallest
    id) feeds a greedy sweep in random order pairing mutually free nodes
    under ``max_weight``.

    ``ctx`` (a ``partition.parallel.ParallelContext``) shards the pair
    generation over node ranges; the per-shard blocks concatenate to the
    serial arrays byte-for-byte (see ``_pair_parts``), so the returned
    ``cmap`` is bit-identical for every worker count.  The greedy sweep
    itself stays serial (it is a sequential dependence chain).

    Acyclicity: any directed path strictly increases the topological
    level, so there is never a path between two same-level nodes, and a
    cycle through the contracted graph would have to visit some cluster's
    level twice -- impossible when every edge strictly increases it.
    Returns ``(cmap, nc)``; stagnation (no pairs) returns the identity.
    """
    n = dag.n
    src, dst = dag.edge_src, dag.edge_dst
    xch = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(src, minlength=n), out=xch[1:])
    mu = np.asarray(dag.mu, dtype=np.float64)
    blocks = None
    if (ctx is not None and not ctx.failed and ctx.workers > 1
            and n >= ctx.min_nodes):
        from ..partition.parallel import parallel_pair_parts
        try:
            blocks = parallel_pair_parts(dag, xch, level, ctx, max_fanout)
        except Exception:
            ctx.failed = True
            blocks = None
    if blocks is None:
        blocks = [_pair_parts(xch, dst, dag.xpar, dag.par_arr, mu, level,
                              max_fanout, 0, n)]
    # serial append order: every child block, then every parent block
    v = np.concatenate([b[0] for b in blocks] + [b[3] for b in blocks])
    u = np.concatenate([b[1] for b in blocks] + [b[4] for b in blocks])
    w = np.concatenate([b[2] for b in blocks] + [b[5] for b in blocks])
    pref = np.full(n, -1, dtype=np.int64)
    if len(v):
        key = v * n + u
        order = np.argsort(key, kind="stable")
        key, w = key[order], w[order]
        first = np.ones(len(key), dtype=bool)
        first[1:] = key[1:] != key[:-1]
        starts = np.flatnonzero(first)
        score = np.add.reduceat(w, starts)
        vd, ud = key[starts] // n, key[starts] % n
        order2 = np.lexsort((ud, -score, vd))
        vd2 = vd[order2]
        lead = np.ones(len(vd2), dtype=bool)
        lead[1:] = vd2[1:] != vd2[:-1]
        pref[vd2[lead]] = ud[order2][lead]
    omega = dag.omega
    match = np.full(n, -1, dtype=np.int64)
    for v in rng.permutation(n):
        u = pref[v]
        if match[v] >= 0 or u < 0 or match[u] >= 0:
            continue
        if omega[v] + omega[u] > max_weight:
            continue
        match[v] = u
        match[u] = v
    partner = np.where(match >= 0, match, np.arange(n, dtype=np.int64))
    rep_id = np.minimum(np.arange(n, dtype=np.int64), partner)
    reps = np.unique(rep_id)
    return np.searchsorted(reps, rep_id), len(reps)


def funnel_clustering(dag: Dag, max_weight: float) -> tuple[np.ndarray, int]:
    """Cluster map attaching in-degree-1 nodes to their unique parent.

    Clusters grow as *unique-parent trees*: every attached member's only
    in-edge comes from inside its cluster, so all external in-edges enter
    at the root -- a cycle in the contracted graph would expand to a fine
    path from a tree member back to its own root, i.e. a fine cycle.
    Batch contraction is therefore acyclicity-safe.  Nodes attach in
    topological order (a parent's root is final before its children are
    visited), deterministically, under the ``max_weight`` work cap.

    This is the depth-reducing rule (chains collapse into supernodes,
    mirroring the elimination-tree structure of the sptrsv DAGs); the
    same-level matching above is the width-reducing one.
    """
    n = dag.n
    indeg = np.diff(dag.xpar)
    par0 = np.full(n, -1, dtype=np.int64)
    only = indeg == 1
    par0[only] = dag.par_arr[dag.xpar[:-1][only]]
    root = np.arange(n, dtype=np.int64)
    cw = dag.omega.astype(np.float64).copy()
    omega = dag.omega
    for v in dag.topo_order():
        u = par0[v]
        if u < 0:
            continue
        r = root[u]
        if cw[r] + omega[v] <= max_weight:
            root[v] = r
            cw[r] += omega[v]
    reps = np.unique(root)
    return np.searchsorted(reps, root), len(reps)


def build_levels(dag: Dag, P: int, opts: MultilevelScheduleOptions,
                 rng: np.random.Generator,
                 ctx=None) -> tuple[list[Dag], list[np.ndarray]]:
    """Coarsen until small/stagnant: ``(levels, cmaps)``.

    ``levels[0]`` is the input; ``cmaps[i]`` maps ``levels[i]`` onto
    ``levels[i + 1]``.  Rounds alternate funnel (depth) and same-level
    matching (width); when the preferred rule stagnates the other gets a
    try before the stack is declared final.  ``ctx`` shards the matching
    rule's scoring pass over node ranges (bit-identical result for every
    worker count; serial when ``None``).
    """
    levels, cmaps = [dag], []
    max_w = opts.cluster_cap_frac * float(dag.omega.sum()) / P
    kind = "funnel"
    while levels[-1].n > opts.coarsest_n and len(levels) < opts.max_levels:
        cur = levels[-1]
        cmap = nc = None
        for k in (kind, "level" if kind == "funnel" else "funnel"):
            if k == "funnel":
                cand, nck = funnel_clustering(cur, max_w)
            else:
                lvl = np.asarray(dag_levels(cur), dtype=np.int64)
                cand, nck = same_level_matching(cur, lvl, max_w, rng,
                                                max_fanout=opts.max_fanout,
                                                ctx=ctx)
            if nck < opts.stagnation * cur.n:
                cmap, nc, kind = cand, nck, k
                break
        if cmap is None:
            break
        levels.append(cur.contract(cmap, nc))
        cmaps.append(cmap)
        kind = "level" if kind == "funnel" else "funnel"
    return levels, cmaps


def _compose_cmaps(cmaps: list[np.ndarray], lo: int, hi: int) -> np.ndarray:
    """Cluster map from level ``lo`` straight onto level ``hi`` (lo < hi).

    Composition is exact: expanding through the composed map equals
    expanding level by level (each member inherits its transitive
    cluster's assignments either way), so skipped refinement stops change
    only where refinement runs, never what projection produces.
    """
    cmap = cmaps[lo]
    for li in range(lo + 1, hi):
        cmap = cmaps[li][cmap]
    return cmap


# ------------------------------------------------------------------ V-cycle

def _refinement_schedule(n_levels: int, refine_every: int) -> list[int]:
    """Level indices to refine at (every ``refine_every``-th; finest (0)
    always included)."""
    return sorted({0} | set(range(0, n_levels - 1, max(refine_every, 1))))


def _refine_level(sched: Schedule, finest: bool,
                  opts: MultilevelScheduleOptions, seed: int,
                  adv_opts: AdvancedOptions | None = None) -> Schedule:
    """Refine one projected level in place (never increases the cost).

    Replica pruning first (the projection expands cluster-grain replicas
    to every member; unused ones are pure work), then hill-climbing moves
    (comm rebalancing and compute re-timing through the batched window
    fronts, node moves through ``price_node_moves``), then bounded rounds
    of the advanced replication heuristic (winner-commit SM/BR/SR fronts)
    -- the same machinery the flat stack runs, scoped to the level.
    """
    sched.prune_useless_comms()
    sched.compact()
    replica_prune_pass(sched)
    sched.prune_useless_comms()
    for r in range(opts.hc_rounds):
        improved = rebalance_comms(sched, max_passes=1)
        improved |= comp_rebalance_pass(sched, max_passes=2)
        improved |= node_move_pass(sched, seed=seed + r)
        improved |= replica_prune_pass(sched, max_passes=1)
        if not improved:
            break
    rounds = opts.final_rounds if finest else opts.level_rounds
    if rounds > 0:
        # caller's AdvancedOptions (pass selection, use_fronts) carry
        # through to refinement; the round budget and split toggle are
        # per-level knobs of the V-cycle
        advanced_heuristic(sched, dataclasses.replace(
            adv_opts or AdvancedOptions(), max_rounds=rounds,
            superstep_splitting=opts.superstep_splits))
    else:
        sched.prune_useless_comms()
        sched.compact()
    return sched


def multilevel_schedule(inst: BspInstance,
                        opts: MultilevelScheduleOptions | None = None,
                        adv_opts: AdvancedOptions | None = None,
                        seed: int = 0, baseline: Schedule | None = None,
                        stats: list | None = None,
                        workers: int | None = None) -> Schedule:
    """Replication-aware multilevel scheduling V-cycle.

    Coarsens the DAG acyclically, solves the coarsest instance with the
    flat ``best_replicated_schedule`` (which runs ``advanced_heuristic``
    from both the baseline and the parallel seed), then projects and
    refines level by level.  Reachable via
    ``best_replicated_schedule(..., multilevel=True)``.

    At or below ``coarsest_n`` (or on immediate coarsening stagnation)
    the driver *is* the flat path -- exact-equality fallthrough, pinned
    by tests.  When ``flat_guard_n`` is set positive, up to that size the
    flat path also runs as a hedge and the cheaper schedule wins (see
    module docstring -- the hedge is off by default since PR 9).
    ``workers > 1`` shards coarsening's matching-score pass over a
    shared-memory process pool (bit-identical result; silently serial
    where shm is unavailable).  ``stats`` (optional list) receives one
    row per refinement stop with projected/refined costs, which is how
    the refinement-never-increases property is tested, plus a
    ``flat_guard`` row when the hedge ran.
    """
    opts = opts or MultilevelScheduleOptions()
    dag = inst.dag
    if dag.n <= opts.coarsest_n:
        return best_replicated_schedule(inst, baseline=baseline,
                                        opts=adv_opts, seed=seed)
    rng = np.random.default_rng(seed)
    ctx = None
    if workers is not None and workers > 1:
        from ..partition.parallel import (PARALLEL_MIN_NODES,
                                          ParallelContext, shm_available)
        if dag.n >= PARALLEL_MIN_NODES and shm_available():
            ctx = ParallelContext(workers)
    try:
        levels, cmaps = build_levels(dag, inst.P, opts, rng, ctx=ctx)
    finally:
        if ctx is not None:
            ctx.close()
    if not cmaps:  # immediate stagnation: no coarse level exists
        return best_replicated_schedule(inst, baseline=baseline,
                                        opts=adv_opts, seed=seed)
    coarse_inst = BspInstance(levels[-1], inst.P, inst.g, inst.L)
    # coarse solve: advanced heuristic from the PARALLEL seed only.  The
    # flat best-of would often pick the sequential schedule here -- coarse
    # mu is a boundary *sum*, so coarse comm systematically overprices the
    # fine comm the canonical re-derivation actually pays -- and a
    # single-superstep coarse solution is a basin no refinement move can
    # leave (every move needs a later superstep to deliver into).
    from .list_sched import bspg_schedule, hill_climb

    par = hill_climb(bspg_schedule(coarse_inst, seed=seed), seed=seed)
    sched = advanced_heuristic(par, adv_opts)
    if stats is not None:
        stats.append({"level": len(levels) - 1, "n": levels[-1].n,
                      "S": sched.S,
                      "cost_projected": float(sched.current_cost()),
                      "cost_refined": float(sched.current_cost())})
    prev = len(levels) - 1
    for li in sorted(_refinement_schedule(len(levels), opts.refine_every),
                     reverse=True):
        cmap = _compose_cmaps(cmaps, li, prev)
        li_inst = inst if li == 0 else BspInstance(levels[li], inst.P,
                                                   inst.g, inst.L)
        sched = Schedule.from_projection(li_inst, sched, cmap)
        prev = li
        projected = float(sched.current_cost())
        _refine_level(sched, li == 0, opts, seed + li, adv_opts=adv_opts)
        if stats is not None:
            stats.append({"level": li, "n": levels[li].n, "S": sched.S,
                          "cost_projected": projected,
                          "cost_refined": float(sched.current_cost())})
    if 0 < dag.n <= opts.flat_guard_n:
        # hedge while the flat path is tractable: the V-cycle's reach claim
        # lives beyond this size; below it, basin differences occasionally
        # favor the flat search (e.g. replication-hungry psdd circuits), so
        # run it too and keep the cheaper schedule.  Guarantees
        # cost-not-worse wherever both paths run, at the disclosed price of
        # one flat run.
        flat = best_replicated_schedule(inst, baseline=baseline,
                                        opts=adv_opts, seed=seed)
        if stats is not None:
            stats.append({"flat_guard": True, "n": dag.n,
                          "flat_cost": float(flat.current_cost()),
                          "vcycle_cost": float(sched.current_cost())})
        if flat.current_cost() < sched.current_cost():
            return flat
    return sched
