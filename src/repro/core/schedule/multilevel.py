"""Multilevel DAG scheduling (acyclic V-cycle, PR 5 tentpole).

The flat replication stack tops out around n ~ 6000: every heuristic pass
walks all nodes/comms of the full DAG, and the baseline list scheduler
builds one superstep per topological level (depth ~ n/width for the solver
DAGs), so wall-clock grows superlinearly with n.  The paper's headline
scheduling claim -- "a sophisticated heuristic that is also applicable to
much larger workloads" (up to 175k-node DAGs) -- lives exactly in the
regime this module opens: coarse-grained scheduling via **acyclic
clustering**, the approach of Papp et al.'s multi-processor scheduling
line of work.

Pipeline (one V-cycle)::

    coarsen   acyclicity-safe clustering, alternating two vectorized
              rules over the DAG's flat edge arrays:
                * same-level heavy-edge matching -- pair nodes at the
                  same topological level that share a parent (score
                  ``mu[parent]``: co-locating them deduplicates the
                  parent's delivery) or a child (score the mean of their
                  own ``mu``); any path strictly increases the level, so
                  clusters of same-level nodes can never close a cycle;
                * funnel clustering -- attach each in-degree-1 node to
                  its unique parent's cluster (clusters grow as
                  unique-parent trees: every external in-edge enters at
                  the root, so a contracted cycle would imply a fine
                  cycle through the root);
              both under a cluster work cap (a fraction of W/P) so the
              coarse compute phases stay balanceable.
    contract  ``Dag.contract``: vectorized cross-edge collapse, boundary
              ``mu`` sums, eager acyclicity validation.
    solve     flat ``best_replicated_schedule`` (baseline list scheduling
              + hill climbing + ``advanced_heuristic``) at the coarsest
              level, where restarts are cheap.
    project   ``Schedule.from_projection``: coarse ``(processor,
              superstep)`` assignments and replica sets expand to cluster
              members, comms re-derived canonically -- bit-identical to a
              from-scratch build of the expanded schedule.
    refine    per refinement stop (every ``refine_every``-th level;
              skipped hops project through composed cluster maps): comm
              rebalancing and node moves priced through the frontier
              layer, then bounded rounds of the advanced heuristic's
              winner-commit SM/BR/SR fronts.

Cost safety: refinement only ever applies strictly improving moves, at or
below ``coarsest_n`` the driver *is* the flat heuristic (exact-equality
fallthrough), and up to ``flat_guard_n`` it additionally runs the flat
path and keeps the cheaper schedule -- so the result is never worse than
flat wherever both paths are tractable, by construction.  On sptrsv the
pure V-cycle (guard disabled) beats flat outright; on replication-hungry
psdd circuits the flat search can win its basin, which is exactly what
the guard hedges -- both pinned by
``tests/test_schedule_multilevel.py`` and measured at scale by
``benchmarks/scheduling.py::multilevel_scale``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..hypergraph import Dag
from .bsp import BspInstance, Schedule
from .list_sched import (comp_rebalance_pass, dag_levels, node_move_pass,
                         rebalance_comms)
from .replication import (AdvancedOptions, advanced_heuristic,
                          best_replicated_schedule, replica_prune_pass)


@dataclasses.dataclass
class MultilevelScheduleOptions:
    """Knobs of the scheduling V-cycle (defaults tuned for sptrsv/psdd)."""

    coarsest_n: int = 1536     # stop coarsening at this many nodes
    max_levels: int = 32       # hard cap on the level stack depth
    stagnation: float = 0.9    # stop when a round shrinks less than this
    cluster_cap_frac: float = 0.01  # max cluster work, fraction of W/P
    max_fanout: int = 16       # larger child/parent groups don't score pairs
    refine_every: int = 2      # refine every k-th level (finest always)
    hc_rounds: int = 3         # rebalance+retime+node-move rounds per stop
    level_rounds: int = 1      # advanced-heuristic rounds per mid level
    final_rounds: int = 4      # advanced-heuristic rounds at the finest
    flat_guard_n: int = 8192   # up to here ALSO run the flat path, keep the
    #                            cheaper schedule (cost-not-worse by
    #                            construction wherever both paths are
    #                            tractable; 0 disables the hedge)


# --------------------------------------------------------------- coarsening

def same_level_matching(dag: Dag, level: np.ndarray, max_weight: float,
                        rng: np.random.Generator,
                        max_fanout: int = 16) -> tuple[np.ndarray, int]:
    """Cluster map from heavy-edge matching of same-topological-level nodes.

    Pair candidates are generated in one vectorized pass over the edge
    arrays: all ordered pairs within each node's child group (scored by the
    shared parent's ``mu`` -- a merged pair needs the parent's value
    delivered once, not twice) and within each node's parent group (scored
    by the mean of the pair's own ``mu`` -- a merged pair keeps the shared
    consumer local to both), restricted to pairs on the *same* level.
    Groups larger than ``max_fanout`` are skipped (hub nodes would expand
    quadratically and their pairs are weak signals anyway).  Every node's
    best partner (max score, ties to the smallest id) feeds a greedy sweep
    in random order pairing mutually free nodes under ``max_weight``.

    Acyclicity: any directed path strictly increases the topological
    level, so there is never a path between two same-level nodes, and a
    cycle through the contracted graph would have to visit some cluster's
    level twice -- impossible when every edge strictly increases it.
    Returns ``(cmap, nc)``; stagnation (no pairs) returns the identity.
    """
    n = dag.n
    src, dst = dag.edge_src, dag.edge_dst
    xch = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(src, minlength=n), out=xch[1:])
    parts_v, parts_u, parts_w = [], [], []
    for xg, arr, per_group_mu in ((xch, dst, True),
                                  (dag.xpar, dag.par_arr, False)):
        lens = np.diff(xg)
        sel = np.flatnonzero((lens >= 2) & (lens <= max_fanout))
        if not len(sel):
            continue
        L = lens[sel]
        L2 = L * L
        rep = np.repeat(sel, L2)
        offs = np.arange(int(L2.sum()), dtype=np.int64)
        offs -= np.repeat(np.cumsum(L2) - L2, L2)
        Lr = np.repeat(L, L2)
        base = xg[rep]
        a = arr[base + offs // Lr]
        b = arr[base + offs % Lr]
        w = (np.repeat(dag.mu[sel], L2) if per_group_mu
             else 0.5 * (dag.mu[a] + dag.mu[b]))
        keep = (a != b) & (level[a] == level[b])
        parts_v.append(a[keep])
        parts_u.append(b[keep])
        parts_w.append(w[keep])
    pref = np.full(n, -1, dtype=np.int64)
    if parts_v:
        v = np.concatenate(parts_v)
        u = np.concatenate(parts_u)
        w = np.concatenate(parts_w)
        if len(v):
            key = v * n + u
            order = np.argsort(key, kind="stable")
            key, w = key[order], w[order]
            first = np.ones(len(key), dtype=bool)
            first[1:] = key[1:] != key[:-1]
            starts = np.flatnonzero(first)
            score = np.add.reduceat(w, starts)
            vd, ud = key[starts] // n, key[starts] % n
            order2 = np.lexsort((ud, -score, vd))
            vd2 = vd[order2]
            lead = np.ones(len(vd2), dtype=bool)
            lead[1:] = vd2[1:] != vd2[:-1]
            pref[vd2[lead]] = ud[order2][lead]
    omega = dag.omega
    match = np.full(n, -1, dtype=np.int64)
    for v in rng.permutation(n):
        u = pref[v]
        if match[v] >= 0 or u < 0 or match[u] >= 0:
            continue
        if omega[v] + omega[u] > max_weight:
            continue
        match[v] = u
        match[u] = v
    partner = np.where(match >= 0, match, np.arange(n, dtype=np.int64))
    rep_id = np.minimum(np.arange(n, dtype=np.int64), partner)
    reps = np.unique(rep_id)
    return np.searchsorted(reps, rep_id), len(reps)


def funnel_clustering(dag: Dag, max_weight: float) -> tuple[np.ndarray, int]:
    """Cluster map attaching in-degree-1 nodes to their unique parent.

    Clusters grow as *unique-parent trees*: every attached member's only
    in-edge comes from inside its cluster, so all external in-edges enter
    at the root -- a cycle in the contracted graph would expand to a fine
    path from a tree member back to its own root, i.e. a fine cycle.
    Batch contraction is therefore acyclicity-safe.  Nodes attach in
    topological order (a parent's root is final before its children are
    visited), deterministically, under the ``max_weight`` work cap.

    This is the depth-reducing rule (chains collapse into supernodes,
    mirroring the elimination-tree structure of the sptrsv DAGs); the
    same-level matching above is the width-reducing one.
    """
    n = dag.n
    indeg = np.diff(dag.xpar)
    par0 = np.full(n, -1, dtype=np.int64)
    only = indeg == 1
    par0[only] = dag.par_arr[dag.xpar[:-1][only]]
    root = np.arange(n, dtype=np.int64)
    cw = dag.omega.astype(np.float64).copy()
    omega = dag.omega
    for v in dag.topo_order():
        u = par0[v]
        if u < 0:
            continue
        r = root[u]
        if cw[r] + omega[v] <= max_weight:
            root[v] = r
            cw[r] += omega[v]
    reps = np.unique(root)
    return np.searchsorted(reps, root), len(reps)


def build_levels(dag: Dag, P: int, opts: MultilevelScheduleOptions,
                 rng: np.random.Generator) -> tuple[list[Dag],
                                                    list[np.ndarray]]:
    """Coarsen until small/stagnant: ``(levels, cmaps)``.

    ``levels[0]`` is the input; ``cmaps[i]`` maps ``levels[i]`` onto
    ``levels[i + 1]``.  Rounds alternate funnel (depth) and same-level
    matching (width); when the preferred rule stagnates the other gets a
    try before the stack is declared final.
    """
    levels, cmaps = [dag], []
    max_w = opts.cluster_cap_frac * float(dag.omega.sum()) / P
    kind = "funnel"
    while levels[-1].n > opts.coarsest_n and len(levels) < opts.max_levels:
        cur = levels[-1]
        cmap = nc = None
        for k in (kind, "level" if kind == "funnel" else "funnel"):
            if k == "funnel":
                cand, nck = funnel_clustering(cur, max_w)
            else:
                lvl = np.asarray(dag_levels(cur), dtype=np.int64)
                cand, nck = same_level_matching(cur, lvl, max_w, rng,
                                                max_fanout=opts.max_fanout)
            if nck < opts.stagnation * cur.n:
                cmap, nc, kind = cand, nck, k
                break
        if cmap is None:
            break
        levels.append(cur.contract(cmap, nc))
        cmaps.append(cmap)
        kind = "level" if kind == "funnel" else "funnel"
    return levels, cmaps


def _compose_cmaps(cmaps: list[np.ndarray], lo: int, hi: int) -> np.ndarray:
    """Cluster map from level ``lo`` straight onto level ``hi`` (lo < hi).

    Composition is exact: expanding through the composed map equals
    expanding level by level (each member inherits its transitive
    cluster's assignments either way), so skipped refinement stops change
    only where refinement runs, never what projection produces.
    """
    cmap = cmaps[lo]
    for li in range(lo + 1, hi):
        cmap = cmaps[li][cmap]
    return cmap


# ------------------------------------------------------------------ V-cycle

def _refinement_schedule(n_levels: int, refine_every: int) -> list[int]:
    """Level indices to refine at (every ``refine_every``-th; finest (0)
    always included)."""
    return sorted({0} | set(range(0, n_levels - 1, max(refine_every, 1))))


def _refine_level(sched: Schedule, finest: bool,
                  opts: MultilevelScheduleOptions, seed: int,
                  adv_opts: AdvancedOptions | None = None) -> Schedule:
    """Refine one projected level in place (never increases the cost).

    Replica pruning first (the projection expands cluster-grain replicas
    to every member; unused ones are pure work), then hill-climbing moves
    (comm rebalancing and compute re-timing through the batched window
    fronts, node moves through ``price_node_moves``), then bounded rounds
    of the advanced replication heuristic (winner-commit SM/BR/SR fronts)
    -- the same machinery the flat stack runs, scoped to the level.
    """
    sched.prune_useless_comms()
    sched.compact()
    replica_prune_pass(sched)
    sched.prune_useless_comms()
    for r in range(opts.hc_rounds):
        improved = rebalance_comms(sched, max_passes=1)
        improved |= comp_rebalance_pass(sched, max_passes=2)
        improved |= node_move_pass(sched, seed=seed + r)
        improved |= replica_prune_pass(sched, max_passes=1)
        if not improved:
            break
    rounds = opts.final_rounds if finest else opts.level_rounds
    if rounds > 0:
        # caller's AdvancedOptions (pass selection, use_fronts) carry
        # through to refinement; only the round budget is per-level
        advanced_heuristic(sched, dataclasses.replace(
            adv_opts or AdvancedOptions(), max_rounds=rounds))
    else:
        sched.prune_useless_comms()
        sched.compact()
    return sched


def multilevel_schedule(inst: BspInstance,
                        opts: MultilevelScheduleOptions | None = None,
                        adv_opts: AdvancedOptions | None = None,
                        seed: int = 0, baseline: Schedule | None = None,
                        stats: list | None = None) -> Schedule:
    """Replication-aware multilevel scheduling V-cycle.

    Coarsens the DAG acyclically, solves the coarsest instance with the
    flat ``best_replicated_schedule`` (which runs ``advanced_heuristic``
    from both the baseline and the parallel seed), then projects and
    refines level by level.  Reachable via
    ``best_replicated_schedule(..., multilevel=True)``.

    At or below ``coarsest_n`` (or on immediate coarsening stagnation)
    the driver *is* the flat path -- exact-equality fallthrough, pinned
    by tests.  Up to ``flat_guard_n`` the flat path also runs as a hedge
    and the cheaper schedule wins (see module docstring).  ``stats``
    (optional list) receives one row per refinement stop with
    projected/refined costs, which is how the refinement-never-increases
    property is tested, plus a ``flat_guard`` row when the hedge ran.
    """
    opts = opts or MultilevelScheduleOptions()
    dag = inst.dag
    if dag.n <= opts.coarsest_n:
        return best_replicated_schedule(inst, baseline=baseline,
                                        opts=adv_opts, seed=seed)
    rng = np.random.default_rng(seed)
    levels, cmaps = build_levels(dag, inst.P, opts, rng)
    if not cmaps:  # immediate stagnation: no coarse level exists
        return best_replicated_schedule(inst, baseline=baseline,
                                        opts=adv_opts, seed=seed)
    coarse_inst = BspInstance(levels[-1], inst.P, inst.g, inst.L)
    # coarse solve: advanced heuristic from the PARALLEL seed only.  The
    # flat best-of would often pick the sequential schedule here -- coarse
    # mu is a boundary *sum*, so coarse comm systematically overprices the
    # fine comm the canonical re-derivation actually pays -- and a
    # single-superstep coarse solution is a basin no refinement move can
    # leave (every move needs a later superstep to deliver into).
    from .list_sched import bspg_schedule, hill_climb

    par = hill_climb(bspg_schedule(coarse_inst, seed=seed), seed=seed)
    sched = advanced_heuristic(par, adv_opts)
    if stats is not None:
        stats.append({"level": len(levels) - 1, "n": levels[-1].n,
                      "S": sched.S,
                      "cost_projected": float(sched.current_cost()),
                      "cost_refined": float(sched.current_cost())})
    prev = len(levels) - 1
    for li in sorted(_refinement_schedule(len(levels), opts.refine_every),
                     reverse=True):
        cmap = _compose_cmaps(cmaps, li, prev)
        li_inst = inst if li == 0 else BspInstance(levels[li], inst.P,
                                                   inst.g, inst.L)
        sched = Schedule.from_projection(li_inst, sched, cmap)
        prev = li
        projected = float(sched.current_cost())
        _refine_level(sched, li == 0, opts, seed + li, adv_opts=adv_opts)
        if stats is not None:
            stats.append({"level": li, "n": levels[li].n, "S": sched.S,
                          "cost_projected": projected,
                          "cost_refined": float(sched.current_cost())})
    if 0 < dag.n <= opts.flat_guard_n:
        # hedge while the flat path is tractable: the V-cycle's reach claim
        # lives beyond this size; below it, basin differences occasionally
        # favor the flat search (e.g. replication-hungry psdd circuits), so
        # run it too and keep the cheaper schedule.  Guarantees
        # cost-not-worse wherever both paths run, at the disclosed price of
        # one flat run.
        flat = best_replicated_schedule(inst, baseline=baseline,
                                        opts=adv_opts, seed=seed)
        if stats is not None:
            stats.append({"flat_guard": True, "n": dag.n,
                          "flat_cost": float(flat.current_cost()),
                          "vcycle_cost": float(sched.current_cost())})
        if flat.current_cost() < sched.current_cost():
            return flat
    return sched
