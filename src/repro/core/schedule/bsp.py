"""BSP schedule representation, validity and cost (paper §3.3).

A schedule consists of compute phases ``comp[s][p]`` (sets of nodes) and
communication steps.  We keep comms canonical: at most one communication per
(value, destination) pair -- sending the same value to the same processor
twice is never beneficial.  A comm ``(v, dst) -> (src, s)`` sends v's output
from ``src`` to ``dst`` in superstep ``s``; the value becomes usable on
``dst`` from superstep ``s+1`` on.

Cost (with node compute weights ``omega`` and communication weights ``mu``):

    sum_s  max_p work(p,s)
         + sum_s [h_s > 0] * (L + g*h_s),   h_s = max_p max(sent, recv)

The synchronization cost L is charged only for supersteps with a non-empty
communication phase (matching the paper's Appendix A.1 accounting, where a
communication-free single-superstep schedule costs exactly its work).

``Schedule`` is the incremental-delta engine (``engine.ScheduleState``,
which maintains per-superstep top-2 load maxima, cached superstep costs and
an undo log for transactional trial moves) plus validity checking and
reporting.  The seed's full-recompute implementation survives verbatim in
``reference.py`` as the equivalence oracle.  ``EPS`` is the single shared
cost-comparison tolerance for the whole scheduling stack.
"""
from __future__ import annotations

import dataclasses

from ..hypergraph import Dag
from .engine import EPS, INF, ScheduleState

__all__ = ["BspInstance", "Schedule", "EPS", "INF"]


@dataclasses.dataclass
class BspInstance:
    dag: Dag
    P: int
    g: float = 1.0
    L: float = 0.0


class Schedule(ScheduleState):
    """BSP schedule (engine-backed).  See module docstring for semantics."""

    # ------------------------------------------------------------- validity
    def validate(self) -> list[str]:
        errors: list[str] = []
        dag, P = self.inst.dag, self.inst.P
        computed = [False] * dag.n
        for v in range(dag.n):
            for p, s in self.assign[v].items():
                computed[v] = True
                if v not in self.comp[s][p]:
                    errors.append(f"assign/comp mismatch for ({v},{p},{s})")
                for u in dag.parents[v]:
                    if not self.present_at(u, p, s):
                        errors.append(f"parent {u} of {v} missing on p{p} at s{s}")
        for v in range(dag.n):
            if not computed[v]:
                errors.append(f"node {v} never computed")
        for (v, dst), (src, s) in self.comms.items():
            if not self.present_at(v, src, s):
                errors.append(f"comm ({v},{src}->{dst},s{s}) source not present")
            if dst == src:
                errors.append(f"comm ({v},{src}->{dst}) self-send")
        return errors

    # ------------------------------------------------------------ reporting
    def surplus_cost(self) -> float:
        """Paper Definition 4.4: BSP cost minus the unavoidable n/P (or
        omega(V)/P with weights) compute floor -- captures exactly the
        extra cost of communication and replication."""
        return self.cost() - float(self.inst.dag.omega.sum()) / self.inst.P

    def stats(self) -> dict:
        return {
            "cost": self.cost(),
            "supersteps": self.S,
            "comms": len(self.comms),
            "replicas": sum(len(a) - 1 for a in self.assign if len(a) > 1),
        }
