"""BSP schedule representation, validity and cost (paper §3.3).

A schedule consists of compute phases ``comp[s][p]`` (sets of nodes) and
communication steps.  We keep comms canonical: at most one communication per
(value, destination) pair -- sending the same value to the same processor
twice is never beneficial.  A comm ``(v, dst) -> (src, s)`` sends v's output
from ``src`` to ``dst`` in superstep ``s``; the value becomes usable on
``dst`` from superstep ``s+1`` on.

Cost (with node compute weights ``omega`` and communication weights ``mu``):

    sum_s  max_p work(p,s)
         + sum_s [h_s > 0] * (L + g*h_s),   h_s = max_p max(sent, recv)

The synchronization cost L is charged only for supersteps with a non-empty
communication phase (matching the paper's Appendix A.1 accounting, where a
communication-free single-superstep schedule costs exactly its work).
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from ..hypergraph import Dag

INF = math.inf


@dataclasses.dataclass
class BspInstance:
    dag: Dag
    P: int
    g: float = 1.0
    L: float = 0.0


class Schedule:
    def __init__(self, inst: BspInstance, S: int):
        self.inst = inst
        P = inst.P
        self.S = S
        self.comp: list[list[set[int]]] = [[set() for _ in range(P)] for _ in range(S)]
        # (v, dst) -> (src, superstep)
        self.comms: dict[tuple[int, int], tuple[int, int]] = {}
        # (v, src) -> set of dsts, for O(deg) use queries
        self.src_index: dict[tuple[int, int], set[int]] = defaultdict(set)
        # v -> {p: superstep computed}  (at most one superstep per (v,p))
        self.assign: list[dict[int, int]] = [dict() for _ in range(inst.dag.n)]
        self.work = np.zeros((S, P))
        self.sent = np.zeros((S, P))
        self.recv = np.zeros((S, P))
        self._cost_arr = np.zeros(S)
        self._total = 0.0
        self._dirty: set[int] = set()

    # ------------------------------------------------------------- mutation
    def _grow(self, s: int) -> None:
        while s >= self.S:
            self.comp.append([set() for _ in range(self.inst.P)])
            self.work = np.vstack([self.work, np.zeros((1, self.inst.P))])
            self.sent = np.vstack([self.sent, np.zeros((1, self.inst.P))])
            self.recv = np.vstack([self.recv, np.zeros((1, self.inst.P))])
            self._cost_arr = np.append(self._cost_arr, 0.0)
            self.S += 1

    def add_comp(self, v: int, p: int, s: int) -> None:
        self._grow(s)
        assert p not in self.assign[v], f"node {v} already on proc {p}"
        self.comp[s][p].add(v)
        self.assign[v][p] = s
        self.work[s, p] += self.inst.dag.omega[v]
        self._dirty.add(s)

    def remove_comp(self, v: int, p: int) -> None:
        s = self.assign[v].pop(p)
        self.comp[s][p].discard(v)
        self.work[s, p] -= self.inst.dag.omega[v]
        self._dirty.add(s)

    def add_comm(self, v: int, src: int, dst: int, s: int) -> None:
        self._grow(s)
        assert (v, dst) not in self.comms
        self.comms[(v, dst)] = (src, s)
        self.src_index[(v, src)].add(dst)
        mu = self.inst.dag.mu[v]
        self.sent[s, src] += mu
        self.recv[s, dst] += mu
        self._dirty.add(s)

    def remove_comm(self, v: int, dst: int) -> None:
        src, s = self.comms.pop((v, dst))
        self.src_index[(v, src)].discard(dst)
        mu = self.inst.dag.mu[v]
        self.sent[s, src] -= mu
        self.recv[s, dst] -= mu
        self._dirty.add(s)

    def move_comm(self, v: int, dst: int, new_s: int) -> None:
        src, _ = self.comms[(v, dst)]
        self.remove_comm(v, dst)
        self.add_comm(v, src, dst, new_s)

    # ------------------------------------------------------------- presence
    def compute_sstep(self, v: int, p: int) -> float:
        return self.assign[v].get(p, INF)

    def recv_sstep(self, v: int, p: int) -> float:
        c = self.comms.get((v, p))
        return c[1] if c is not None else INF

    def present_at(self, v: int, p: int, s: int) -> bool:
        """Usable on p in superstep s (for compute or as a send source)."""
        return self.compute_sstep(v, p) <= s or self.recv_sstep(v, p) < s

    # ----------------------------------------------------------------- cost
    def superstep_cost(self, s: int) -> float:
        c = float(self.work[s].max())
        h = max(self.sent[s].max(), self.recv[s].max())
        if h > 1e-12:
            c += self.inst.L + self.inst.g * h
        return c

    def cost(self) -> float:
        return sum(self.superstep_cost(s) for s in range(self.S))

    def surplus_cost(self) -> float:
        """Paper Definition 4.4: BSP cost minus the unavoidable n/P (or
        omega(V)/P with weights) compute floor -- captures exactly the
        extra cost of communication and replication."""
        return self.cost() - float(self.inst.dag.omega.sum()) / self.inst.P

    def current_cost(self) -> float:
        """Incrementally maintained total cost (O(dirty supersteps))."""
        for s in self._dirty:
            c = self.superstep_cost(s)
            self._total += c - self._cost_arr[s]
            self._cost_arr[s] = c
        self._dirty.clear()
        return self._total

    # ------------------------------------------------------------- validity
    def validate(self) -> list[str]:
        errors: list[str] = []
        dag, P = self.inst.dag, self.inst.P
        computed = [False] * dag.n
        for v in range(dag.n):
            for p, s in self.assign[v].items():
                computed[v] = True
                if v not in self.comp[s][p]:
                    errors.append(f"assign/comp mismatch for ({v},{p},{s})")
                for u in dag.parents[v]:
                    if not self.present_at(u, p, s):
                        errors.append(f"parent {u} of {v} missing on p{p} at s{s}")
        for v in range(dag.n):
            if not computed[v]:
                errors.append(f"node {v} never computed")
        for (v, dst), (src, s) in self.comms.items():
            if not self.present_at(v, src, s):
                errors.append(f"comm ({v},{src}->{dst},s{s}) source not present")
            if dst == src:
                errors.append(f"comm ({v},{src}->{dst}) self-send")
        return errors

    # ------------------------------------------------------ use / windows
    def uses_on(self, v: int, p: int) -> list[int]:
        """Supersteps where v's value is consumed on p (compute or send)."""
        out = []
        for c in self.inst.dag.children[v]:
            s = self.assign[c].get(p)
            if s is not None:
                out.append(s)
        for dst in self.src_index.get((v, p), ()):
            out.append(self.comms[(v, dst)][1])
        return sorted(out)

    def first_use_on(self, v: int, p: int) -> float:
        u = self.uses_on(v, p)
        return u[0] if u else INF

    def earliest_replication(self, v: int, p: int) -> float:
        """First superstep where all parents of v are present on p."""
        e = 0
        for u in self.inst.dag.parents[v]:
            cs = self.compute_sstep(u, p)
            rs = self.recv_sstep(u, p)
            e = max(e, min(cs, rs + 1))
        return e

    # -------------------------------------------------------------- cleanup
    def prune_useless_comms(self) -> int:
        """Drop comms whose value is never used on the destination after
        arrival (can appear after replication rewrites)."""
        drop = []
        for (v, dst), (src, s) in self.comms.items():
            cs = self.compute_sstep(v, dst)
            # a use at superstep t is satisfied by this comm iff s < t, and
            # does not need it at all when covered by local compute (cs <= t)
            needed = any(t > s and not cs <= t for t in self.uses_on(v, dst))
            if not needed:
                drop.append((v, dst))
        for key in drop:
            self.remove_comm(*key)
        return len(drop)

    def compact(self) -> None:
        """Remove empty supersteps (no compute and no comm anywhere)."""
        keep = [s for s in range(self.S)
                if self.work[s].any() or self.sent[s].any() or self.recv[s].any()
                or any(self.comp[s][p] for p in range(self.inst.P))]
        remap = {old: new for new, old in enumerate(keep)}
        self.comp = [self.comp[s] for s in keep]
        self.work = self.work[keep]
        self.sent = self.sent[keep]
        self.recv = self.recv[keep]
        self.S = len(keep)
        self._cost_arr = np.array([self.superstep_cost(s) for s in range(self.S)])
        self._total = float(self._cost_arr.sum())
        self._dirty = set()
        for v in range(self.inst.dag.n):
            self.assign[v] = {p: remap[s] for p, s in self.assign[v].items()}
        self.comms = {k: (src, remap[s]) for k, (src, s) in self.comms.items()}

    def copy(self) -> "Schedule":
        other = Schedule.__new__(Schedule)
        other.inst = self.inst
        other.S = self.S
        other.comp = [[set(ps) for ps in row] for row in self.comp]
        other.comms = dict(self.comms)
        other.src_index = defaultdict(set)
        for k, dsts in self.src_index.items():
            if dsts:
                other.src_index[k] = set(dsts)
        other.assign = [dict(a) for a in self.assign]
        other.work = self.work.copy()
        other.sent = self.sent.copy()
        other.recv = self.recv.copy()
        other._cost_arr = self._cost_arr.copy()
        other._total = self._total
        other._dirty = set(self._dirty)
        return other

    def stats(self) -> dict:
        return {
            "cost": self.cost(),
            "supersteps": self.S,
            "comms": len(self.comms),
            "replicas": sum(len(a) - 1 for a in self.assign if len(a) > 1),
        }
