"""Replication heuristics for BSP schedules (paper §6.2), engine-backed.

``basic_heuristic``     -- §6.2.2: replace single communication steps by a
                           replication whenever that decreases the total cost.
``advanced_heuristic``  -- §6.2.3: iterates three larger moves until fixpoint:
    * batch replication (BR): remove at least one comm from every processor
      saturating the h-relation of a superstep, simultaneously;
    * superstep merging (SM): merge consecutive supersteps, replicating
      (recursively) the values that could not otherwise arrive in time;
    * superstep replication (SR): replicate a whole compute phase V_{p1,s}
      on another processor p2.

All moves are evaluated against the exact BSP cost; only strictly improving
moves are kept.  Between rounds the schedule is cleaned (useless comms
pruned, empty supersteps compacted), mirroring the paper's §C.2.1 remark.

The pricing mechanics run on the incremental-delta engine (``engine.py``):
the basic move is priced by a pure ``delta_replicate_for_comm`` (no
mutation at all), and the compound BR/SM trials mutate inside a
``begin()``/``commit()``/``rollback()`` transaction instead of working on a
throwaway ``Schedule.copy()``.  The SR pass goes further through the
frontier layer (``core.frontier.schedule_front``): each superstep's whole
``(s, p1, p2)`` candidate front is enumerated from one flat pass over the
compute phase and priced *purely* (failed candidates never touch the undo
log); only the winning candidate commits through a transaction.  Decisions
are tie-broken deterministically (sorted comm/compute iteration,
``(superstep, processor)`` source keys, lexicographic SR winner) so the
search trajectory is identical to the preserved full-recompute oracle in
``reference.py`` -- same final costs, O(touched-supersteps) work per trial
instead of O(n + S*P + comms).
"""
from __future__ import annotations

import dataclasses

from .bsp import EPS, INF, Schedule


# ----------------------------------------------------------- basic heuristic

def _replication_window(sched: Schedule, v: int, dst: int) -> tuple[int, int]:
    """Valid supersteps to replicate v on dst, ignoring its current comm.

    earliest: all parents present; latest: first use of v on dst.
    """
    e = sched.earliest_replication(v, dst)
    if e == INF:  # some parent never becomes available on dst
        return 1, 0
    first = sched.first_use_on(v, dst)
    hi = int(first) if first is not INF else sched.S - 1
    return int(e), min(hi, sched.S - 1)


def _best_replication_sstep(sched: Schedule, v: int, dst: int) -> tuple[int, float] | None:
    """Cheapest superstep (by compute-cost increase) to replicate v on dst."""
    lo, hi = _replication_window(sched, v, dst)
    if lo > hi:
        return None
    w = sched.inst.dag.omega[v]
    best_t, best_inc = None, INF
    for t in range(lo, hi + 1):
        cur_max = sched.work_max(t)
        inc = max(0.0, sched.work[t][dst] + w - cur_max)
        if inc < best_inc - EPS:
            best_inc, best_t = inc, t
        if inc <= EPS:
            break  # cannot do better than free
    return (best_t, best_inc) if best_t is not None else None


def try_replicate_for_comm(sched: Schedule, v: int, dst: int) -> bool:
    """Basic move: drop comm (v -> dst), replicate v on dst instead."""
    if dst in sched.assign[v]:
        return False
    cand = _best_replication_sstep(sched, v, dst)
    if cand is None:
        return False
    t, _ = cand
    if sched.delta_replicate_for_comm(v, dst, t) < -EPS:
        sched.remove_comm(v, dst)
        sched.add_comp(v, dst, t)
        return True
    return False


def basic_heuristic(sched: Schedule, max_passes: int = 50) -> Schedule:
    for _ in range(max_passes):
        improved = False
        for (v, dst) in sorted(sched.comms.keys()):
            if (v, dst) not in sched.comms:
                continue
            if try_replicate_for_comm(sched, v, dst):
                improved = True
        if not improved:
            break
    sched.prune_useless_comms()
    sched.compact()
    return sched


def replica_prune_pass(sched: Schedule, max_passes: int = 4) -> bool:
    """Inverse of the basic move: drop compute replicas, re-feeding their
    consumers by a comm from another replica when needed.

    The multilevel projection expands a replicated coarse cluster to a
    replica of *every* member, many of which serve no fine-level use --
    and no existing move ever removes a replica, so projected schedules
    would stay stuck with the inherited replication grain.  Per replica
    (node computed on more than one processor, sorted iteration):

      * no use on that processor: remove it outright (work only drops;
        validity cannot depend on an unused presence);
      * otherwise price [drop compute, add one comm from the earliest
        other replica arriving before the first use] through
        ``_delta_cells`` and apply when strictly improving.

    Repeats until a pass changes nothing (a removal can unlock its
    neighbors').  Never touches the last remaining assignment.
    """
    improved_any = False
    dag = sched.inst.dag
    for _ in range(max_passes):
        improved = False
        for v in range(dag.n):
            if len(sched.assign[v]) < 2:
                continue
            for p in sorted(sched.assign[v]):
                if len(sched.assign[v]) < 2:
                    break
                if (v, p) in sched.comms:
                    continue  # compute + incoming comm: out of scope
                if sched.src_index.get((v, p)):
                    # replica sources onward comms: dropping it would turn
                    # them into relays (source present only by receive),
                    # which the whole stack assumes never exist
                    continue
                s = sched.assign[v][p]
                uses = sched.uses_on(v, p)
                if not uses:
                    sched.remove_comp(v, p)
                    improved = improved_any = True
                    continue
                tf = min(uses) - 1
                others = [(ss, pp) for pp, ss in sched.assign[v].items()
                          if pp != p]
                s_src, src = min(others)
                if s_src > tf or tf < 0:
                    continue  # no replica early enough to feed the uses
                mu, om = dag.mu[v], dag.omega[v]
                d = sched._delta_cells([("work", s, p, -om),
                                        ("sent", tf, src, mu),
                                        ("recv", tf, p, mu)])
                if d < -EPS:
                    sched.remove_comp(v, p)
                    sched.add_comm(v, src, p, tf)
                    improved = improved_any = True
        if not improved:
            break
    return improved_any


# -------------------------------------------------------- batch replication

def batch_replication_pass(sched: Schedule) -> bool:
    """BR: per superstep, simultaneously remove one comm from every
    saturated send/recv side, replicating the carried values."""
    improved_any = False
    # bucket comms by superstep once: this pass only removes comms (at the
    # superstep being worked) and adds compute, so a bucket filtered
    # against the live dict is exactly the inline per-iteration sort
    by_t: dict[int, list] = {}
    for (v, dst), (src, t) in sched.comms.items():
        by_t.setdefault(t, []).append((v, dst, src))
    for s in range(sched.S):
        bucket = sorted(by_t.get(s, []))
        while True:
            h = sched.h_of(s)
            if h <= EPS:
                break
            comms_at_s = [e for e in bucket
                          if (e[0], e[1]) in sched.comms]
            if not comms_at_s:
                break
            sat = [("sent", p) for p in range(sched.inst.P)
                   if sched.sent[s][p] >= h - EPS] + \
                  [("recv", p) for p in range(sched.inst.P)
                   if sched.recv[s][p] >= h - EPS]
            before = sched.current_cost()
            sched.begin()
            chosen: dict[tuple[int, int], int] = {}  # (v, dst) -> src
            feasible = True
            for side, p in sat:
                # already covered by a chosen comm?
                covered = any((side == "sent" and src == p) or
                              (side == "recv" and dst == p)
                              for (v, dst), src in chosen.items())
                if covered:
                    continue
                # cheapest replication among comms on this side
                best = None
                for (v, dst, src) in comms_at_s:
                    if (v, dst) in chosen or (v, dst) not in sched.comms:
                        continue
                    if (side == "sent" and src != p) or (side == "recv" and dst != p):
                        continue
                    if dst in sched.assign[v]:
                        continue
                    cand = _best_replication_sstep(sched, v, dst)
                    if cand is None:
                        continue
                    if best is None or cand[1] < best[2]:
                        best = (v, dst, cand[1], cand[0], src)
                if best is None:
                    feasible = False
                    break
                v, dst, _, t, src = best
                sched.remove_comm(v, dst)
                sched.add_comp(v, dst, t)
                chosen[(v, dst)] = src
            if feasible and chosen and sched.current_cost() < before - EPS:
                sched.commit()
                improved_any = True
                continue  # try to shave the new maximum too
            sched.rollback()
            break
    return improved_any


# --------------------------------------------------------- superstep merging

def try_merge_with_replication(sched: Schedule, s: int) -> bool:
    """Attempt to merge superstep s+1 into s (SM), in place under a
    transaction.  Commits (and compacts) on improvement, rolls back
    otherwise; returns whether the merge was kept.

    First-improvement comparator path (``use_fronts=False``), post-prune
    accept; the mutation sequence itself lives in
    ``frontier.apply_sm_mutations``, shared with the winner-rule path and
    the oracle.
    """
    from ..frontier import apply_sm_mutations

    if s + 1 >= sched.S:
        return False
    before = sched.current_cost()
    sched.begin()
    if not apply_sm_mutations(sched, s):
        sched.rollback()
        return False
    sched.prune_useless_comms()
    if sched.current_cost() < before - EPS:
        sched.commit()
        sched.compact()
        return True
    sched.rollback()
    return False


def superstep_merge_pass(sched: Schedule,
                         use_fronts: bool = True) -> tuple[Schedule, bool]:
    """SM sweep over adjacent superstep pairs.

    Default path: price every candidate merge *purely*
    (``frontier.price_superstep_merge`` -- failed or losing candidates
    never touch the undo log) and commit **the winner** -- minimal
    pre-prune delta, ties to the smallest s -- through the transaction
    machinery, repeating until no candidate improves.  The oracle
    (``reference.superstep_merge_pass``) applies the same winner rule, so
    trajectories stay identical (bit-identical on integer weights).

    ``use_fronts=False`` keeps the pre-frontier first-improvement
    transactional sweep with its post-prune accept test (benchmark
    comparator; may visit a different local optimum).
    """
    improved = False
    if not use_fronts:
        s = 0
        while s < sched.S - 1:
            if try_merge_with_replication(sched, s):
                improved = True
                # stay at the same index: maybe merge further
            else:
                s += 1
        return sched, improved
    from ..frontier import (commit_superstep_merge, price_superstep_merge,
                            sm_front)
    while sched.S > 1:
        # one comm sort per round, bucketed by superstep, shared by every
        # candidate pricing (identical iteration to the inline sort)
        by_t: dict[int, list] = {}
        for kv in sorted(sched.comms.items()):
            by_t.setdefault(kv[1][1], []).append(kv)
        best = None
        for s in sm_front(sched):
            priced = price_superstep_merge(
                sched, s, comms_at=(by_t.get(s, []), by_t.get(s + 1, [])))
            if priced is not None and priced < -EPS:
                if best is None or priced < best[0]:
                    best = (priced, s)
        if best is None:
            break
        commit_superstep_merge(sched, best[1])
        improved = True
    return sched, improved


# -------------------------------------------------------- superstep splitting

def superstep_split_pass(sched: Schedule) -> tuple[Schedule, bool]:
    """Superstep-split sweep (the inverse of SM): per superstep, enumerate
    level-cut bipartitions of the compute phase (``frontier.split_front``),
    price every candidate *purely* (``price_superstep_split`` -- losers
    never touch the undo log) and commit **the winner** -- minimal
    pre-prune delta, ties to the smallest ``(s, cut)`` by ascending
    enumeration with a strict comparison -- through the transaction
    machinery, repeating until no candidate improves.  The oracle
    (``reference.superstep_split_pass``) applies the same winner rule, so
    trajectories stay bit-identical on integer weights.

    Escapes over-merged basins organically: where SM has collapsed an
    h-relation into one overloaded comm phase, the split re-derives the
    affected comms canonically across the two resulting phases, trading
    ``L`` against ``g * h`` -- the priced fixed point of merge + split is
    what retires the multilevel flat-path guard.
    """
    from ..frontier import (commit_superstep_split, price_superstep_split,
                            split_front)
    from .list_sched import dag_levels

    level = dag_levels(sched.inst.dag)
    improved = False
    while True:
        pre = sorted(sched.comms.items())
        best = None
        for s in range(sched.S):
            for _cut, late in split_front(sched, s, level):
                priced = price_superstep_split(sched, s, late, pre)
                if priced is not None and priced < -EPS:
                    if best is None or priced < best[0]:
                        best = (priced, s, late)
        if best is None:
            break
        commit_superstep_split(sched, best[1], best[2])
        improved = True
    return sched, improved


# ------------------------------------------------------ superstep replication

def try_superstep_replication(sched: Schedule, s: int, p1: int, p2: int) -> bool:
    """SR: replicate (the useful part of) V_{p1,s} onto p2, in place under
    a transaction.  Returns whether the replication was kept.

    First-improvement comparator path (``use_fronts=False``); the mutation
    sequence itself lives in ``frontier.apply_sr_mutations``, shared with
    the winner-rule path and the oracle.
    """
    from ..frontier import apply_sr_mutations

    nodes = [v for v in sorted(sched.comp[s][p1])
             if p2 not in sched.assign[v] and sched.has_use_on(v, p2)]
    if not nodes:
        return False
    before = sched.current_cost()
    sched.begin()
    if not apply_sr_mutations(sched, s, p1, p2, nodes):
        sched.rollback()
        return False
    sched.prune_useless_comms()
    if sched.current_cost() < before - EPS:
        sched.commit()
        return True
    sched.rollback()
    return False


def superstep_replication_pass(sched: Schedule,
                               use_fronts: bool = True) -> tuple[Schedule, bool]:
    """SR sweep over supersteps.

    Default path: per superstep, enumerate the whole ``(p1, p2)`` candidate
    front from one flat pass (``frontier.sr_front``), price every candidate
    purely (no transaction, no rollback; pruning after commit only helps),
    and commit **the winner** -- minimal priced delta, ties to the
    lexicographically smallest ``(p1, p2)`` -- through the transaction
    machinery, repeating the superstep until no candidate improves.  The
    oracle (``reference.superstep_replication_pass``) applies the same
    winner rule, so trajectories stay identical.

    ``use_fronts=False`` keeps the pre-frontier first-improvement
    transactional sweep (benchmark comparator; may visit a different local
    optimum than the winner rule).
    """
    improved = False
    P = sched.inst.P
    s = 0
    if not use_fronts:
        while s < sched.S:
            done = False
            for p1 in range(P):
                for p2 in range(P):
                    if p1 == p2:
                        continue
                    if try_superstep_replication(sched, s, p1, p2):
                        improved = done = True
                        break
                if done:
                    break
            if not done:
                s += 1
        return sched, improved
    from ..frontier import (commit_superstep_replication,
                            price_superstep_replication, sr_front)
    while s < sched.S:
        best = None
        for (p1, p2, nodes) in sr_front(sched, s):
            priced = price_superstep_replication(sched, s, p1, p2, nodes)
            if priced is not None and priced < -EPS:
                if best is None or priced < best[0]:
                    best = (priced, p1, p2, nodes)
        if best is None:
            s += 1
        else:
            commit_superstep_replication(sched, s, *best[1:])
            improved = True  # retry the same superstep with the new state
    return sched, improved


# ------------------------------------------------------------------- drivers

def best_replicated_schedule(inst, baseline: Schedule | None = None,
                             opts: "AdvancedOptions | None" = None,
                             seed: int = 0, multilevel: bool = False,
                             ml_opts=None, stats: list | None = None,
                             workers: int | None = None) -> Schedule:
    """Run the advanced heuristic from the best non-replicating schedule AND
    from the parallel list schedule.  The latter matters when the
    non-replicating optimum degenerates to few processors (e.g. the paper's
    Appendix A.1 bipartite example, where only a parallel seed gives the
    replication moves room to work); beyond-paper addition.

    ``multilevel=True`` routes through the acyclic-coarsening V-cycle
    (``multilevel.multilevel_schedule``) instead, which takes the same
    search to 100k-node DAGs; at or below its coarsest size that driver
    falls through to this flat path exactly.  ``ml_opts`` forwards a
    ``MultilevelScheduleOptions``; ``stats`` collects per-level cost rows;
    ``workers`` (> 1) shards the coarsening scoring passes over a
    process-parallel context (bit-identical results; serial where shared
    memory is unavailable).
    """
    from .list_sched import baseline_schedule, bspg_schedule, hill_climb

    if multilevel:
        from .multilevel import multilevel_schedule

        return multilevel_schedule(inst, opts=ml_opts, adv_opts=opts,
                                   seed=seed, baseline=baseline, stats=stats,
                                   workers=workers)
    if baseline is None:
        baseline = baseline_schedule(inst, seed=seed)
    cands = [advanced_heuristic(baseline.copy(), opts)]
    par = hill_climb(bspg_schedule(inst, seed=seed), seed=seed)
    cands.append(advanced_heuristic(par, opts))
    return min(cands, key=lambda s: s.current_cost())


@dataclasses.dataclass
class AdvancedOptions:
    batch_replication: bool = True
    superstep_merging: bool = True
    superstep_replication: bool = True
    max_rounds: int = 8
    # False = pre-frontier first-improvement SR sweep (benchmark comparator)
    use_fronts: bool = True
    # winner-commit superstep splits right after the SM block (multilevel
    # refinement enables this so merge/split reach a priced fixed point);
    # appended last to keep positional construction stable
    superstep_splitting: bool = False


def advanced_heuristic(sched: Schedule, opts: AdvancedOptions | None = None) -> Schedule:
    opts = opts or AdvancedOptions()
    sched = basic_heuristic(sched)
    for _ in range(opts.max_rounds):
        improved = False
        # SM before BR: batch replication fills compute slack that merging
        # would otherwise exploit (ablations show SM is the bigger lever,
        # cf. paper Table 14)
        if opts.superstep_merging:
            sched, imp = superstep_merge_pass(sched,
                                              use_fronts=opts.use_fronts)
            improved |= imp
        # splits directly after merges: the two alternate to a priced
        # fixed point (every commit strictly improves, so this terminates)
        if opts.superstep_splitting:
            sched, imp = superstep_split_pass(sched)
            improved |= imp
        if opts.batch_replication:
            improved |= batch_replication_pass(sched)
        if opts.superstep_replication:
            sched, imp = superstep_replication_pass(
                sched, use_fronts=opts.use_fronts)
            improved |= imp
        # interleave the basic move as cleanup (cheap local improvements)
        before = sched.current_cost()
        sched = basic_heuristic(sched, max_passes=5)
        improved |= sched.current_cost() < before - EPS
        if not improved:
            break
    sched.prune_useless_comms()
    sched.compact()
    return sched
