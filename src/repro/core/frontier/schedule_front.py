"""Batched candidate-front pricing for the BSP schedule engine.

Two fronts from the scheduling stack's hot loops:

  * **Node moves** (``list_sched.hill_climb``): ``price_node_moves`` prices
    moving a single-assignment node to *every* processor at once.  The
    move's cell changes are accumulated into per-superstep (P x P) delta
    matrices (candidate q x processor) and evaluated against flat
    per-superstep load rows -- ascending superstep order, full-row maxima
    -- which reproduces ``ScheduleState.delta_node_move`` bit-for-bit for
    each q (``tests/test_frontier.py`` pins this).

  * **Superstep replication** (``replication.superstep_replication_pass``):
    ``sr_front`` enumerates every non-empty ``(p1, p2)`` candidate of a
    superstep from one flat use/assignment matrix over the superstep's
    compute phase, and ``price_superstep_replication`` prices a candidate
    *purely* -- simulating exactly the mutation sequence of the
    transactional trial (parent comms, dropped comms, replica compute) and
    folding the cells through ``ScheduleState._delta_cells`` -- so failed
    candidates never touch the undo log.  Pruning after a commit can only
    reduce the cost further, so a candidate priced improving is improving.

Both are pure; committing stays with the engine's transaction machinery.
"""
from __future__ import annotations

import numpy as np

from ..schedule.engine import EPS, ScheduleState


def price_node_moves(sched: ScheduleState, v: int) -> np.ndarray:
    """Deltas of the compound node move ``v -> q`` for every q at once.

    Requires ``len(sched.assign[v]) == 1``.  Entry q equals
    ``sched.delta_node_move(v, q)`` bit-for-bit for every ``q != p``
    (entry p, the current processor, is 0 -- not a move).  Feasibility is
    ``node_move_targets``'s concern, mirroring the hill climber.
    """
    P = sched.inst.P
    (p, s), = sched.assign[v].items()
    dag = sched.inst.dag
    mu, om = dag.mu[v], dag.omega[v]
    allq = np.arange(P)
    D: dict[tuple[str, int], np.ndarray] = {}

    def dd(kind: str, t: int) -> np.ndarray:
        key = (kind, t)
        if key not in D:
            D[key] = np.zeros((P, P))
        return D[key]

    # outgoing comms retarget src p -> q (the one to q itself is dropped);
    # fill order mirrors ScheduleState._node_move_cells so every (q, proc)
    # slot accumulates its contributions in the same sequence
    for dst in sorted(sched.src_index.get((v, p), ())):
        _, t = sched.comms[(v, dst)]
        ds = dd("sent", t)
        ds[:, p] -= mu
        dd("recv", t)[dst, dst] -= mu
        keep = allq != dst
        ds[allq[keep], allq[keep]] += mu
    # an incoming comm to q is dropped (v becomes local there)
    for q in range(P):
        c0 = sched.comms.get((v, q))
        if c0 is not None and c0[0] != p:
            src0, t0 = c0
            dd("sent", t0)[q, src0] -= mu
            dd("recv", t0)[q, q] -= mu
    dw = dd("work", s)
    dw[:, p] -= om
    dw[allq, allq] += om
    # consumers left on p get one comm q -> p before their first use
    uses_p = sched.uses_on(v, p)
    if uses_p:
        tf = min(uses_p) - 1
        dd("sent", tf)[allq, allq] += mu
        dd("recv", tf)[:, p] += mu

    L, g = sched.inst.L, sched.inst.g
    zeros = np.zeros((P, P))
    deltas = np.zeros(P)
    for t in sorted({t for (_, t) in D}):
        assert t < sched.S, "node move cannot touch beyond the horizon"
        w1 = (np.asarray(sched.work[t])
              + D.get(("work", t), zeros)).max(axis=1)
        s1 = (np.asarray(sched.sent[t])
              + D.get(("sent", t), zeros)).max(axis=1)
        r1 = (np.asarray(sched.recv[t])
              + D.get(("recv", t), zeros)).max(axis=1)
        h = np.maximum(s1, r1)
        deltas += np.where(h > EPS, w1 + L + g * h, w1) - sched._scost[t]
    deltas[p] = 0.0
    return deltas


def node_move_targets(sched: ScheduleState, v: int) -> list[bool]:
    """Feasible targets of the hill climber's node move, as P bools.

    Mirrors ``list_sched.try_node_move``'s guards: q must differ from the
    current processor, every parent must be present on q at v's superstep,
    and v must not be consumed on its current processor in that superstep
    (the replacement comm could not arrive in time).  Plain-python with
    early exits -- this runs once per node per pass, usually to say "no"
    (numpy dispatch here would dominate the whole pass).
    """
    P = sched.inst.P
    (p, s), = sched.assign[v].items()
    uses_p = sched.uses_on(v, p)
    if uses_p and min(uses_p) <= s:
        return [False] * P
    feas = [True] * P
    feas[p] = False
    alive = P - 1
    comms = sched.comms
    for u in sched.inst.dag.parents[v]:
        assign_u = sched.assign[u]
        for q in range(P):
            if not feas[q]:
                continue
            ss = assign_u.get(q)
            if ss is not None and ss <= s:
                continue
            c = comms.get((u, q))
            if c is None or c[1] >= s:
                feas[q] = False
                alive -= 1
        if not alive:
            break
    return feas


# --------------------------------------------------------------------------
# Superstep-replication front
# --------------------------------------------------------------------------

def sr_front(sched: ScheduleState, s: int) -> list[tuple[int, int, list[int]]]:
    """All non-empty SR candidates ``(p1, p2, nodes)`` of superstep s.

    One flat pass over the superstep's compute phase builds, per node, the
    processors it is *usable toward* (a child computed there or an onward
    send from there, minus processors it is already assigned to); the
    candidate list then reads off as the non-zero (p1, p2) combinations,
    in the deterministic lexicographic order both search paths share.
    ``nodes`` reproduces ``try_superstep_replication``'s eligibility
    filter exactly (sorted members of ``comp[s][p1]`` with a use on p2).
    """
    P = sched.inst.P
    entries: list[int] = []
    p1_of: list[int] = []
    for p1 in range(P):
        for v in sorted(sched.comp[s][p1]):
            entries.append(v)
            p1_of.append(p1)
    if not entries:
        return []
    assign = sched.assign
    children = sched.inst.dag.children
    src_index = sched.src_index
    U = np.zeros((len(entries), P), dtype=bool)
    for i, v in enumerate(entries):
        row = U[i]
        for c in children[v]:
            for pp in assign[c]:
                row[pp] = True
        for pp in range(P):
            if src_index.get((v, pp)):
                row[pp] = True
        for pp in assign[v]:
            row[pp] = False
    p1_arr = np.asarray(p1_of)
    front = []
    for p1 in range(P):
        idx = np.flatnonzero(p1_arr == p1)
        if not len(idx):
            continue
        nz = U[idx].any(axis=0)
        for p2 in range(P):
            if p2 == p1 or not nz[p2]:
                continue
            front.append((p1, p2, [entries[i] for i in idx if U[i, p2]]))
    return front


def price_superstep_replication(sched: ScheduleState, s: int, p1: int,
                                p2: int, nodes: list[int]) -> float | None:
    """Pure price of replicating ``nodes`` (from ``V_{p1,s}``) onto p2.

    Simulates the exact mutation sequence of the transactional trial --
    parent comms added at s-1, comms (v, p2) arriving at >= s dropped,
    replica compute added at (s, p2) -- without touching the schedule, and
    returns the cost delta *before* ``prune_useless_comms`` (which can
    only decrease it further, so an improving price implies an improving
    commit).  Returns None when some parent cannot be made present on p2
    (the trial would roll back).
    """
    dag = sched.inst.dag
    node_set = set(nodes)
    cells: list[tuple[str, int, int, float]] = []
    added_comp: set[int] = set()   # nodes virtually replicated at (p2, s)
    added_comm: set[int] = set()   # parents virtually comm'd to p2 at s-1
    for v in nodes:
        for u in dag.parents[v]:
            if (u in added_comp or u in added_comm
                    or sched.present_at(u, p2, s)):
                continue
            if u in node_set and sched.assign[u].get(p1) == s:
                continue  # replicated alongside
            cs_any = min(sched.assign[u].values())
            if (cs_any <= s - 1 and s - 1 >= 0
                    and (u, p2) not in sched.comms):
                src = min(sched.assign[u],
                          key=lambda p: (sched.assign[u][p], p))
                mu = dag.mu[u]
                cells.append(("sent", s - 1, src, mu))
                cells.append(("recv", s - 1, p2, mu))
                added_comm.add(u)
            else:
                return None
        c = sched.comms.get((v, p2))
        if c is not None and c[1] >= s:  # arrives later than the replica
            src0, t0 = c
            mu = dag.mu[v]
            cells.append(("sent", t0, src0, -mu))
            cells.append(("recv", t0, p2, -mu))
        cells.append(("work", s, p2, dag.omega[v]))
        added_comp.add(v)
    return sched._delta_cells(cells)


def apply_sr_mutations(sched, s: int, p1: int, p2: int,
                       nodes: list[int]) -> bool:
    """The SR mutation sequence (no prune): parent comms at s-1, late
    comms (v, p2) dropped, replica compute added at (s, p2).

    Single home of the sequence, shared by the engine commit below and the
    ``reference.py`` oracle (it only touches the mutation API the two
    schedule classes have in common); ``price_superstep_replication``'s
    pure simulation must mirror it cell-for-cell.  Returns False when some
    parent cannot be made present (caller rolls back / discards).
    """
    node_set = set(nodes)
    for v in nodes:
        # parents must be present on p2 by superstep s
        for u in sched.inst.dag.parents[v]:
            if sched.present_at(u, p2, s):
                continue
            if u in node_set and sched.assign[u].get(p1) == s:
                continue  # replicated alongside
            cs_any = min(sched.assign[u].values())
            if cs_any <= s - 1 and s - 1 >= 0 and (u, p2) not in sched.comms:
                src = min(sched.assign[u],
                          key=lambda p: (sched.assign[u][p], p))
                sched.add_comm(u, src, p2, s - 1)
            else:
                return False
        if (v, p2) in sched.comms and sched.comms[(v, p2)][1] >= s:
            sched.remove_comm(v, p2)  # arrives later than the replica
        sched.add_comp(v, p2, s)
    return True


def commit_superstep_replication(sched: ScheduleState, s: int, p1: int,
                                 p2: int, nodes: list[int]) -> None:
    """Replay a priced SR winner through the transaction machinery.

    Performs exactly the mutations ``price_superstep_replication``
    simulated (feasibility was established there), then prunes; a
    surprise infeasibility or mid-commit failure rolls the transaction
    back before re-raising, so the schedule is never left corrupted.
    """
    sched.begin()
    try:
        if not apply_sr_mutations(sched, s, p1, p2, nodes):
            raise RuntimeError("priced SR became infeasible at commit")
        sched.prune_useless_comms()
    except BaseException:
        sched.rollback()
        raise
    sched.commit()
