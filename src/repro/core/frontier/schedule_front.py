"""Batched candidate-front pricing for the BSP schedule engine.

Three fronts from the scheduling stack's hot loops:

  * **Node moves** (``list_sched.hill_climb``): ``price_node_moves`` prices
    moving a single-assignment node to *every* processor at once.  The
    move's cell changes are accumulated into per-superstep (P x P) delta
    matrices (candidate q x processor) and evaluated against flat
    per-superstep load rows -- ascending superstep order, full-row maxima
    -- which reproduces ``ScheduleState.delta_node_move`` bit-for-bit for
    each q (``tests/test_frontier.py`` pins this).

  * **Superstep replication** (``replication.superstep_replication_pass``):
    ``sr_front`` enumerates every non-empty ``(p1, p2)`` candidate of a
    superstep from one flat use/assignment matrix over the superstep's
    compute phase, and ``price_superstep_replication`` prices a candidate
    *purely* -- simulating exactly the mutation sequence of the
    transactional trial (parent comms, dropped comms, replica compute) and
    folding the cells through ``ScheduleState._delta_cells`` -- so failed
    candidates never touch the undo log.  Pruning after a commit can only
    reduce the cost further, so a candidate priced improving is improving.

  * **Superstep merging** (``replication.superstep_merge_pass``):
    ``sm_front`` is simply every adjacent pair ``(s, s + 1)``;
    ``price_superstep_merge`` prices one merge *purely* by replaying the
    exact mutation sequence of ``apply_sm_mutations`` against a virtual
    overlay (``_MergeSim``) -- comm moves to s-1, recursive replication of
    values produced in the merged step, compute and comm shifts from s+1
    -- so failed or losing candidates never touch the undo log.  Like SR,
    the price is the *pre-prune* delta (pruning after a commit can only
    lower the cost further) and only the **winner** (min priced delta,
    ties to the smallest s) commits through a transaction; the
    ``reference.py`` oracle applies the same winner rule in lockstep, so
    trajectories stay bit-identical on integer weights.

All are pure; committing stays with the engine's transaction machinery.
"""
from __future__ import annotations

import numpy as np

from ..schedule.engine import EPS, INF, ScheduleState


def device_windows(sched: ScheduleState, backend: str | None = None):
    """Device-resident window pricer for the jax backend, or None.

    Builds a ``kernels.front_pass.DeviceScheduleWindows`` mirror of the
    schedule's per-superstep rows when the jax backend is selected (the
    explicit argument wins, else the frontier default backend) and the
    instance satisfies the integer contract -- integral weights and BSP
    parameters make the fused int32 window programs bit-identical to the
    float64 fronts here.  Anything else returns None and the caller keeps
    the numpy pricers.
    """
    if backend is None:
        from .partition_front import get_backend
        backend = get_backend()
    if backend != "jax":
        return None
    from ...kernels.front_pass import (DeviceScheduleWindows,
                                       schedule_device_supported)
    if not schedule_device_supported(sched):
        return None
    return DeviceScheduleWindows(sched)


def price_node_moves(sched: ScheduleState, v: int) -> np.ndarray:
    """Deltas of the compound node move ``v -> q`` for every q at once.

    Requires ``len(sched.assign[v]) == 1``.  Entry q equals
    ``sched.delta_node_move(v, q)`` bit-for-bit for every ``q != p``
    (entry p, the current processor, is 0 -- not a move).  Feasibility is
    ``node_move_targets``'s concern, mirroring the hill climber.
    """
    P = sched.inst.P
    (p, s), = sched.assign[v].items()
    dag = sched.inst.dag
    mu, om = dag.mu[v], dag.omega[v]
    allq = np.arange(P)
    D: dict[tuple[str, int], np.ndarray] = {}

    def dd(kind: str, t: int) -> np.ndarray:
        key = (kind, t)
        if key not in D:
            D[key] = np.zeros((P, P))
        return D[key]

    # outgoing comms retarget src p -> q (the one to q itself is dropped);
    # fill order mirrors ScheduleState._node_move_cells so every (q, proc)
    # slot accumulates its contributions in the same sequence
    for dst in sorted(sched.src_index.get((v, p), ())):
        _, t = sched.comms[(v, dst)]
        ds = dd("sent", t)
        ds[:, p] -= mu
        dd("recv", t)[dst, dst] -= mu
        keep = allq != dst
        ds[allq[keep], allq[keep]] += mu
    # an incoming comm to q is dropped (v becomes local there)
    for q in range(P):
        c0 = sched.comms.get((v, q))
        if c0 is not None and c0[0] != p:
            src0, t0 = c0
            dd("sent", t0)[q, src0] -= mu
            dd("recv", t0)[q, q] -= mu
    dw = dd("work", s)
    dw[:, p] -= om
    dw[allq, allq] += om
    # consumers left on p get one comm q -> p before their first use
    uses_p = sched.uses_on(v, p)
    if uses_p:
        tf = min(uses_p) - 1
        dd("sent", tf)[allq, allq] += mu
        dd("recv", tf)[:, p] += mu

    L, g = sched.inst.L, sched.inst.g
    zeros = np.zeros((P, P))
    deltas = np.zeros(P)
    for t in sorted({t for (_, t) in D}):
        assert t < sched.S, "node move cannot touch beyond the horizon"
        w1 = (np.asarray(sched.work[t])
              + D.get(("work", t), zeros)).max(axis=1)
        s1 = (np.asarray(sched.sent[t])
              + D.get(("sent", t), zeros)).max(axis=1)
        r1 = (np.asarray(sched.recv[t])
              + D.get(("recv", t), zeros)).max(axis=1)
        h = np.maximum(s1, r1)
        deltas += np.where(h > EPS, w1 + L + g * h, w1) - sched._scost[t]
    deltas[p] = 0.0
    return deltas


def price_comm_moves(sched: ScheduleState, v: int, dst: int,
                     ts) -> np.ndarray:
    """Deltas of moving comm ``(v, dst)`` to every superstep in ``ts``.

    Entry i equals ``sched.delta_move_comm(v, dst, ts[i])`` bit-for-bit:
    the removal delta at the current superstep is computed once (scalar),
    the insertion delta is evaluated against gathered top-2 triples for
    the whole window in one vectorized pass -- same ``max`` structure and
    float association as ``_comm_step_delta``.  All ``ts`` must be
    existing supersteps; entries equal to the current superstep price 0.
    The comm-rebalancing sweep calls this for long windows (the hot loop
    of multilevel refinement, where windows span the whole wavefront
    depth) and keeps the scalar path for short ones.
    """
    src, s = sched.comms[(v, dst)]
    mu = sched.inst.dag.mu[v]
    ts = np.asarray(ts, dtype=np.int64)
    d0 = sched._comm_step_delta(s, src, dst, -mu)
    st, rt, wt = sched._stop, sched._rtop, sched._wtop
    srow, rrow = sched.sent, sched.recv
    # alt = the max the changed entry competes against (``_kind_max_if``):
    # the runner-up when the entry IS the argmax, the leader otherwise
    s_alt = np.fromiter((st[t][2] if st[t][1] == src else st[t][0]
                         for t in ts), dtype=np.float64, count=len(ts))
    s_new = np.fromiter((srow[t][src] for t in ts), dtype=np.float64,
                        count=len(ts)) + mu
    r_alt = np.fromiter((rt[t][2] if rt[t][1] == dst else rt[t][0]
                         for t in ts), dtype=np.float64, count=len(ts))
    r_new = np.fromiter((rrow[t][dst] for t in ts), dtype=np.float64,
                        count=len(ts)) + mu
    w1 = np.fromiter((wt[t][0] for t in ts), dtype=np.float64,
                     count=len(ts))
    scost = np.fromiter((sched._scost[t] for t in ts), dtype=np.float64,
                        count=len(ts))
    h = np.maximum(np.maximum(s_alt, s_new), np.maximum(r_alt, r_new))
    L, g = sched.inst.L, sched.inst.g
    step = np.where(h > EPS, w1 + L + g * h, w1)
    deltas = d0 + (step - scost)
    deltas[ts == s] = 0.0
    return deltas


def price_comp_moves(sched: ScheduleState, v: int, p: int,
                     ts) -> np.ndarray:
    """Deltas of re-timing compute ``(v, p)`` to every superstep in ``ts``.

    Entry i equals ``sched._delta_cells([("work", s, p, -omega),
    ("work", ts[i], p, +omega)])`` bit-for-bit (the same two-cell fold the
    scalar compute-rebalancing trial prices): the removal delta at the
    current superstep is scalar, the insertion deltas are evaluated
    against gathered work top-2 triples in one pass.  Entries equal to
    the current superstep price 0.  Feasibility (parents present, uses
    not orphaned) is the caller's concern -- see
    ``list_sched.comp_rebalance_pass``.
    """
    s = sched.assign[v][p]
    om = sched.inst.dag.omega[v]
    ts = np.asarray(ts, dtype=np.int64)
    w1_minus = sched._kind_max_if("work", s, p, -om)
    d_s = sched._step_cost(w1_minus, sched.h_of(s)) - sched._scost[s]
    wt, wrow = sched._wtop, sched.work
    w_alt = np.fromiter((wt[t][2] if wt[t][1] == p else wt[t][0]
                         for t in ts), dtype=np.float64, count=len(ts))
    w_new = np.fromiter((wrow[t][p] for t in ts), dtype=np.float64,
                        count=len(ts)) + om
    w1 = np.maximum(w_alt, w_new)
    h = np.fromiter((max(sched._stop[t][0], sched._rtop[t][0])
                     for t in ts), dtype=np.float64, count=len(ts))
    scost = np.fromiter((sched._scost[t] for t in ts), dtype=np.float64,
                        count=len(ts))
    L, g = sched.inst.L, sched.inst.g
    step = np.where(h > EPS, w1 + L + g * h, w1)
    deltas = d_s + (step - scost)
    deltas[ts == s] = 0.0
    return deltas


def node_move_targets(sched: ScheduleState, v: int) -> list[bool]:
    """Feasible targets of the hill climber's node move, as P bools.

    Mirrors ``list_sched.try_node_move``'s guards: q must differ from the
    current processor, every parent must be present on q at v's superstep,
    and v must not be consumed on its current processor in that superstep
    (the replacement comm could not arrive in time).  Plain-python with
    early exits -- this runs once per node per pass, usually to say "no"
    (numpy dispatch here would dominate the whole pass).
    """
    P = sched.inst.P
    (p, s), = sched.assign[v].items()
    uses_p = sched.uses_on(v, p)
    if uses_p and min(uses_p) <= s:
        return [False] * P
    feas = [True] * P
    feas[p] = False
    alive = P - 1
    comms = sched.comms
    for u in sched.inst.dag.parents[v]:
        assign_u = sched.assign[u]
        for q in range(P):
            if not feas[q]:
                continue
            ss = assign_u.get(q)
            if ss is not None and ss <= s:
                continue
            c = comms.get((u, q))
            if c is None or c[1] >= s:
                feas[q] = False
                alive -= 1
        if not alive:
            break
    return feas


# --------------------------------------------------------------------------
# Superstep-replication front
# --------------------------------------------------------------------------

def sr_front(sched: ScheduleState, s: int) -> list[tuple[int, int, list[int]]]:
    """All non-empty SR candidates ``(p1, p2, nodes)`` of superstep s.

    One flat pass over the superstep's compute phase builds, per node, the
    processors it is *usable toward* (a child computed there or an onward
    send from there, minus processors it is already assigned to); the
    candidate list then reads off as the non-zero (p1, p2) combinations,
    in the deterministic lexicographic order both search paths share.
    ``nodes`` reproduces ``try_superstep_replication``'s eligibility
    filter exactly (sorted members of ``comp[s][p1]`` with a use on p2).
    """
    P = sched.inst.P
    entries: list[int] = []
    p1_of: list[int] = []
    for p1 in range(P):
        for v in sorted(sched.comp[s][p1]):
            entries.append(v)
            p1_of.append(p1)
    if not entries:
        return []
    assign = sched.assign
    children = sched.inst.dag.children
    src_index = sched.src_index
    U = np.zeros((len(entries), P), dtype=bool)
    for i, v in enumerate(entries):
        row = U[i]
        for c in children[v]:
            for pp in assign[c]:
                row[pp] = True
        for pp in range(P):
            if src_index.get((v, pp)):
                row[pp] = True
        for pp in assign[v]:
            row[pp] = False
    p1_arr = np.asarray(p1_of)
    front = []
    for p1 in range(P):
        idx = np.flatnonzero(p1_arr == p1)
        if not len(idx):
            continue
        nz = U[idx].any(axis=0)
        for p2 in range(P):
            if p2 == p1 or not nz[p2]:
                continue
            front.append((p1, p2, [entries[i] for i in idx if U[i, p2]]))
    return front


def price_superstep_replication(sched: ScheduleState, s: int, p1: int,
                                p2: int, nodes: list[int]) -> float | None:
    """Pure price of replicating ``nodes`` (from ``V_{p1,s}``) onto p2.

    Simulates the exact mutation sequence of the transactional trial --
    parent comms added at s-1, comms (v, p2) arriving at >= s dropped,
    replica compute added at (s, p2) -- without touching the schedule, and
    returns the cost delta *before* ``prune_useless_comms`` (which can
    only decrease it further, so an improving price implies an improving
    commit).  Returns None when some parent cannot be made present on p2
    (the trial would roll back).
    """
    dag = sched.inst.dag
    node_set = set(nodes)
    cells: list[tuple[str, int, int, float]] = []
    added_comp: set[int] = set()   # nodes virtually replicated at (p2, s)
    added_comm: set[int] = set()   # parents virtually comm'd to p2 at s-1
    for v in nodes:
        for u in dag.parents[v]:
            if (u in added_comp or u in added_comm
                    or sched.present_at(u, p2, s)):
                continue
            if u in node_set and sched.assign[u].get(p1) == s:
                continue  # replicated alongside
            cs_any = min(sched.assign[u].values())
            if (cs_any <= s - 1 and s - 1 >= 0
                    and (u, p2) not in sched.comms):
                src = min(sched.assign[u],
                          key=lambda p: (sched.assign[u][p], p))
                mu = dag.mu[u]
                cells.append(("sent", s - 1, src, mu))
                cells.append(("recv", s - 1, p2, mu))
                added_comm.add(u)
            else:
                return None
        c = sched.comms.get((v, p2))
        if c is not None and c[1] >= s:  # arrives later than the replica
            src0, t0 = c
            mu = dag.mu[v]
            cells.append(("sent", t0, src0, -mu))
            cells.append(("recv", t0, p2, -mu))
        cells.append(("work", s, p2, dag.omega[v]))
        added_comp.add(v)
    return sched._delta_cells(cells)


def apply_sr_mutations(sched, s: int, p1: int, p2: int,
                       nodes: list[int]) -> bool:
    """The SR mutation sequence (no prune): parent comms at s-1, late
    comms (v, p2) dropped, replica compute added at (s, p2).

    Single home of the sequence, shared by the engine commit below and the
    ``reference.py`` oracle (it only touches the mutation API the two
    schedule classes have in common); ``price_superstep_replication``'s
    pure simulation must mirror it cell-for-cell.  Returns False when some
    parent cannot be made present (caller rolls back / discards).
    """
    node_set = set(nodes)
    for v in nodes:
        # parents must be present on p2 by superstep s
        for u in sched.inst.dag.parents[v]:
            if sched.present_at(u, p2, s):
                continue
            if u in node_set and sched.assign[u].get(p1) == s:
                continue  # replicated alongside
            cs_any = min(sched.assign[u].values())
            if cs_any <= s - 1 and s - 1 >= 0 and (u, p2) not in sched.comms:
                src = min(sched.assign[u],
                          key=lambda p: (sched.assign[u][p], p))
                sched.add_comm(u, src, p2, s - 1)
            else:
                return False
        if (v, p2) in sched.comms and sched.comms[(v, p2)][1] >= s:
            sched.remove_comm(v, p2)  # arrives later than the replica
        sched.add_comp(v, p2, s)
    return True


# --------------------------------------------------------------------------
# Superstep-merging front
# --------------------------------------------------------------------------

def _ensure_present_for_merge(sched, v: int, dst: int, s: int) -> bool:
    """Make value v usable on dst within merged superstep s, replicating
    recursively when the producer sits in superstep s itself (paper SM).
    Mutates sched; returns False if impossible (caller rolls back).

    Single home of the recursion, shared by the engine commit, the
    ``reference.py`` oracle and -- cell-for-cell -- the pure pricing
    simulation below (``_MergeSim`` implements the same mutation API).
    """
    if sched.present_at(v, dst, s):
        return True
    cs_any = min(sched.assign[v].values())
    if cs_any <= s - 1 and s - 1 >= 0 and (v, dst) not in sched.comms:
        src = min(sched.assign[v],
                  key=lambda p: (sched.assign[v][p], p))
        sched.add_comm(v, src, dst, s - 1)
        return True
    # must replicate v on dst at superstep s -> parents must be available too
    if dst in sched.assign[v]:
        return False  # computed later on dst; moving it up is out of scope
    for u in sched.inst.dag.parents[v]:
        if not _ensure_present_for_merge(sched, u, dst, s):
            return False
    sched.add_comp(v, dst, s)
    return True


def apply_sm_mutations(sched, s: int, comms_at=None) -> bool:
    """The SM mutation sequence (no prune): comms at s used at s+1 move to
    s-1 or are replaced by recursive replication, compute and comms of
    s+1 shift into s.

    Single home of the sequence, shared by the engine commit, the
    ``reference.py`` oracle (mutation API only) and the pure pricing
    simulation (a ``_MergeSim`` quacks like a schedule).  Returns False
    when the merge is infeasible (caller rolls back / discards).

    ``comms_at`` optionally supplies the two sorted comm snapshots
    ``(at s, at s+1)`` so a pricing sweep can sort the comm dict once per
    round instead of once per candidate.  This is exactly the iteration
    the inline sort produces: the s+1 snapshot stays valid throughout
    because the earlier steps only remove/move comms scheduled *at s* and
    only add comms at s-1.
    """
    P = sched.inst.P
    if comms_at is None:
        snap = sorted(sched.comms.items())
        at_s = [kv for kv in snap if kv[1][1] == s]
        at_s1 = [kv for kv in snap if kv[1][1] == s + 1]
    else:
        at_s, at_s1 = comms_at
    for (v, dst), (src, t) in at_s:
        uses = [x for x in sched.uses_on(v, dst)
                if x > t and not sched.compute_sstep(v, dst) <= x]
        if not uses or min(uses) > s + 1:
            continue  # stays in merged superstep, delivers for >= s+2
        if sched.assign[v].get(src, INF) <= s - 1 and s - 1 >= 0:
            sched.move_comm(v, dst, s - 1)
            continue
        # replicate v (and recursively its parents) on dst
        sched.remove_comm(v, dst)
        if not _ensure_present_for_merge(sched, v, dst, s):
            return False
    # move compute s+1 -> s.  A pricing sim aggregates the whole shift
    # into per-processor work transfers (``shift_comp_bulk``): nothing
    # after this step reads assignments, and the per-node infeasibility
    # guard below cannot fire (``_ensure_present_for_merge`` refuses to
    # replicate onto a processor the value is already assigned to).
    shift = getattr(sched, "shift_comp_bulk", None)
    if shift is not None:
        shift(s)
    else:
        for p in range(P):
            for v in sorted(sched.comp[s + 1][p]):
                sched.remove_comp(v, p)
                if p in sched.assign[v]:
                    return False  # already replicated there during merge
                sched.add_comp(v, p, s)
    # move comms at s+1 -> s
    for (v, dst), _ in at_s1:
        sched.move_comm(v, dst, s)
    return True


class _CowComms:
    """Copy-on-write view of a comm dict: reads fall through to the base,
    writes land in an overlay (None = removed).  Supports exactly the
    operations the SM/split sequences perform -- ``get`` / ``in`` / ``[]``
    / ``pop`` / ``[] =`` -- so building a pricing sim is O(1) instead of
    O(comms).  ``map_base`` (optional) is applied to base *values* on
    read: the split sim renumbers base comm positions into post-shift
    coordinates without materializing anything."""

    __slots__ = ("base", "over", "map_base")

    def __init__(self, base: dict, map_base=None) -> None:
        self.base = base
        self.over: dict = {}
        self.map_base = map_base

    def get(self, k, default=None):
        if k in self.over:
            v = self.over[k]
            return default if v is None else v
        v = self.base.get(k)
        if v is None:
            return default
        return v if self.map_base is None else self.map_base(v)

    def __contains__(self, k) -> bool:
        return self.get(k) is not None

    def __getitem__(self, k):
        v = self.get(k)
        if v is None:
            raise KeyError(k)
        return v

    def __setitem__(self, k, v) -> None:
        self.over[k] = v

    def pop(self, k):
        v = self[k]
        self.over[k] = None
        return v

    def items(self):
        mb = self.map_base
        for k, v in self.base.items():
            if k not in self.over:
                yield k, (v if mb is None else mb(v))
        for k, v in self.over.items():
            if v is not None:
                yield k, v


class _MergeSim:
    """Virtual overlay over a ``ScheduleState`` exposing exactly the reads
    and mutations ``apply_sm_mutations`` performs, without touching the
    real schedule.  Mutations accumulate cost cells instead; the price is
    ``base._delta_cells(cells)`` at the end.

    Only the members the SM sequence uses are implemented: ``comms`` /
    ``assign`` (merged dict views), ``comp`` (base -- the sequence never
    revisits a phase it mutates), ``uses_on`` / ``compute_sstep`` /
    ``present_at``, and the four mutation primitives.
    """

    def __init__(self, base: ScheduleState) -> None:
        self.base = base
        self.inst = base.inst
        self.comp = base.comp          # never mutated during pricing
        self.cells: list[tuple[str, int, int, float]] = []
        self.comms = _CowComms(base.comms)
        self._assign: dict[int, dict[int, int]] = {}   # copy-on-write
        self._src: dict[tuple[int, int], set[int]] = {}

    # ------------------------------------------------------------- views
    @property
    def assign(self):
        return self

    def __getitem__(self, v: int) -> dict[int, int]:
        # self.assign[v] -- copy-on-write per node
        got = self._assign.get(v)
        if got is None:
            got = dict(self.base.assign[v])
            self._assign[v] = got
        return got

    def _src_set(self, v: int, src: int) -> set[int]:
        key = (v, src)
        got = self._src.get(key)
        if got is None:
            got = set(self.base.src_index.get(key, ()))
            self._src[key] = got
        return got

    def compute_sstep(self, v: int, p: int) -> float:
        return self[v].get(p, INF)

    def recv_sstep(self, v: int, p: int) -> float:
        c = self.comms.get((v, p))
        return c[1] if c is not None else INF

    def present_at(self, v: int, p: int, s: int) -> bool:
        return self.compute_sstep(v, p) <= s or self.recv_sstep(v, p) < s

    def uses_on(self, v: int, p: int) -> list[int]:
        out = []
        for c in self.inst.dag.children[v]:
            t = self[c].get(p)
            if t is not None:
                out.append(t)
        for dst in self._src_set(v, p):
            out.append(self.comms[(v, dst)][1])
        return sorted(out)

    # --------------------------------------------------------- mutations
    def add_comp(self, v: int, p: int, s: int) -> None:
        assert p not in self[v]
        self[v][p] = s
        self.cells.append(("work", s, p, self.inst.dag.omega[v]))

    def remove_comp(self, v: int, p: int) -> None:
        s = self[v].pop(p)
        self.cells.append(("work", s, p, -self.inst.dag.omega[v]))

    def add_comm(self, v: int, src: int, dst: int, s: int) -> None:
        assert (v, dst) not in self.comms
        self.comms[(v, dst)] = (src, s)
        self._src_set(v, src).add(dst)
        mu = self.inst.dag.mu[v]
        self.cells.append(("sent", s, src, mu))
        self.cells.append(("recv", s, dst, mu))

    def remove_comm(self, v: int, dst: int) -> None:
        src, s = self.comms.pop((v, dst))
        self._src_set(v, src).discard(dst)
        mu = self.inst.dag.mu[v]
        self.cells.append(("sent", s, src, -mu))
        self.cells.append(("recv", s, dst, -mu))

    def move_comm(self, v: int, dst: int, new_s: int) -> None:
        src, _ = self.comms[(v, dst)]
        self.remove_comm(v, dst)
        self.add_comm(v, src, dst, new_s)

    def shift_comp_bulk(self, s: int) -> None:
        """Aggregate the s+1 -> s compute shift: the work row at s+1 *is*
        the per-processor omega sum of ``comp[s + 1]``, so the whole step
        collapses into P cell transfers (step 1 never touches row s+1)."""
        row = self.base.work[s + 1]
        for p in range(self.inst.P):
            w = row[p]
            if w:
                self.cells.append(("work", s + 1, p, -w))
                self.cells.append(("work", s, p, w))


def sm_front(sched: ScheduleState) -> list[int]:
    """All SM candidates: merge s+1 into s for every adjacent pair."""
    return list(range(sched.S - 1))


def price_superstep_merge(sched: ScheduleState, s: int,
                          comms_at=None) -> float | None:
    """Pure price of merging superstep s+1 into s.

    Replays ``apply_sm_mutations`` against a virtual overlay, so the real
    schedule (and its undo log) is never touched; returns the *pre-prune*
    cost delta -- the quantity both search paths rank winners by; pruning
    after a commit only lowers it further -- or None when the merge is
    infeasible (the transactional trial would roll back).  ``comms_at``
    forwards the pre-sorted per-superstep comm snapshots (see
    ``apply_sm_mutations``).
    """
    if s + 1 >= sched.S:
        return None
    sim = _MergeSim(sched)
    if not apply_sm_mutations(sim, s, comms_at):
        return None
    return sched._delta_cells(sim.cells)


def commit_superstep_merge(sched: ScheduleState, s: int) -> None:
    """Replay a priced SM winner through the transaction machinery, then
    prune (the commit is never worse than its price) and compact."""
    sched.begin()
    try:
        if not apply_sm_mutations(sched, s):
            raise RuntimeError("priced SM became infeasible at commit")
        sched.prune_useless_comms()
    except BaseException:
        sched.rollback()
        raise
    sched.commit()
    sched.compact()


def commit_superstep_replication(sched: ScheduleState, s: int, p1: int,
                                 p2: int, nodes: list[int]) -> None:
    """Replay a priced SR winner through the transaction machinery.

    Performs exactly the mutations ``price_superstep_replication``
    simulated (feasibility was established there), then prunes; a
    surprise infeasibility or mid-commit failure rolls the transaction
    back before re-raising, so the schedule is never left corrupted.
    """
    sched.begin()
    try:
        if not apply_sr_mutations(sched, s, p1, p2, nodes):
            raise RuntimeError("priced SR became infeasible at commit")
        sched.prune_useless_comms()
    except BaseException:
        sched.rollback()
        raise
    sched.commit()


# --------------------------------------------------------------------------
# Superstep-split front (inverse of SM)
# --------------------------------------------------------------------------

class _SplitSim:
    """Virtual overlay over a ``ScheduleState`` exposing exactly the reads
    and mutations ``apply_split_mutations`` performs, without touching the
    real schedule.  Mutations accumulate cost cells; the price is
    ``base._delta_cells(cells)`` at the end.

    The sim lives in *post-split* coordinates once ``shift_tail_bulk`` has
    run: base positions ``t > s`` read as ``t + 1`` (comms through the
    ``_CowComms`` value map, assignments through the lazy copy-on-write
    dicts -- every assign read in the split sequence happens post-shift).
    Cells map post positions back onto base rows: ``t <= s`` hits row t,
    the inserted superstep ``s + 1`` hits the virtual row at ``base.S``
    (``_delta_cells`` folds everything at exactly that index into one
    all-zero row -- the correct model of the one new superstep), and
    ``t >= s + 2`` hits base row ``t - 1`` -- the row whose content it is
    after the pure renumbering.  The renumbering itself moves no load
    between rows, so ``shift_tail_bulk`` emits **no** cells: the tail
    shift prices to exactly zero, by construction rather than by O(S * P)
    transfer pairs.  Single-use: one sim per priced candidate.
    """

    def __init__(self, base: ScheduleState, s: int) -> None:
        self.base = base
        self.inst = base.inst
        self.S = base.S
        self.cells: list[tuple[str, int, int, float]] = []
        self._split = s
        self._shifted = False
        self.comms = _CowComms(base.comms, map_base=self._map_comm)
        self._assign: dict[int, dict[int, int]] = {}   # copy-on-write

    def _map_comm(self, val):
        src, t = val
        if self._shifted and t > self._split:
            return (src, t + 1)
        return val

    def _cell_s(self, t: int) -> int:
        """Base row a mutation at post-shift position t lands on."""
        if not self._shifted or t <= self._split:
            return t
        if t == self._split + 1:
            return self.base.S   # the inserted superstep: virtual row
        return t - 1

    # ------------------------------------------------------------- views
    @property
    def assign(self):
        return self

    def __getitem__(self, v: int) -> dict[int, int]:
        got = self._assign.get(v)
        if got is None:
            base = self.base.assign[v]
            if self._shifted:
                sp = self._split
                got = {p: (t + 1 if t > sp else t) for p, t in base.items()}
            else:
                got = dict(base)
            self._assign[v] = got
        return got

    # --------------------------------------------------------- mutations
    def shift_tail_bulk(self, s: int) -> None:
        assert s == self._split and not self._shifted
        self._shifted = True
        self.S += 1

    def add_comp(self, v: int, p: int, t: int) -> None:
        av = self[v]
        assert p not in av
        av[p] = t
        self.cells.append(("work", self._cell_s(t), p,
                           self.inst.dag.omega[v]))

    def remove_comp(self, v: int, p: int) -> None:
        t = self[v].pop(p)
        self.cells.append(("work", self._cell_s(t), p,
                           -self.inst.dag.omega[v]))

    def add_comm(self, v: int, src: int, dst: int, t: int) -> None:
        assert (v, dst) not in self.comms
        self.comms[(v, dst)] = (src, t)
        mu = self.inst.dag.mu[v]
        cs = self._cell_s(t)
        self.cells.append(("sent", cs, src, mu))
        self.cells.append(("recv", cs, dst, mu))

    def remove_comm(self, v: int, dst: int) -> None:
        src, t = self.comms.pop((v, dst))
        mu = self.inst.dag.mu[v]
        cs = self._cell_s(t)
        self.cells.append(("sent", cs, src, -mu))
        self.cells.append(("recv", cs, dst, -mu))


def split_front(sched: ScheduleState, s: int, level,
                max_candidates: int = 8) -> list[tuple[int, list]]:
    """Candidate bipartitions of superstep s's compute phase, as
    ``(cut_level, late)`` pairs in ascending cut order.

    Cut points are the distinct topological levels present in the phase
    (``level`` from ``list_sched.dag_levels``): candidate ``cut`` delays
    every ``(node, proc)`` entry whose node sits at level >= cut into the
    new superstep.  Cutting by level guarantees structural feasibility --
    an edge u -> c inside the phase implies ``level[c] > level[u]``, so a
    delayed parent's children delay with it, and every replica of a
    delayed node delays together.  With more than ``max_candidates``
    distinct cut levels a deterministic evenly-spaced subset is priced
    (the front stays bounded per superstep; no RNG, so engine and oracle
    enumerate identically).  ``late`` is sorted -- the shared mutation
    order of ``apply_split_mutations``.
    """
    P = sched.inst.P
    members = [(level[v], v, p)
               for p in range(P) for v in sched.comp[s][p]]
    lvls = sorted({l for (l, _v, _p) in members})
    if len(lvls) < 2:
        return []
    cuts = lvls[1:]
    k = len(cuts)
    if k > max_candidates:
        idxs = sorted({(i * (k - 1)) // (max_candidates - 1)
                       for i in range(max_candidates)})
        cuts = [cuts[i] for i in idxs]
    front = []
    for cut in cuts:
        late = sorted((v, p) for (l, v, p) in members if l >= cut)
        front.append((cut, late))
    return front


def price_superstep_split(sched: ScheduleState, s: int, late,
                          pre=None) -> float | None:
    """Pure price of splitting superstep s's compute phase (``late`` pairs
    delay into a new superstep ``s + 1``).

    Replays ``apply_split_mutations`` against a virtual overlay, so the
    real schedule (and its undo log) is never touched; returns the
    *pre-prune* cost delta -- the quantity the winner rule ranks by;
    pruning after a commit only lowers it further -- or None when some
    re-derived comm cannot reach a consumer in time (the transactional
    trial would roll back).  ``pre`` forwards the sorted pre-mutation comm
    snapshot (see ``apply_split_mutations``); on integer weights the price
    equals the transactional replay's cost change bit-for-bit, the same
    contract as ``price_superstep_merge``.
    """
    from ..schedule.engine import apply_split_mutations
    sim = _SplitSim(sched, s)
    if not apply_split_mutations(sim, s, late, pre):
        return None
    return sched._delta_cells(sim.cells)


def commit_superstep_split(sched: ScheduleState, s: int, late) -> None:
    """Replay a priced split winner through the transaction machinery,
    then prune (the commit is never worse than its price) and compact --
    so superstep indices never drift from the oracle's."""
    from ..schedule.engine import apply_split_mutations
    sched.begin()
    try:
        if not apply_split_mutations(sched, s, late):
            raise RuntimeError("priced split became infeasible at commit")
        sched.prune_useless_comms()
    except BaseException:
        sched.rollback()
        raise
    sched.commit()
    sched.compact()
