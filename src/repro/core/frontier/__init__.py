"""Frontier-pricing layer: batched candidate-front evaluation (PR 3).

Both search stacks price enormous numbers of candidate moves; PR 1/PR 2
made each *single* pricing incremental, this package makes whole *fronts*
of candidates one vectorized evaluation:

  * ``partition_front`` -- ragged batched gain evaluation over the CSR
    arrays of a ``PartitionState`` (NumPy backend, always available, and a
    JAX/Pallas backend via ``repro.kernels.gain``), plus the ``GainCache``
    that makes FM / replication passes output-sensitive (only nodes whose
    gain changed are repriced);
  * ``schedule_front`` -- batched node-move pricing and superstep-
    replication front enumeration + pure pricing against flat
    per-superstep load arrays of a ``ScheduleState``.

Pricing here is *bit-equal* to the scalar engine deltas
(``PartitionState.delta_masks`` / ``ScheduleState.delta_node_move``); the
heuristics keep their exact decision rules, so refactoring onto this layer
changes wall-clock, not results (the one deliberate exception is the SR
pass's commit-the-winner rule, applied to engine and oracle in lockstep).
"""
from .partition_front import (GainCache, add_replica_candidates,
                              connected_add_candidates, connected_targets,
                              device_pass, fm_move_candidates, get_backend,
                              lookahead_window, move_candidates,
                              price_mask_front, refresh_boundary_window,
                              set_backend)
from .schedule_front import (apply_sm_mutations, apply_sr_mutations,
                             commit_superstep_merge,
                             commit_superstep_replication,
                             commit_superstep_split, device_windows,
                             node_move_targets, price_comm_moves,
                             price_comp_moves, price_node_moves,
                             price_superstep_merge,
                             price_superstep_replication,
                             price_superstep_split, sm_front, split_front,
                             sr_front)

__all__ = [
    "GainCache", "add_replica_candidates", "connected_add_candidates",
    "connected_targets", "device_pass", "fm_move_candidates", "get_backend",
    "lookahead_window", "move_candidates", "price_mask_front",
    "refresh_boundary_window", "set_backend",
    "apply_sm_mutations", "apply_sr_mutations", "commit_superstep_merge",
    "commit_superstep_replication", "commit_superstep_split",
    "device_windows", "node_move_targets",
    "price_comm_moves", "price_comp_moves", "price_node_moves",
    "price_superstep_merge", "price_superstep_replication",
    "price_superstep_split", "sm_front", "split_front", "sr_front",
]
