"""Batched gain evaluation for the partition engine (frontier layer).

``price_mask_front`` evaluates a *ragged front* of candidate masks -- node
``vs[i]`` with candidates ``cands[xcand[i]:xcand[i+1]]`` -- in one
vectorized pass over the engine's CSR state, returning exactly what
``PartitionState.delta_masks`` would return per node, bit-for-bit: the
per-(candidate, edge) cost terms are summed sequentially in edge order
(``np.bincount``), the same reduction the engine uses, so a front of one
node and a front of a thousand produce identical floats.

Two interchangeable lambda backends (selected per call or via
``set_backend``):

  * ``"numpy"`` (default): ``engine._lambda_from_rows`` -- a single
    argmax over the popcount-ordered subset columns;
  * ``"jax"``: ``repro.kernels.gain.min_cover_lambdas`` -- the same
    reduction as a Pallas TPU kernel (jnp fallback off-TPU), dispatched
    like ``kernels/ops.py``.  Lambdas are small integers, so both
    backends feed identical values into the (float64, NumPy) cost
    reduction -- bit-equality holds across backends too.

``GainCache`` sits on top: it memoizes each node's candidate deltas and
invalidates through the pin-adjacency on every applied move, so FM-style
passes reprice only nodes whose gain actually changed (output-sensitive)
and reprice them in batched fronts instead of one engine call per node.

PR 4 (multilevel) additions, all decision-identical and shared with the
flat heuristics: ``connected_targets`` restricts candidate fronts to
processors that appear in another pin of a shared edge (moves toward
unconnected processors provably cannot strictly improve), front pricing
exploits the single-pin-change lambda bound (``_bounded_lambdas``: only
popcount classes ``lambda_old +- 1`` can hold the first zero cover), and
``lookahead_window`` adapts the GainCache scan window to the instance's
degree so dense coarse levels do not thrash the cache.
"""
from __future__ import annotations

import numpy as np

from ..partition.engine import PartitionState, _lambda_from_rows

_BACKEND = "numpy"

# cap on the (rows x 2^P) scratch of one evaluation chunk (elements);
# fronts beyond it are split on candidate boundaries, which cannot change
# any per-candidate sum
_CHUNK_ELEMS = 4_000_000

# the jax backend only pays for itself on big fronts: below this row count
# device dispatch dominates and the numpy reduction runs instead (the two
# produce bit-identical lambdas, so this is a pure scheduling choice)
_JAX_MIN_ROWS = 4096


def set_backend(backend: str) -> None:
    """Select the default lambda backend: ``"numpy"`` or ``"jax"``."""
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown frontier backend {backend!r}")
    global _BACKEND
    _BACKEND = backend


def get_backend() -> str:
    return _BACKEND


def device_pass(state: PartitionState, cap: float, backend: str | None = None,
                **kw):
    """Device-resident whole-pass runner for the jax backend, or None.

    The PR 3 jax path ships one front to the device per priced node; the
    PR 6 device-resident path (``kernels.front_pass``) keeps the engine
    state on device for an entire refinement pass with one host sync per
    committed move.  Dispatch mirrors ``_lambdas``: the explicit
    ``frontier=`` argument wins, else the module default backend; anything
    but ``"jax"`` -- or an instance the device pass cannot hold
    bit-identically (too small, non-integer mu, unassigned nodes, no jax)
    -- returns None and the caller keeps the numpy front path.
    """
    if backend is None:
        backend = _BACKEND
    if backend != "jax":
        return None
    from ...kernels.front_pass import attach
    return attach(state, cap, **kw)


def _ragged_gather(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Flat indices for concatenating ``arr[starts[i]:starts[i]+lens[i]]``."""
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    off = np.repeat(np.cumsum(lens) - lens, lens)
    return np.repeat(starts, lens) + (np.arange(total, dtype=np.int64) - off)


def _lambdas(rows: np.ndarray, state: PartitionState, backend: str) -> np.ndarray:
    if backend == "jax" and rows.shape[0] >= _JAX_MIN_ROWS:
        from ...kernels import gain
        return gain.min_cover_lambdas(rows, state._order, state._order_pc)
    return _lambda_from_rows(rows, state._order, state._order_pc)


def price_mask_front(state: PartitionState, vs: np.ndarray, cands: np.ndarray,
                     xcand: np.ndarray, backend: str | None = None) -> np.ndarray:
    """Cost deltas for a ragged candidate front, one vectorized pass.

    ``vs[i]`` gets candidates ``cands[xcand[i]:xcand[i+1]]``; the result is
    the flat float64 array equal (bit-for-bit) to concatenating
    ``state.delta_masks(vs[i], cands[xcand[i]:xcand[i+1]])`` per node.
    Requires the numpy engine backend (the python backend has no uncov
    matrix to batch over).
    """
    if state.backend != "numpy":
        raise ValueError("price_mask_front needs a numpy-backend PartitionState")
    backend = backend or _BACKEND
    vs = np.asarray(vs, dtype=np.int64)
    cands = np.asarray(cands, dtype=np.int64)
    xcand = np.asarray(xcand, dtype=np.int64)
    C = len(cands)
    out = np.zeros(C, dtype=np.float64)
    if C == 0 or len(vs) == 0:
        return out
    K = np.diff(xcand)                       # candidates per node
    node_of_pair = np.repeat(np.arange(len(vs), dtype=np.int64), K)
    deg = state.xinc[vs + 1] - state.xinc[vs]
    deg_of_pair = deg[node_of_pair]
    # rows for pair (i, c): uncov[e] + contrib[c] - contrib[old_i], for each
    # incident edge e of vs[i] -- contiguous per pair, edges in CSR order
    edge_rep = state.inc_edges[
        _ragged_gather(state.xinc[vs][node_of_pair], deg_of_pair)]
    old_rows = np.repeat(state.masks[vs][node_of_pair], deg_of_pair)
    cand_rows = np.repeat(cands, deg_of_pair)
    pair_ids = np.repeat(np.arange(C, dtype=np.int64), deg_of_pair)
    nsub = state._contrib.shape[0]
    chunk_rows = max(_CHUNK_ELEMS // nsub, 1)
    R = len(edge_rep)
    lam_old_all = state.edge_lambda[edge_rep]
    base_lam = np.maximum(lam_old_all.astype(np.float64) - 1, 0)
    order, order_pc = state._order, state._order_pc
    # popcount-class boundaries inside ``order`` (classes 1..P)
    bounds = np.searchsorted(order_pc, np.arange(int(order_pc[-1]) + 2))
    lo = 0
    while lo < R:
        hi = min(lo + chunk_rows, R)
        # never split a pair across chunks (the bincount below must see a
        # pair's terms in one sequential run)
        while hi < R and pair_ids[hi] == pair_ids[hi - 1]:
            hi += 1
        if nsub <= 64 or (backend == "jax" and hi - lo >= _JAX_MIN_ROWS):
            # small tables (P <= 6): the one-shot scan beats the grouped
            # bounded scan; jax: the device kernel takes full uncov rows.
            # Both produce bit-equal lambdas.
            rows = (state.uncov[edge_rep[lo:hi]]
                    + state._contrib[cand_rows[lo:hi]]
                    - state._contrib[old_rows[lo:hi]])
            lam = _lambdas(rows, state, backend)
        else:
            lam = _bounded_lambdas(state, edge_rep[lo:hi],
                                   cand_rows[lo:hi], old_rows[lo:hi],
                                   lam_old_all[lo:hi], order, bounds)
        terms = ((np.maximum(lam.astype(np.float64) - 1, 0) - base_lam[lo:hi])
                 * state.mu[edge_rep[lo:hi]])
        out += np.bincount(pair_ids[lo:hi], weights=terms, minlength=C)
        lo = hi
    return out


def _bounded_lambdas(state: PartitionState, er: np.ndarray,
                     cand: np.ndarray, old: np.ndarray,
                     lam_old: np.ndarray, order: np.ndarray,
                     bounds: np.ndarray) -> np.ndarray:
    """Candidate-row lambdas using the single-pin-change bound.

    Every front row is ``uncov[e]`` with exactly one pin's mask changed,
    and a one-pin change moves an edge's min cover by at most one:
    re-adding the pin to any cover of the remaining pins costs at most one
    extra processor (so ``lam_new <= lam_old + 1`` and, symmetrically,
    ``lam_old <= lam_new + 1``).  Only the popcount classes
    ``[lam_old - 1, lam_old + 1]`` of the subset order can therefore hold
    the first zero, so per ``lam_old`` group at most three classes are
    scanned (column 0 settles the no-assigned-pin case) -- identical
    integers to the full 2^P scan at a fraction of the work.
    """
    n_rows = len(er)
    lam = np.zeros(n_rows, dtype=np.int16)
    if n_rows == 0:
        return lam
    P_max = int(state._order_pc[-1])
    rows = state.uncov[er] + state._contrib[cand] - state._contrib[old]
    for k in np.unique(lam_old):
        idx = np.flatnonzero(lam_old == k)
        rem = idx
        for pc in range(max(int(k) - 1, 1), min(int(k) + 1, P_max) + 1):
            cols = order[bounds[pc]:bounds[pc + 1]]
            hit = (rows[np.ix_(rem, cols)] == 0).any(axis=1)
            lam[rem[hit]] = pc
            rem = rem[~hit]
            if not len(rem):
                break
        # rows still unresolved lost their last assigned pin (lambda 0)
    lam[rows[:, 0] == 0] = 0
    return lam


# --------------------------------------------------------------------------
# Candidate builders (vectorized): masks per node, ascending processor order
# --------------------------------------------------------------------------

def connected_targets(state: PartitionState, vs: np.ndarray) -> np.ndarray:
    """(len(vs), P) bools: q appears in another pin of an edge of ``vs[i]``.

    ``uncov[e, 0] > uncov[e, 1 << q]`` says some assigned pin of e carries
    q; for candidate processors (q outside the node's own mask) that pin
    is necessarily another node.  A mask change toward an *unconnected* q
    can never strictly improve: a cover of the changed edge that beats the
    old lambda would have to avoid the node's old mask entirely and enter
    through q, which costs a full extra processor unless q already hits
    some other pin.  Restricting candidate fronts to connected targets is
    therefore decision-identical and shrinks the priced volume by ~P/deg
    of the cut (pinned by ``tests/test_multilevel.py``).
    """
    P = state.P
    vs = np.asarray(vs, dtype=np.int64)
    out = np.zeros((len(vs), P), dtype=bool)
    if len(vs) == 0:
        return out
    deg = state.xinc[vs + 1] - state.xinc[vs]
    edges_rep = state.inc_edges[_ragged_gather(state.xinc[vs], deg)]
    if len(edges_rep) == 0:
        return out
    cols = np.concatenate(([0], np.int64(1) << np.arange(P, dtype=np.int64)))
    # outer-product gather: only the P+1 needed columns, never the full
    # (rows, 2^P) intermediate
    sub = state.uncov[edges_rep[:, None], cols[None, :]]
    haveq = sub[:, 1:] < sub[:, :1]
    nz = deg > 0
    starts = np.cumsum(deg) - deg
    out[nz] = np.logical_or.reduceat(haveq, starts[nz], axis=0)
    return out


def fm_move_candidates(state: PartitionState, vs: np.ndarray):
    """``move_candidates`` restricted to connected targets (the FM default
    builder): same ascending-q order, same deltas for every emitted
    candidate, decision-identical to the unrestricted front because every
    dropped candidate's delta is provably >= 0."""
    P = state.P
    vs = np.asarray(vs, dtype=np.int64)
    prim = np.zeros(len(vs), dtype=np.int64)
    m = state.masks[vs].copy()
    while np.any(m > 1):                      # primary = highest set bit
        gt = m > 1
        prim[gt] += 1
        m[gt] >>= 1
    targets = np.arange(P, dtype=np.int64)
    keep = (targets[None, :] != prim[:, None]) & connected_targets(state, vs)
    cands = np.broadcast_to(np.int64(1) << targets, (len(vs), P))[keep]
    xcand = np.zeros(len(vs) + 1, dtype=np.int64)
    np.cumsum(keep.sum(axis=1), out=xcand[1:])
    return cands, xcand


def move_candidates(state: PartitionState, vs: np.ndarray):
    """FM move front: for each single-assignment node, masks ``1 << q`` for
    every q except the current primary, ascending q (the deterministic
    tie-break order, see ``heuristic._fm_refine``)."""
    P = state.P
    vs = np.asarray(vs, dtype=np.int64)
    prim = np.zeros(len(vs), dtype=np.int64)
    m = state.masks[vs].copy()
    while np.any(m > 1):                      # primary = highest set bit
        gt = m > 1
        prim[gt] += 1
        m[gt] >>= 1
    targets = np.arange(P, dtype=np.int64)
    keep = targets[None, :] != prim[:, None]
    cands = np.broadcast_to(np.int64(1) << targets, (len(vs), P))[keep]
    xcand = np.zeros(len(vs) + 1, dtype=np.int64)
    np.cumsum(keep.sum(axis=1), out=xcand[1:])
    return cands, xcand


def add_replica_candidates(state: PartitionState, vs: np.ndarray):
    """Replication front: ``mask | (1 << q)`` for every unset q, ascending
    q -- the candidate order of ``replicate_local_search``'s add step."""
    P = state.P
    vs = np.asarray(vs, dtype=np.int64)
    m = state.masks[vs]
    targets = np.arange(P, dtype=np.int64)
    unset = (m[:, None] >> targets[None, :]) & 1 == 0
    cands = (m[:, None] | (np.int64(1) << targets)[None, :])[unset]
    xcand = np.zeros(len(vs) + 1, dtype=np.int64)
    np.cumsum(unset.sum(axis=1), out=xcand[1:])
    return cands, xcand


def connected_add_candidates(state: PartitionState, vs: np.ndarray):
    """``add_replica_candidates`` restricted to connected targets (the
    replication default builder): an added replica lowers some lambda only
    when the new processor already appears in another pin of a shared
    edge, so dropping unconnected targets is decision-identical."""
    P = state.P
    vs = np.asarray(vs, dtype=np.int64)
    m = state.masks[vs]
    targets = np.arange(P, dtype=np.int64)
    keep = (((m[:, None] >> targets[None, :]) & 1) == 0) \
        & connected_targets(state, vs)
    cands = (m[:, None] | (np.int64(1) << targets)[None, :])[keep]
    xcand = np.zeros(len(vs) + 1, dtype=np.int64)
    np.cumsum(keep.sum(axis=1), out=xcand[1:])
    return cands, xcand


class GainCache:
    """Output-sensitive per-node candidate deltas over a ``PartitionState``.

    ``cands_builder(state, vs) -> (cands, xcand)`` defines the (ordered)
    candidate rule; ``get(v)`` returns that node's ``(cands, deltas)``
    exactly as a fresh ``state.delta_masks`` call would produce them.  A
    node's entry goes stale only when the uncov row of one of its incident
    edges changes, i.e. when a node sharing a hyperedge with it (or the
    node itself) is re-assigned -- ``invalidate_move`` marks exactly that
    pin-adjacency set.  ``refresh_dirty`` reprices every stale node in one
    batched front, so a full FM pass touches clean nodes for free.
    """

    def __init__(self, state: PartitionState, cands_builder,
                 backend: str | None = None) -> None:
        self.state = state
        self.cands_builder = cands_builder
        self.backend = backend
        n = state.hg.n
        self._dirty = np.ones(n, dtype=bool)
        self._cands: list = [None] * n
        self._deltas: list = [None] * n

    def _refresh(self, vs: np.ndarray) -> None:
        cands, xcand = self.cands_builder(self.state, vs)
        deltas = price_mask_front(self.state, vs, cands, xcand,
                                  backend=self.backend)
        for i, v in enumerate(vs):
            lo, hi = xcand[i], xcand[i + 1]
            self._cands[v] = cands[lo:hi]
            self._deltas[v] = deltas[lo:hi]
            self._dirty[v] = False

    def refresh_dirty(self) -> int:
        """Batch-reprice every stale node; returns how many were stale."""
        vs = np.flatnonzero(self._dirty)
        if len(vs):
            self._refresh(vs)
        return len(vs)

    def refresh_window(self, vs: np.ndarray) -> None:
        """Batch-reprice the stale subset of ``vs`` (permutation lookahead).

        Scan loops call this when they reach a stale node, passing the next
        W entries of their visit order: stale nodes about to be visited are
        repriced in one front instead of one engine call each.  A node
        re-dirtied by a later move is simply repriced again at its visit --
        values returned by ``get`` are always current-state exact.
        """
        vs = vs[self._dirty[vs]]
        if len(vs):
            self._refresh(vs)

    def get(self, v: int):
        """(cands, deltas) for node v, repricing lazily if stale."""
        if self._dirty[v]:
            self._refresh(np.array([v], dtype=np.int64))
        return self._cands[v], self._deltas[v]

    def is_dirty(self, v: int) -> bool:
        return bool(self._dirty[v])

    def invalidate_move(self, v: int) -> None:
        """Mark v and every node sharing a hyperedge with it stale."""
        hg = self.state.hg
        self._dirty[hg.adj_nodes[hg.xadj[v]:hg.xadj[v + 1]]] = True
        self._dirty[v] = True

    @property
    def dirty_count(self) -> int:
        return int(self._dirty.sum())


def refresh_boundary_window(cache: GainCache, perm: np.ndarray, i: int,
                            W: int) -> None:
    """Reprice the dirty *boundary* slice of ``perm[i:i + W]`` in one front.

    Single home of the scan loops' lookahead rule (fm_refine and
    replicate_local_search share it): nodes already clean keep their
    cached deltas, and interior nodes -- every incident edge at
    lambda <= 1 -- are skipped because their prices are never consulted
    (the visit loops skip them via the same boundary test).  Purely a
    batching choice; cached values stay exact either way.
    """
    st = cache.state
    xinc, inc_edges, elam = st.xinc, st.inc_edges, st.edge_lambda
    win = [u for u in (int(x) for x in perm[i:i + W])
           if cache.is_dirty(u) and xinc[u] < xinc[u + 1]
           and int(elam[inc_edges[xinc[u]:xinc[u + 1]]].max()) > 1]
    cache.refresh_window(np.asarray(win, dtype=np.int64))


def lookahead_window(state: PartitionState) -> int:
    """Permutation-lookahead width for ``GainCache`` scan loops.

    Purely a batching choice (cached values are exact regardless, so
    decisions cannot change): wide windows amortize numpy call overhead on
    low-degree instances, but on high-degree ones (coarse multilevel
    levels average hundreds of pins per node) a 64-node window prices tens
    of thousands of rows per cache miss, most re-dirtied before their
    visit.  Target a few thousand rows per window instead.
    """
    hg = state.hg
    rows_per_node = (len(state.pins) / max(hg.n, 1)) * max(state.P - 1, 1)
    return int(min(64, max(8, 4096 // max(int(rows_per_node), 1))))
