"""Replication-aware expert placement: paper -> runtime bridge.

Pipeline (exactly the paper's moe-8 construction, §B.1, fed by a live
router trace instead of the published profiles):

  1. ``Model.route_trace`` yields (T, k) expert choices per MoE layer;
  2. ``trace_to_moe8`` turns them into a co-activation hypergraph
     (hyperedge = frequent k-tuple, weight = normalized frequency);
  3. hypergraph partitioning *with replication* (ILP-semantics heuristic,
     balance eps = spare expert-slot memory per device) assigns each expert
     a set of EP shards;
  4. the masks become a ``PlacementPlan`` whose local-fraction statically
     sizes the MoE all_to_all buffers.

``evaluate_plan`` reports the paper's (lambda_e - 1) cost for a plan, so
the communication reduction can be stated in the paper's own metric next
to the HLO collective-bytes reduction of the dry-run.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ...core.hypergraph import Hypergraph
from ...core.partition import (partition_cost, partition_heuristic,
                               replicate_local_search)
from ...datagen.moe_traces import trace_to_moe8
from ...models.moe import PlacementPlan, plan_from_masks, round_robin_plan


@dataclasses.dataclass
class PlacementResult:
    plan: PlacementPlan
    baseline_plan: PlacementPlan
    lambda_cost_no_repl: float
    lambda_cost_repl: float
    local_fraction_no_repl: float
    local_fraction_repl: float


def plan_expert_placement(
    trace: np.ndarray,          # (T, k) expert ids from the router
    n_experts: int,
    n_shards: int,
    eps: float = 0.25,          # spare HBM expert slots per shard
    kappa0: int = 1000,
    seed: int = 0,
    max_replicas: int | None = None,
) -> PlacementResult:
    hg_full, freq = _hypergraph_in_expert_space(trace, kappa0, n_experts)

    base = partition_heuristic(hg_full, n_shards, eps, seed=seed)
    rep = replicate_local_search(hg_full, base.masks.copy(), n_shards, eps,
                                 max_replicas=max_replicas, seed=seed)

    base_plan = plan_from_masks(base.masks, n_experts, n_shards,
                                expert_freq=freq)
    plan = plan_from_masks(rep.masks, n_experts, n_shards, expert_freq=freq)
    return PlacementResult(
        plan=plan,
        baseline_plan=base_plan,
        lambda_cost_no_repl=float(base.cost),
        lambda_cost_repl=float(rep.cost),
        local_fraction_no_repl=base_plan.local_fraction,
        local_fraction_repl=plan.local_fraction,
    )


def _hypergraph_in_expert_space(trace: np.ndarray, kappa0: int,
                                n_experts: int):
    """moe-8 hypergraph on the FULL expert id space (experts outside the
    frequent tuples become singleton-free nodes that the balance constraint
    still has to place), plus per-expert frequency."""
    from collections import Counter
    uniq, counts = np.unique(trace, axis=0, return_counts=True)
    counter = Counter({tuple(int(x) for x in row): int(c)
                       for row, c in zip(uniq, counts)})
    items = counter.most_common()
    edges, mu, pins = [], [], 0
    for tup, f in items:
        edges.append(tup)
        mu.append(f)
        pins += len(tup)
        if pins >= kappa0:
            break
    mu = np.asarray(mu, np.float64)
    if mu.max() > mu.min():
        mu = 1.0 + 9.0 * (mu - mu.min()) / (mu.max() - mu.min())
    else:
        mu = np.ones_like(mu)
    freq = np.bincount(trace.reshape(-1), minlength=n_experts).astype(float)
    return Hypergraph(n=n_experts, edges=edges, mu=mu, name="moe8_full"), freq


def evaluate_plan(plan: PlacementPlan, trace: np.ndarray, kappa0: int = 1000
                  ) -> dict:
    """(lambda_e - 1) cost of a plan on a (held-out) trace."""
    n_experts = plan.n_experts
    hg, freq = _hypergraph_in_expert_space(trace, kappa0, n_experts)
    local = np.array(plan.local_slot)
    masks = np.zeros(n_experts, np.int64)
    for p in range(plan.n_shards):
        for e in range(n_experts):
            if local[p, e] >= 0:
                masks[e] |= 1 << p
    cost = partition_cost(hg, masks, plan.n_shards)
    return {"lambda_cost": float(cost),
            "local_fraction": plan.local_fraction,
            "replicated_experts": int(sum(
                1 for e in range(n_experts)
                if bin(int(masks[e])).count("1") > 1))}
