"""BSP-replication -> rematerialization bridge (DESIGN.md §2).

In BSP scheduling, replication trades extra *compute* for removed
*communication*.  The training-step analogue: rematerializing a layer's
activations in the backward pass trades recompute FLOPs for removed HBM
traffic (saving residuals to memory is the "communication" -- on TPU the
backward pass "receives" them from HBM).  The trade is governed by the
same comparison the paper's basic heuristic makes per step:

    replicate (remat)  iff  recompute_time < save_traffic_time
                       or   the saved bytes do not fit the HBM budget.

``plan_remat`` evaluates both sides per layer family with the analytic
cost model and returns the checkpoint policy for the step builder.
"""
from __future__ import annotations

import dataclasses

from ...models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9


@dataclasses.dataclass
class RematDecision:
    policy: str               # 'none' | 'dots' | 'full'
    recompute_seconds: float  # extra fwd per device per step
    save_seconds: float       # HBM traffic of saved activations
    save_bytes: int           # bytes of saved residuals+intermediates
    fits_budget: bool


def plan_remat(cfg: ModelConfig, B: int, S: int, dp: int, tp: int,
               hbm_budget_bytes: float = 8e9) -> RematDecision:
    """Decide the activation-checkpoint policy for (cfg, shape, mesh)."""
    from ...roofline.model import step_cost

    fwd = step_cost(cfg.with_(remat="none"), B, S, S, dp, tp, "prefill")
    recompute_s = fwd["flops"] / PEAK_FLOPS

    # bytes that must live until the backward pass without remat:
    # residual stream per layer + the larger ffn/attention intermediates
    T_dev = B * S / dp
    D = cfg.d_model
    L = cfg.n_layers
    resid = T_dev * D * 2 * L
    inter = 0.0
    for seg in cfg.segments:
        n = seg.n_layers * seg.sub_layers
        width = max(cfg.d_ff, cfg.moe_d_ff * cfg.top_k,
                    2 * cfg.d_inner if cfg.ssm_state else 0, D)
        inter += n * T_dev * (width / max(tp, 1)) * 2
    save_bytes = resid + inter
    save_s = save_bytes / HBM_BW

    fits = save_bytes <= hbm_budget_bytes
    if not fits or recompute_s < save_s:
        policy = "full"
    elif resid + inter * 0.3 <= hbm_budget_bytes:
        policy = "none"
    else:
        policy = "dots"  # keep matmul outputs, recompute elementwise
    return RematDecision(policy=policy, recompute_seconds=recompute_s,
                         save_seconds=save_s, save_bytes=int(save_bytes),
                         fits_budget=fits)
