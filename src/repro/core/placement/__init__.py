from .expert_placement import (PlacementResult, evaluate_plan,
                               plan_expert_placement)
from .remat_policy import RematDecision, plan_remat

__all__ = ["PlacementResult", "evaluate_plan", "plan_expert_placement",
           "RematDecision", "plan_remat"]
