"""Seed (pre-engine) heuristic implementation, kept as a reference oracle.

This is the full-recompute local search the repo shipped with before the
incremental-gain engine: every candidate move re-runs exact set cover over
all incident hyperedges.  It is O(deg^2)-ish per evaluation and only viable
on toy instances, but its simplicity makes it the ground truth for

  * equivalence tests (the engine-backed heuristic must return valid,
    balanced masks with equal-or-better cost on fixed seeds), and
  * the old-vs-new throughput benchmark in ``benchmarks/partitioning.py``.

Do not use it in production paths; ``heuristic.py`` is the fast one.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from ..hypergraph import Hypergraph
from .cost import capacity, edge_cost, min_cover, partition_cost


def _incident_lists(hg: Hypergraph) -> list[list[int]]:
    """Seed-identical list-of-lists incidence (not the CSR view), so the
    reference's timing stays an honest baseline."""
    inc: list[list[int]] = [[] for _ in range(hg.n)]
    for ei, e in enumerate(hg.edges):
        for v in e:
            inc[v].append(ei)
    return inc


def greedy_initial_reference(hg: Hypergraph, P: int, eps: float,
                             rng: np.random.Generator) -> np.ndarray:
    """BFS-grow partitions over the pin-adjacency, balanced by weight."""
    cap_target = float(hg.omega.sum()) / P
    inc = _incident_lists(hg)
    visited = np.zeros(hg.n, dtype=bool)
    part = np.zeros(hg.n, dtype=np.int64)
    order = rng.permutation(hg.n)
    cur_p, cur_w = 0, 0.0
    queue: deque[int] = deque()
    qi = 0
    while True:
        if not queue:
            while qi < hg.n and visited[order[qi]]:
                qi += 1
            if qi == hg.n:
                break
            queue.append(order[qi])
        v = queue.popleft()
        if visited[v]:
            continue
        visited[v] = True
        if cur_w + hg.omega[v] > cap_target and cur_p < P - 1:
            cur_p += 1
            cur_w = 0.0
        part[v] = cur_p
        cur_w += hg.omega[v]
        for ei in inc[v]:
            for u in hg.edges[ei]:
                if not visited[u]:
                    queue.append(u)
    return (1 << part).astype(np.int64)


def fm_refine_reference(hg: Hypergraph, masks: np.ndarray, P: int, eps: float,
                        rng: np.random.Generator, passes: int = 6) -> np.ndarray:
    """Move-based refinement with per-move full recomputation (seed)."""
    cap = capacity(hg, P, eps) + 1e-9
    inc = _incident_lists(hg)
    load = np.zeros(P)
    for v in range(hg.n):
        load[int(masks[v]).bit_length() - 1] += hg.omega[v]

    def incident_cost(v: int) -> float:
        return sum(edge_cost(hg, masks, ei, P) for ei in inc[v])

    for _ in range(passes):
        improved = False
        for v in rng.permutation(hg.n):
            p = int(masks[v]).bit_length() - 1
            base = incident_cost(v)
            best_gain, best_q = 0.0, -1
            for q in range(P):
                if q == p or load[q] + hg.omega[v] > cap:
                    continue
                masks[v] = 1 << q
                gain = base - incident_cost(v)
                masks[v] = 1 << p
                if gain > best_gain + 1e-12:
                    best_gain, best_q = gain, q
            if best_q >= 0:
                masks[v] = 1 << best_q
                load[p] -= hg.omega[v]
                load[best_q] += hg.omega[v]
                improved = True
        if not improved:
            break
    return masks


def partition_heuristic_reference(hg: Hypergraph, P: int, eps: float,
                                  restarts: int = 4, seed: int = 0):
    """Seed non-replicating baseline: greedy + FM, best of restarts.

    Returns ``(masks, cost)``.
    """
    rng = np.random.default_rng(seed)
    best_masks, best_cost = None, np.inf
    for _ in range(restarts):
        masks = greedy_initial_reference(hg, P, eps, rng)
        masks = fm_refine_reference(hg, masks, P, eps, rng)
        c = partition_cost(hg, masks, P)
        if c < best_cost:
            best_cost, best_masks = c, masks.copy()
    return best_masks, float(best_cost)


def replicate_local_search_reference(
    hg: Hypergraph,
    masks: np.ndarray,
    P: int,
    eps: float,
    max_replicas: int | None = None,
    max_passes: int = 30,
    seed: int = 0,
):
    """Seed replication local search (full recompute).  Returns (masks, cost)."""
    rng = np.random.default_rng(seed)
    masks = np.asarray(masks, dtype=np.int64).copy()
    cap = capacity(hg, P, eps) + 1e-9
    inc = _incident_lists(hg)
    load = np.zeros(P)
    for v in range(hg.n):
        m = int(masks[v])
        for p in range(P):
            if (m >> p) & 1:
                load[p] += hg.omega[v]

    def incident_cost(v: int) -> float:
        return sum(edge_cost(hg, masks, ei, P) for ei in inc[v])

    def try_edge_move(ei: int) -> bool:
        e = hg.edges[ei]
        pin_masks = [int(masks[v]) for v in e]
        lam = min_cover(pin_masks, P)
        if lam < 2:
            return False
        best = None
        for p in range(P):
            movers = [v for v in e if not (int(masks[v]) >> p) & 1]
            if not movers:
                continue
            if max_replicas is not None and any(
                    bin(int(masks[v])).count("1") >= max_replicas
                    for v in movers):
                continue
            w = sum(hg.omega[v] for v in movers)
            if load[p] + w > cap:
                continue
            if best is None or len(movers) < len(best[1]):
                best = (p, movers, w)
        if best is None:
            return False
        p, movers, w = best
        touched = sorted({e2 for v in movers for e2 in inc[v]})
        before = sum(edge_cost(hg, masks, e2, P) for e2 in touched)
        old = [int(masks[v]) for v in movers]
        for v in movers:
            masks[v] = int(masks[v]) | (1 << p)
        after = sum(edge_cost(hg, masks, e2, P) for e2 in touched)
        if after < before - 1e-12:
            load[p] += w
            return True
        for v, m_old in zip(movers, old):
            masks[v] = m_old
        return False

    for _ in range(max_passes):
        improved = False
        for ei in rng.permutation(len(hg.edges)):
            if try_edge_move(int(ei)):
                improved = True
        for v in rng.permutation(hg.n):
            m = int(masks[v])
            k = bin(m).count("1")
            base = incident_cost(v)
            if max_replicas is None or k < max_replicas:
                best_gain, best_p = 0.0, -1
                for p in range(P):
                    if (m >> p) & 1 or load[p] + hg.omega[v] > cap:
                        continue
                    masks[v] = m | (1 << p)
                    gain = base - incident_cost(v)
                    masks[v] = m
                    if gain > best_gain + 1e-12:
                        best_gain, best_p = gain, p
                if best_p >= 0:
                    masks[v] = m | (1 << best_p)
                    load[best_p] += hg.omega[v]
                    improved = True
                    continue
            if k > 1:
                for p in range(P):
                    if bin(m).count("1") <= 1:
                        break
                    if not (m >> p) & 1:
                        continue
                    masks[v] = m & ~(1 << p)
                    if incident_cost(v) <= base + 1e-12:
                        load[p] -= hg.omega[v]
                        improved = True
                        m = int(masks[v])
                        base = incident_cost(v)
                    else:
                        masks[v] = m
        if not improved:
            break
    return masks, partition_cost(hg, masks, P)
