"""Multilevel V-cycle partitioning (METIS-style coarsening, PR 4 tentpole).

The flat greedy-BFS + FM stack tops out around n ~ 6000 (seconds per
instance): every restart walks the whole hypergraph and every refinement
pass prices every node.  The standard route to large instances is the
multilevel V-cycle -- coarsen until the hypergraph is small, partition the
coarse instance well, then project the solution back up level by level,
refining locally at each scale.  What is new here relative to stock
multilevel partitioners is that the *replication* local search (the
paper's cost model: ``sum mu_e * (lambda_e - 1)`` with set-cover lambdas)
runs inside the V-cycle too, with replication masks projecting as unions.

Pipeline (one V-cycle)::

    match   heavy-pin matching, vectorized over the CSR arrays
    contract  ``Hypergraph.contract``: cluster map + identical-net collapse
    recurse  until ``coarsest_n`` nodes, stagnation, or ``max_levels``
    solve    flat ``partition_heuristic`` (+ ``replicate_local_search``)
             at the coarsest level -- restarts are cheap there
    project  ``coarse_masks[cmap]``; ``PartitionState.from_projection``
             rebuilds the fine engine state reusing the coarse lambdas --
             projection is cost-exact (bit-identical state, see
             ``tests/test_multilevel.py``), so the V-cycle changes
             wall-clock and reach, never correctness
    refine   frontier-priced FM (``GainCache`` fronts) and
             ``replicate_local_search`` at each refinement stop (every
             ``refine_every``-th level; skipped hops project through
             composed maps, which is still cost-exact).  With
             ``frontier="jax"`` the levels above ``DEVICE_MIN_NODES``
             run their passes device-resident (``kernels.front_pass``,
             one host sync per committed move, decision-identical) --
             the ``frontier`` argument threads through unchanged, so
             the V-cycle needs no device-specific code

Cost safety: the coarsest level is solved by the *same* flat heuristic,
projection preserves cost exactly, and every refinement stage only ever
applies strictly improving moves -- so the final cost can only be at or
below the coarsest solution's, and in practice at or below the flat
heuristic's wherever both run (pinned on the shipped spmv datasets by
``tests/test_multilevel.py``, measured at scale by
``benchmarks/partitioning.py::bench_multilevel``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..hypergraph import Hypergraph
from .engine import _MAX_P, PartitionState
from .heuristic import (HeuristicResult, fm_refine, partition_heuristic,
                        partition_with_replication, replicate_local_search)


@dataclasses.dataclass
class MultilevelOptions:
    """Knobs of the V-cycle driver (defaults tuned for spmv row-nets)."""

    coarsest_n: int = 384      # stop coarsening at this many nodes
    max_levels: int = 24       # hard cap on the level stack depth
    stagnation: float = 0.9    # stop when a level shrinks less than this
    max_edge_size: int = 24    # larger edges do not steer the matching
    cluster_cap_frac: float = 0.15  # max cluster weight, fraction of W/P
    fm_passes: int = 1         # FM passes per intermediate level
    final_fm_passes: int = 3   # FM passes at the finest level
    restarts: int = 2          # flat restarts at the coarsest level
    rep_passes: int = 2        # replication passes per intermediate level
    final_rep_passes: int = 12  # replication passes at the finest level
    alternations: int = 1      # primary-FM + replicate rounds at the end
    refine_every: int = 2      # refine every k-th level (finest always);
    #                            skipped levels project straight through
    #                            (composed cmaps -- still cost-exact)


# --------------------------------------------------------------- coarsening

def _match_pref(hg: Hypergraph, max_edge_size: int, lo: int = 0,
                hi: int | None = None) -> np.ndarray:
    """Best heavy-pin partner per node of ``[lo, hi)`` (-1 = none).

    The pair expansion for a node v draws only on v's incident small
    edges, and the (v, u) score sums accumulate in ascending-edge
    expansion order -- so computing a node range from the range's incident
    edge set (an ascending superset of each member's incident edges)
    reproduces the full-graph pass byte for byte.  That is the sharding
    contract of the process-parallel scorer: concatenating per-range
    results over any partition of [0, n) equals the serial ``pref``.
    """
    n = hg.n
    hi = n if hi is None else hi
    xpins, pins = hg.xpins, hg.pins
    lens = np.diff(xpins)
    if lo == 0 and hi == n:
        sel = np.flatnonzero((lens >= 2) & (lens <= max_edge_size))
    else:
        xinc, inc = hg.xinc, hg.inc_edges
        cand = np.unique(inc[xinc[lo]:xinc[hi]])
        cl = lens[cand]
        sel = cand[(cl >= 2) & (cl <= max_edge_size)]
    pref = np.full(hi - lo, -1, dtype=np.int64)
    if len(sel):
        L = lens[sel]
        L2 = L * L
        edge_rep = np.repeat(sel, L2)
        offs = np.arange(int(L2.sum()), dtype=np.int64)
        offs -= np.repeat(np.cumsum(L2) - L2, L2)
        Lr = np.repeat(L, L2)
        base = xpins[edge_rep]
        v = pins[base + offs // Lr]
        u = pins[base + offs % Lr]
        w = np.repeat(hg.mu[sel] / (L - 1), L2)
        keep = v != u
        if lo > 0 or hi < n:
            keep &= (v >= lo) & (v < hi)
        v, u, w = v[keep], u[keep], w[keep]
        if len(v):
            key = v * n + u
            order = np.argsort(key, kind="stable")
            key, w = key[order], w[order]
            first = np.ones(len(key), dtype=bool)
            first[1:] = key[1:] != key[:-1]
            starts = np.flatnonzero(first)
            score = np.add.reduceat(w, starts)
            vd, ud = key[starts] // n, key[starts] % n
            # per node: strongest partner first, ties to the smallest id
            order2 = np.lexsort((ud, -score, vd))
            vd2 = vd[order2]
            lead = np.ones(len(vd2), dtype=bool)
            lead[1:] = vd2[1:] != vd2[:-1]
            pref[vd2[lead] - lo] = ud[order2][lead]
    return pref


def heavy_pin_matching(hg: Hypergraph, max_weight: float,
                       rng: np.random.Generator,
                       max_edge_size: int = 24,
                       ctx=None) -> tuple[np.ndarray, int]:
    """Cluster map from heavy-pin matching, scored over the CSR arrays.

    Connectivity score between two nodes is ``sum mu_e / (|e| - 1)`` over
    shared hyperedges (the classic heavy-edge rating); edges larger than
    ``max_edge_size`` are ignored for scoring (they are nearly uncut-able
    and would blow the pair expansion up quadratically).  Every node's best
    partner (max score, ties to the smallest id) is computed in one
    vectorized pass; a greedy sweep in random order then pairs mutually
    free nodes whose combined weight stays under ``max_weight``.  Unmatched
    nodes become singleton clusters.  Returns ``(cmap, nc)``.

    ``ctx`` (a ``parallel.ParallelContext``) shards the scoring pass --
    the O(sum |e|^2) pair expansion, the expensive half -- over node
    ranges across the worker pool; the O(n) greedy sweep stays serial on
    the same ``rng``, so the resulting ``cmap`` is bit-identical to the
    serial path for every worker count.
    """
    n = hg.n
    if (ctx is not None and not ctx.failed and ctx.workers > 1
            and n >= ctx.min_nodes):
        from .parallel import parallel_match_pref
        pref = parallel_match_pref(hg, ctx, max_edge_size)
    else:
        pref = _match_pref(hg, max_edge_size)
    omega = hg.omega
    match = np.full(n, -1, dtype=np.int64)
    for v in rng.permutation(n):
        u = pref[v]
        if match[v] >= 0 or u < 0 or match[u] >= 0:
            continue
        if omega[v] + omega[u] > max_weight:
            continue
        match[v] = u
        match[u] = v
    # cluster ids in order of each cluster's smallest member (deterministic,
    # locality-preserving for the coarse BFS)
    partner = np.where(match >= 0, match, np.arange(n, dtype=np.int64))
    rep = np.minimum(np.arange(n, dtype=np.int64), partner)
    reps = np.unique(rep)
    cmap = np.searchsorted(reps, rep)
    return cmap, len(reps)


def build_levels(hg: Hypergraph, P: int, eps: float, opts: MultilevelOptions,
                 rng: np.random.Generator, ctx=None):
    """Coarsen until small/stagnant: ``(levels, cmaps, edge_maps)``.

    ``levels[0]`` is the input; ``cmaps[i]``/``edge_maps[i]`` map
    ``levels[i]`` onto ``levels[i + 1]``.  ``ctx`` shards the matching
    scorer across a worker pool (bit-identical cmaps, see
    ``heavy_pin_matching``).
    """
    levels, cmaps, edge_maps = [hg], [], []
    # cluster weight cap: granular enough that the coarsest greedy's
    # per-partition overshoot (at most one node weight) stays inside the
    # eps balance slack -- half the slack, and never above the knob
    max_w = min(opts.cluster_cap_frac, 0.5 * eps) * float(hg.omega.sum()) / P
    while levels[-1].n > opts.coarsest_n and len(levels) < opts.max_levels:
        cur = levels[-1]
        cmap, nc = heavy_pin_matching(cur, max_w, rng,
                                      max_edge_size=opts.max_edge_size,
                                      ctx=ctx)
        if nc >= opts.stagnation * cur.n:
            break
        coarse, emap = cur.contract(cmap, nc)
        levels.append(coarse)
        cmaps.append(cmap)
        edge_maps.append(emap)
    return levels, cmaps, edge_maps


def project_masks(cmap: np.ndarray, coarse_masks: np.ndarray) -> np.ndarray:
    """Prolongate coarse masks to the fine level (unions for replication:
    each cluster member inherits the cluster's whole processor set)."""
    return np.asarray(coarse_masks, dtype=np.int64)[np.asarray(cmap,
                                                               dtype=np.int64)]


# ------------------------------------------------------------------ V-cycle

def _project_state(fine: Hypergraph, P: int, st: PartitionState,
                   cmap: np.ndarray, edge_map: np.ndarray) -> PartitionState:
    return PartitionState.from_projection(fine, P, st, cmap, edge_map)


def _refinement_schedule(n_levels: int, refine_every: int):
    """Level indices to refine at (every ``refine_every``-th, finest (0)
    always included); projection hops between consecutive stops use
    composed maps (``_compose_maps``).

    Composition is exact: ``masks[cmap_a][cmap_b] == masks[cmap_a[cmap_b]]``
    and a fine edge survives the double contraction iff both hops keep it,
    so skipped levels cost nothing and change nothing about projection
    semantics -- only where refinement runs.
    """
    stops = sorted({0} | set(range(0, n_levels - 1, max(refine_every, 1))))
    return stops


def _compose_maps(cmaps, edge_maps, lo: int, hi: int):
    """Maps from level ``lo`` straight onto level ``hi`` (lo < hi)."""
    cmap = cmaps[lo]
    emap = edge_maps[lo]
    for li in range(lo + 1, hi):
        cmap = cmaps[li][cmap]
        keep = emap >= 0
        nxt = np.full_like(emap, -1)
        nxt[keep] = edge_maps[li][emap[keep]]
        emap = nxt
    return cmap, emap


def _make_ctx(workers: int | None):
    """A ``ParallelContext`` for ``workers > 1`` (None when unavailable)."""
    if not workers or workers <= 1:
        return None
    from .parallel import ParallelContext, shm_available
    if not shm_available():
        return None
    return ParallelContext(workers)


def _fm_stop(fine: Hypergraph, st: PartitionState, P: int, eps: float,
             rng: np.random.Generator, passes: int, frontier: str | None,
             ctx, seed: int) -> None:
    """One FM refinement stop: sharded workers + reconciliation when a
    ``ParallelContext`` is live and the level is big enough, the serial
    frontier-priced pass otherwise.  Mutates ``st`` in place."""
    if ctx is not None and not ctx.failed and fine.n >= ctx.min_nodes:
        from .parallel import parallel_refine
        parallel_refine(fine, st, P, eps, ctx, "fm", passes, seed=seed)
    else:
        fm_refine(fine, st.masks, P, eps, rng, passes=passes, state=st,
                  frontier=frontier)


def _rep_stop(fine: Hypergraph, st: PartitionState, P: int, eps: float,
              passes: int, max_replicas: int | None, frontier: str | None,
              ctx, seed: int) -> HeuristicResult:
    """One replication refinement stop (cf. ``_fm_stop``)."""
    if ctx is not None and not ctx.failed and fine.n >= ctx.min_nodes:
        from .parallel import parallel_refine
        parallel_refine(fine, st, P, eps, ctx, "rep", passes, seed=seed,
                        max_replicas=max_replicas)
        return HeuristicResult(masks=st.masks.copy(), cost=float(st.cost))
    return replicate_local_search(fine, st.masks, P, eps,
                                  max_replicas=max_replicas,
                                  max_passes=passes, seed=seed,
                                  frontier=frontier, state=st)


def multilevel_partition(hg: Hypergraph, P: int, eps: float,
                         opts: MultilevelOptions | None = None,
                         seed: int = 0, frontier: str | None = None,
                         stats: list | None = None,
                         workers: int | None = None) -> HeuristicResult:
    """Non-replicating V-cycle: coarsest flat solve + per-level FM.

    Falls through to the flat heuristic when the instance is already at or
    below ``coarsest_n`` (or P exceeds the engine tables) -- on such
    instances the two paths are the same algorithm.  ``stats`` (optional
    list) receives one dict per level with projected/refined costs, which
    is how the refinement-never-increases property is tested.
    """
    opts = opts or MultilevelOptions()
    if P > _MAX_P or hg.n <= opts.coarsest_n:
        # at-or-below the coarsest size the V-cycle *is* the flat
        # heuristic -- call it with its own defaults so the two paths are
        # literally identical there
        return partition_heuristic(hg, P, eps, seed=seed, frontier=frontier)
    rng = np.random.default_rng(seed)
    ctx = _make_ctx(workers)
    try:
        levels, cmaps, edge_maps = build_levels(hg, P, eps, opts, rng,
                                                ctx=ctx)
        if not cmaps:
            # matching stagnated immediately (e.g. every edge above
            # max_edge_size, or a weight cap below any pair): no coarse
            # level exists, so the V-cycle degenerates to the flat heuristic
            return partition_heuristic(hg, P, eps, seed=seed,
                                       frontier=frontier)
        res = partition_heuristic(levels[-1], P, eps,
                                  restarts=opts.restarts,
                                  seed=seed, frontier=frontier)
        st = PartitionState(levels[-1], P, masks=res.masks)
        if stats is not None:
            stats.append({"level": len(levels) - 1, "n": levels[-1].n,
                          "edges": len(levels[-1].edges),
                          "cost_projected": float(st.cost),
                          "cost_refined": float(st.cost)})
        prev = len(levels) - 1
        for li in sorted(_refinement_schedule(len(levels),
                                              opts.refine_every),
                         reverse=True):
            cmap, emap = _compose_maps(cmaps, edge_maps, li, prev)
            st = _project_state(levels[li], P, st, cmap, emap)
            prev = li
            projected = float(st.cost)
            _fm_stop(levels[li], st, P, eps, rng,
                     opts.final_fm_passes if li == 0 else opts.fm_passes,
                     frontier, ctx, seed + 101 * li)
            if stats is not None:
                stats.append({"level": li, "n": levels[li].n,
                              "edges": len(levels[li].edges),
                              "cost_projected": projected,
                              "cost_refined": float(st.cost)})
        return HeuristicResult(masks=st.masks.copy(), cost=float(st.cost))
    finally:
        if ctx is not None:
            ctx.close()


def partition_with_replication_multilevel(
    hg: Hypergraph,
    P: int,
    eps: float,
    mode: str = "rep",
    opts: MultilevelOptions | None = None,
    seed: int = 0,
    frontier: str | None = None,
    stats: list | None = None,
    workers: int | None = None,
):
    """Multilevel analogue of ``partition_with_replication``.

    Returns ``(base, rep)`` like the flat entry point.  Two mask streams
    ride the same level stack down:

      * **base** -- single-assignment, refined by FM at each refinement
        stop (the paper's non-replicating comparator);
      * **rep** -- replicated, seeded at the coarsest level from the base
        solution, projected as unions and refined by
        ``replicate_local_search`` at each stop.  If the projected stream
        has not already beaten the base at the finest level, a second
        replication search runs from the refined base masks and the
        cheaper wins -- a replication search never increases cost, so
        ``rep.cost <= base.cost`` by construction either way.

    The finest level finishes with the flat driver's alternation
    (primary-extract + FM + replicate, ``opts.alternations`` rounds).

    This driver is heuristic-only: the exact small-instance solve (the
    paper's base-ILP comparison) lives in ``partition_with_replication``,
    which dispatches to it *before* routing here; sizes at or below
    ``coarsest_n`` fall through to the flat heuristic driver.
    """
    opts = opts or MultilevelOptions()
    if P > _MAX_P or hg.n <= opts.coarsest_n:
        return partition_with_replication(hg, P, eps, mode=mode,
                                          exact_node_limit=0, seed=seed,
                                          frontier=frontier)
    max_replicas = 2 if mode == "dup" else None
    rng = np.random.default_rng(seed)
    ctx = _make_ctx(workers)
    try:
        levels, cmaps, edge_maps = build_levels(hg, P, eps, opts, rng,
                                                ctx=ctx)
        if not cmaps:  # immediate stagnation: no coarse level (cf. above)
            return partition_with_replication(hg, P, eps, mode=mode,
                                              exact_node_limit=0, seed=seed,
                                              frontier=frontier)
        base_res = partition_heuristic(levels[-1], P, eps,
                                       restarts=opts.restarts, seed=seed,
                                       frontier=frontier)
        base_st = PartitionState(levels[-1], P, masks=base_res.masks)
        rep_res = replicate_local_search(levels[-1], base_res.masks.copy(),
                                         P, eps, max_replicas=max_replicas,
                                         seed=seed, frontier=frontier)
        rep_st = PartitionState(levels[-1], P, masks=rep_res.masks)
        prev = len(levels) - 1
        for li in sorted(_refinement_schedule(len(levels),
                                              opts.refine_every),
                         reverse=True):
            fine = levels[li]
            finest = li == 0
            cmap, emap = _compose_maps(cmaps, edge_maps, li, prev)
            base_st = _project_state(fine, P, base_st, cmap, emap)
            _fm_stop(fine, base_st, P, eps, rng,
                     opts.final_fm_passes if finest else opts.fm_passes,
                     frontier, ctx, seed + 101 * li)
            rep_st = _project_state(fine, P, rep_st, cmap, emap)
            prev = li
            projected = float(rep_st.cost)
            passes = opts.final_rep_passes if finest else opts.rep_passes
            rep = _rep_stop(fine, rep_st, P, eps, passes, max_replicas,
                            frontier, ctx, seed)
            if finest and rep.cost > base_st.cost - 1e-12:
                # alternation seed at the finest level: replicate from the
                # refined base masks -- only needed when the projected
                # stream did not already beat the base (guarantees
                # rep <= base)
                alt_st = PartitionState(fine, P,
                                        masks=base_st.masks.copy())
                alt = _rep_stop(fine, alt_st, P, eps, passes, max_replicas,
                                frontier, ctx, seed + li + 1)
                if alt.cost < rep.cost - 1e-12:
                    rep = alt
            if stats is not None:
                stats.append({"level": li, "n": fine.n,
                              "edges": len(fine.edges),
                              "cost_projected": projected,
                              "cost_refined": float(rep.cost),
                              "base_cost": float(base_st.cost)})
        base = HeuristicResult(masks=base_st.masks.copy(),
                               cost=float(base_st.cost))
        best = rep
        # flat-driver alternation at the finest level: re-run FM on the
        # primary copies, replicate again, keep while it improves (cf.
        # heuristic.py)
        for r in range(opts.alternations):
            masks = best.masks.copy()
            primary = np.array([1 << (int(m).bit_length() - 1)
                                for m in masks])
            alt_rng = np.random.default_rng(seed + r + 1)
            fm_st = PartitionState(hg, P, masks=primary.copy())
            _fm_stop(hg, fm_st, P, eps, alt_rng, opts.final_fm_passes,
                     frontier, ctx, seed + r + 1)
            rls_st = PartitionState(hg, P, masks=fm_st.masks.copy())
            cand = _rep_stop(hg, rls_st, P, eps, opts.final_rep_passes,
                             max_replicas, frontier, ctx, seed + r + 1)
            if cand.cost < best.cost - 1e-12:
                best = cand
            else:
                break
        return base, best
    finally:
        if ctx is not None:
            ctx.close()
