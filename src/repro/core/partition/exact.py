"""Exact branch-and-bound solver for (hyper)graph partitioning.

This plays the role of the paper's ILP formulations (§5).  The container has
no commercial ILP solver (the paper uses COPT), so we solve the same 0/1
programs exactly with a branch-and-bound search that certifies optimality on
small instances:

  * mode='none'  -- classical partitioning, each node on exactly 1 processor
                    (the base ILP of §5.1);
  * mode='dup'   -- ILP/D semantics (§5.2.1): at most 2 replicas per node;
  * mode='rep'   -- ILP/R semantics (§5.2.2): unlimited replication.

Branching assigns each node a processor *bitmask*; the lower bound is the
connectivity cost of partially-assigned hyperedges, which is monotone:
adding pins to an edge can only raise its minimum cover.  Processor-
permutation symmetry is broken by only allowing a new processor index once
all smaller indices are in use.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..hypergraph import Hypergraph
from .cost import capacity, min_cover, partition_cost


@dataclasses.dataclass
class ExactResult:
    masks: np.ndarray
    cost: float
    optimal: bool
    nodes_explored: int
    seconds: float


def _candidate_masks(P: int, mode: str) -> list[int]:
    out = []
    for m in range(1, 1 << P):
        k = bin(m).count("1")
        if mode == "none" and k != 1:
            continue
        if mode == "dup" and k > 2:
            continue
        out.append(m)
    # prefer fewer replicas first: cheaper loads, finds good UBs earlier
    out.sort(key=lambda m: (bin(m).count("1"), m))
    return out


def exact_partition(
    hg: Hypergraph,
    P: int,
    eps: float,
    mode: str = "none",
    time_limit: float | None = None,
    ub_masks: np.ndarray | None = None,
) -> ExactResult:
    assert mode in ("none", "dup", "rep")
    n = len(hg.edges)
    cap = capacity(hg, P, eps) + 1e-9
    t0 = time.monotonic()

    inc = hg.incident_edges()
    # order nodes by decreasing total incident edge weight (tight LBs early)
    score = [sum(hg.mu[ei] for ei in inc[v]) for v in range(hg.n)]
    order = sorted(range(hg.n), key=lambda v: -score[v])
    pos_in_order = {v: i for i, v in enumerate(order)}

    cands = _candidate_masks(P, mode)

    best_cost = np.inf
    best_masks: np.ndarray | None = None
    if ub_masks is not None:
        best_masks = np.asarray(ub_masks).copy()
        best_cost = partition_cost(hg, best_masks, P)

    masks = np.zeros(hg.n, dtype=np.int64)
    load = np.zeros(P, dtype=np.float64)
    # per-edge partial pin masks (list of masks of already-assigned pins)
    edge_pins: list[list[int]] = [[] for _ in range(n)]
    edge_lb = np.zeros(n, dtype=np.float64)  # current mu*(cover-1) of partial edge
    remaining_w = [0.0] * (hg.n + 1)
    for i in range(hg.n - 1, -1, -1):
        remaining_w[i] = remaining_w[i + 1] + hg.omega[order[i]]

    state = {"explored": 0, "timed_out": False, "lb_sum": 0.0,
             "best_cost": best_cost, "best_masks": best_masks}

    def dfs(idx: int, used_procs: int) -> None:
        if state["timed_out"]:
            return
        state["explored"] += 1
        if time_limit is not None and state["explored"] % 2048 == 0:
            if time.monotonic() - t0 > time_limit:
                state["timed_out"] = True
                return
        if idx == hg.n:
            if state["lb_sum"] < state["best_cost"] - 1e-12:
                state["best_cost"] = state["lb_sum"]
                state["best_masks"] = masks.copy()
            return
        v = order[idx]
        # capacity feasibility: every remaining node needs >= its weight somewhere
        free = float(np.maximum(cap - load, 0.0).sum())
        if remaining_w[idx] > free + 1e-9:
            return
        for m in cands:
            # Symmetry breaking: used processors always form the prefix
            # {0..used_procs-1}; a mask may use any of those plus a
            # *contiguous block* of fresh processors starting at used_procs
            # (fresh processors are mutually symmetric).
            high = m >> used_procs
            if high & (high + 1):
                continue
            # balance check
            ok = True
            k = 0
            mm = m
            while mm:
                p = (mm & -mm).bit_length() - 1
                if load[p] + hg.omega[v] > cap:
                    ok = False
                    break
                mm &= mm - 1
                k += 1
            if not ok:
                continue
            # apply
            delta_lb = 0.0
            touched = []
            mm = m
            while mm:
                p = (mm & -mm).bit_length() - 1
                load[p] += hg.omega[v]
                mm &= mm - 1
            for ei in inc[v]:
                edge_pins[ei].append(m)
                new_lb = hg.mu[ei] * max(0, min_cover(edge_pins[ei], P) - 1)
                delta_lb += new_lb - edge_lb[ei]
                touched.append((ei, edge_lb[ei]))
                edge_lb[ei] = new_lb
            state["lb_sum"] += delta_lb
            masks[v] = m
            if state["lb_sum"] < state["best_cost"] - 1e-12:
                new_used = max(used_procs, m.bit_length())
                dfs(idx + 1, new_used)
            # undo
            masks[v] = 0
            state["lb_sum"] -= delta_lb
            for ei, old in reversed(touched):
                edge_pins[ei].pop()
                edge_lb[ei] = old
            mm = m
            while mm:
                p = (mm & -mm).bit_length() - 1
                load[p] -= hg.omega[v]
                mm &= mm - 1
            if state["timed_out"]:
                return

    dfs(0, 0)
    seconds = time.monotonic() - t0
    if state["best_masks"] is None:
        raise RuntimeError("no feasible partition found (check eps/P)")
    return ExactResult(
        masks=np.asarray(state["best_masks"]),
        cost=float(state["best_cost"]),
        optimal=not state["timed_out"],
        nodes_explored=state["explored"],
        seconds=seconds,
    )
