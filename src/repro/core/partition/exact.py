"""Exact branch-and-bound solver for (hyper)graph partitioning.

This plays the role of the paper's ILP formulations (§5).  The container has
no commercial ILP solver (the paper uses COPT), so we solve the same 0/1
programs exactly with a branch-and-bound search that certifies optimality on
small instances:

  * mode='none'  -- classical partitioning, each node on exactly 1 processor
                    (the base ILP of §5.1);
  * mode='dup'   -- ILP/D semantics (§5.2.1): at most 2 replicas per node;
  * mode='rep'   -- ILP/R semantics (§5.2.2): unlimited replication.

Branching assigns each node a processor *bitmask*.  Partial-assignment
state (per-edge uncovered-subset counts, loads, and the monotone lower
bound -- the connectivity cost of partially-assigned hyperedges, which can
only grow as pins are added) lives in the incremental ``PartitionState``
engine: assigning a node is ``engine.apply`` (O(degree)), backtracking is
``engine.undo``.  Processor-permutation symmetry is broken by only allowing
a new processor index once all smaller indices are in use.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..hypergraph import Hypergraph
from .cost import capacity, partition_cost
from .engine import _MAX_P, PartitionState


@dataclasses.dataclass
class ExactResult:
    masks: np.ndarray
    cost: float
    optimal: bool
    nodes_explored: int
    seconds: float


def _candidate_masks(P: int, mode: str) -> list[int]:
    out = []
    for m in range(1, 1 << P):
        k = bin(m).count("1")
        if mode == "none" and k != 1:
            continue
        if mode == "dup" and k > 2:
            continue
        out.append(m)
    # prefer fewer replicas first: cheaper loads, finds good UBs earlier
    out.sort(key=lambda m: (bin(m).count("1"), m))
    return out


def exact_partition(
    hg: Hypergraph,
    P: int,
    eps: float,
    mode: str = "none",
    time_limit: float | None = None,
    ub_masks: np.ndarray | None = None,
) -> ExactResult:
    assert mode in ("none", "dup", "rep")
    if P > _MAX_P:
        raise ValueError(
            f"exact_partition supports P <= {_MAX_P} (2^P subset tables); "
            "wider meshes are heuristic-only -- use partition_heuristic")
    cap = capacity(hg, P, eps) + 1e-9
    t0 = time.monotonic()

    # scalar backend: B&B applies/undoes one tiny assignment per search
    # node, where per-op numpy dispatch would dominate (see engine.py)
    st = PartitionState(hg, P, backend="python")  # unassigned; st.cost = LB
    xinc, inc_edges = hg.xinc, hg.inc_edges
    # order nodes by decreasing total incident edge weight (tight LBs early)
    score = [float(hg.mu[inc_edges[xinc[v]:xinc[v + 1]]].sum())
             for v in range(hg.n)]
    order = sorted(range(hg.n), key=lambda v: -score[v])

    cands = _candidate_masks(P, mode)

    best_cost = np.inf
    best_masks: np.ndarray | None = None
    if ub_masks is not None:
        best_masks = np.asarray(ub_masks).copy()
        best_cost = partition_cost(hg, best_masks, P)

    remaining_w = [0.0] * (hg.n + 1)
    for i in range(hg.n - 1, -1, -1):
        remaining_w[i] = remaining_w[i + 1] + hg.omega[order[i]]

    state = {"explored": 0, "timed_out": False,
             "best_cost": best_cost, "best_masks": best_masks}

    def dfs(idx: int, used_procs: int) -> None:
        if state["timed_out"]:
            return
        state["explored"] += 1
        if time_limit is not None and state["explored"] % 2048 == 0:
            if time.monotonic() - t0 > time_limit:
                state["timed_out"] = True
                return
        if idx == hg.n:
            if st.cost < state["best_cost"] - 1e-12:
                state["best_cost"] = st.cost
                state["best_masks"] = st.masks.copy()
            return
        v = order[idx]
        # capacity feasibility: every remaining node needs >= its weight somewhere
        free = 0.0
        for load in st.loads:
            if load < cap:
                free += cap - load
        if remaining_w[idx] > free + 1e-9:
            return
        w_v = hg.omega[v]
        for m in cands:
            # Symmetry breaking: used processors always form the prefix
            # {0..used_procs-1}; a mask may use any of those plus a
            # *contiguous block* of fresh processors starting at used_procs
            # (fresh processors are mutually symmetric).
            high = m >> used_procs
            if high & (high + 1):
                continue
            # balance check
            ok = True
            mm = m
            while mm:
                p = (mm & -mm).bit_length() - 1
                if st.loads[p] + w_v > cap:
                    ok = False
                    break
                mm &= mm - 1
            if not ok:
                continue
            st.apply(v, m)
            if st.cost < state["best_cost"] - 1e-12:
                dfs(idx + 1, max(used_procs, m.bit_length()))
            st.undo()
            if state["timed_out"]:
                return

    dfs(0, 0)
    seconds = time.monotonic() - t0
    if state["best_masks"] is None:
        raise RuntimeError("no feasible partition found (check eps/P)")
    return ExactResult(
        masks=np.asarray(state["best_masks"]),
        cost=float(state["best_cost"]),
        optimal=not state["timed_out"],
        nodes_explored=state["explored"],
        seconds=seconds,
    )
