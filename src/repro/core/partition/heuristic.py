"""Heuristic partitioner + replication local search for paper-scale instances.

The paper solves instances of 80-500 nodes with a commercial ILP solver and a
5-hour budget; offline, we complement the exact branch-and-bound
(`exact.py`, viable to n ~ 25-40) with:

  * a multi-restart greedy + FM-style refinement baseline (no replication);
  * a replication local search that starts from the non-replicating solution
    and keeps adding (or dropping) replicas while the connectivity cost
    decreases and the balance constraint allows it.  ``max_replicas=2``
    gives the ILP/D search space, ``None`` the ILP/R one.

All move evaluation runs on the incremental-gain ``PartitionState`` engine
(O(degree) per candidate instead of full set-cover recomputation; see
``engine.py``), which is what lets the local search reach hundreds-to-
thousands of nodes.  On top of it sits the frontier-pricing layer
(``core.frontier``): a ``GainCache`` holds every node's candidate deltas,
priced in batched vectorized fronts and invalidated through the
pin-adjacency, so refinement passes are *output-sensitive* -- only nodes
whose gain actually changed are repriced, and they are repriced together
instead of one engine call per node.  Decisions are identical to the
per-node rescan (kept as ``frontier="off"`` for benchmarking); the seed
full-recompute implementation survives in ``reference.py`` as the
equivalence/benchmark oracle.

Tie-breaking rule (shared by every move selection below, and pinned by
``tests/test_frontier.py``): candidate masks are generated in **ascending
processor order** and the first minimum wins (``int(np.argmin(...))``
returns the lowest index), i.e. ties go to the lowest processor id.  Any
batched backend must reproduce this, which is why the frontier candidate
builders emit masks in ascending-q order and the front reduction is
bit-equal to the scalar engine deltas.

This mirrors the paper's observation (§8) that replication comes "for free":
the per-partition capacity is unchanged, replicas only consume slack.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque

import numpy as np

from ..hypergraph import Hypergraph
from .cost import capacity, edge_cost, min_cover, partition_cost  # noqa: F401
from .engine import _MAX_P, PartitionState


@dataclasses.dataclass
class HeuristicResult:
    masks: np.ndarray
    cost: float


def greedy_initial(hg: Hypergraph, P: int, eps: float, rng: np.random.Generator) -> np.ndarray:
    """BFS-grow partitions over the pin-adjacency, balanced by weight.

    Stage entry point: the flat heuristic seeds every restart with it, the
    multilevel V-cycle (``multilevel.py``) only ever runs it at the
    coarsest level.
    """
    cap_target = float(hg.omega.sum()) / P  # aim for perfect balance
    xadj, adj = hg.xadj, hg.adj_nodes
    visited = np.zeros(hg.n, dtype=bool)
    part = np.zeros(hg.n, dtype=np.int64)
    order = rng.permutation(hg.n)
    cur_p, cur_w = 0, 0.0

    # in_queue dedupes the multiset pin-adjacency: only a node's *first*
    # queue occurrence is ever visited, so dropping later duplicates keeps
    # the BFS order (and hence the partition) bit-identical while cutting
    # queue traffic from O(sum deg^2) to O(n)
    queue: deque[int] = deque()
    in_queue = np.zeros(hg.n, dtype=bool)
    qi = 0
    while True:
        if not queue:
            while qi < hg.n and visited[order[qi]]:
                qi += 1
            if qi == hg.n:
                break
            queue.append(order[qi])
            in_queue[order[qi]] = True
        v = queue.popleft()
        if visited[v]:
            continue
        visited[v] = True
        if cur_w + hg.omega[v] > cap_target and cur_p < P - 1:
            cur_p += 1
            cur_w = 0.0
        part[v] = cur_p
        cur_w += hg.omega[v]
        nbr = adj[xadj[v]:xadj[v + 1]]
        fresh = nbr[~(visited[nbr] | in_queue[nbr])]
        if len(fresh):
            first = np.sort(np.unique(fresh, return_index=True)[1])
            fresh = fresh[first]
            in_queue[fresh] = True
            queue.extend(fresh.tolist())
    return (1 << part).astype(np.int64)


def fm_refine(hg: Hypergraph, masks: np.ndarray, P: int, eps: float,
              rng: np.random.Generator, passes: int = 6,
              state: PartitionState | None = None,
              frontier: str | None = None,
              nodes: np.ndarray | None = None) -> np.ndarray:
    """Move-based refinement (single-assignment masks), engine-backed.

    Stage entry point, independently callable with externally supplied
    masks or a live ``PartitionState`` (the multilevel V-cycle hands it
    the state built from projected masks at every level).

    Default path: a frontier ``GainCache`` prices the whole node front in
    one batched call per pass and thereafter only nodes adjacent to an
    applied move (output-sensitive FM).  ``frontier="off"`` keeps the
    per-node rescan; both take identical decisions (ties to the lowest
    processor id, see the module docstring).

    ``nodes`` (optional sorted id array) restricts the sweep to those
    movers -- the process-parallel layer's shard/boundary passes.  With
    ``nodes=None`` the RNG consumption is byte-identical to before the
    parameter existed (one ``permutation(hg.n)`` per pass).
    """
    cap = capacity(hg, P, eps) + 1e-9
    st = state if state is not None else PartitionState(hg, P, masks=masks)
    if nodes is not None:
        nodes = np.asarray(nodes, dtype=np.int64)
    if frontier != "off" and nodes is None:
        # jax backend, large instance: run whole passes device-resident
        # (one host sync per committed move; decisions bit-identical --
        # see kernels.front_pass).  Falls through to the numpy front path
        # whenever the device pass cannot hold the instance exactly.
        from ..frontier.partition_front import device_pass
        dev = device_pass(st, cap, backend=frontier)
        if dev is not None:
            try:
                dev.run_fm(rng, passes)
            finally:
                dev.detach()
            masks[:] = st.masks
            return masks
    if frontier == "off":
        for _ in range(passes):
            improved = False
            for v in (rng.permutation(hg.n) if nodes is None
                      else nodes[rng.permutation(len(nodes))]):
                v = int(v)
                p = int(st.masks[v]).bit_length() - 1
                targets = [q for q in range(P)
                           if q != p and st.fits(v, q, cap)]
                if not targets:
                    continue
                deltas = st.delta_masks(v, np.array([1 << q for q in targets]))
                best = int(np.argmin(deltas))
                if deltas[best] < -1e-12:
                    st.apply(v, 1 << targets[best])
                    st.commit()
                    improved = True
            if not improved:
                break
        masks[:] = st.masks
        return masks
    from ..frontier import (GainCache, fm_move_candidates,
                            lookahead_window, refresh_boundary_window)
    cache = GainCache(st, fm_move_candidates, backend=frontier)
    W = lookahead_window(st)
    # on high-degree instances (dense coarse multilevel levels) a window
    # refresh prices mostly nodes that get re-dirtied before their visit;
    # lazy singleton refreshes in cache.get keep every visit O(deg * K)
    # with no thrash.  Purely a batching choice: values stay exact either
    # way, so decisions cannot change.
    use_windows = len(st.pins) <= 128 * max(hg.n, 1)
    xinc, inc_edges = st.xinc, st.inc_edges
    elam = st.edge_lambda  # updated in place by apply/undo
    # boundary filter (exact at visit time, mirrors the per-node rescan):
    # if every incident edge has lambda <= 1, each one is covered by a
    # single processor every pin of it shares -- re-masking v can only
    # raise its lambda, so no candidate is strictly improving and the node
    # skips pricing entirely (decision-identical; interior nodes are the
    # vast majority of a refined partition).  Boundary status can only
    # change when a pin sharing an edge is re-masked -- the same event
    # that dirties the gain cache -- so it is memoized per node and
    # re-derived only after an adjacent move (``bnd_fresh``).
    bnd = np.zeros(hg.n, dtype=bool)
    bnd_fresh = np.zeros(hg.n, dtype=bool)
    xadj, adj_nodes = hg.xadj, hg.adj_nodes
    for _ in range(passes):
        improved = False
        perm = (rng.permutation(hg.n) if nodes is None
                else nodes[rng.permutation(len(nodes))])
        for i, v in enumerate(perm):
            if not bnd_fresh[v]:
                inc = inc_edges[xinc[v]:xinc[v + 1]]
                bnd[v] = inc.size > 0 and int(elam[inc].max()) > 1
                bnd_fresh[v] = True
            if not bnd[v]:
                continue
            if use_windows and cache.is_dirty(v):
                # lookahead: reprice the boundary part of the window in
                # one go (shared rule, see frontier.refresh_boundary_window)
                refresh_boundary_window(cache, perm, i, W)
            cands, deltas = cache.get(v)
            # capacity filter at decision time (loads move on every apply;
            # cost deltas do not depend on them) -- ascending q order
            sel = [j for j in range(len(cands))
                   if st.fits(v, int(cands[j]).bit_length() - 1, cap)]
            if not sel:
                continue
            sub = deltas[sel]
            best = int(np.argmin(sub))  # first minimum: lowest processor id
            if sub[best] < -1e-12:
                st.apply(v, int(cands[sel[best]]))
                st.commit()
                cache.invalidate_move(v)
                bnd_fresh[adj_nodes[xadj[v]:xadj[v + 1]]] = False
                bnd_fresh[v] = False
                improved = True
        if not improved:
            break
    masks[:] = st.masks
    return masks


def partition_heuristic(hg: Hypergraph, P: int, eps: float,
                        restarts: int = 4, seed: int = 0,
                        frontier: str | None = None) -> HeuristicResult:
    """Non-replicating baseline: greedy initial + FM refinement, best of restarts.

    ``frontier`` selects the gain-pricing path: ``None`` (the frontier
    layer's default backend), ``"numpy"`` / ``"jax"`` explicitly, or
    ``"off"`` for the pre-frontier per-node rescan -- all decision-
    identical.
    """
    if P > _MAX_P:  # beyond the engine's 2^P tables: scalar reference path
        from .reference import partition_heuristic_reference
        masks, cost = partition_heuristic_reference(hg, P, eps,
                                                    restarts=restarts,
                                                    seed=seed)
        return HeuristicResult(masks=masks, cost=cost)
    rng = np.random.default_rng(seed)
    best_masks, best_cost = None, np.inf
    for _ in range(restarts):
        masks = greedy_initial(hg, P, eps, rng)
        st = PartitionState(hg, P, masks=masks)
        fm_refine(hg, masks, P, eps, rng, state=st, frontier=frontier)
        if st.cost < best_cost:
            best_cost, best_masks = st.cost, st.masks.copy()
    return HeuristicResult(masks=best_masks, cost=float(best_cost))


def replicate_local_search(
    hg: Hypergraph,
    masks: np.ndarray,
    P: int,
    eps: float,
    max_replicas: int | None = None,
    max_passes: int = 30,
    seed: int = 0,
    frontier: str | None = None,
    state: PartitionState | None = None,
    nodes: np.ndarray | None = None,
) -> HeuristicResult:
    """Add/drop replicas while the (lambda_e - 1) cost decreases.

    Starts from any valid assignment (typically the non-replicating optimum
    or heuristic solution, as the paper suggests for warm-starting ILPs in
    §C.1.1).  Stage entry point: pass ``state`` to search on a live
    ``PartitionState`` instead of rebuilding one from ``masks`` (the
    multilevel V-cycle supplies the state built from projected masks; the
    search then refines it in place).  Add-replica candidates are priced
    through the frontier ``GainCache`` (batched, output-sensitive;
    ``frontier="off"`` keeps the per-node engine rescan -- identical
    decisions, ties to the lowest processor id); drops and the multi-pin
    edge-guided move stay on the engine's scalar delta / apply+undo path.

    ``nodes`` (optional sorted id array) restricts every mover -- the node
    sweep visits only those nodes and the edge-guided move may only
    replicate onto processors whose minority pins all lie inside the set
    (the process-parallel layer's shard/boundary discipline).  With
    ``nodes=None`` the RNG consumption is byte-identical to before the
    parameter existed.
    """
    if P > _MAX_P:  # beyond the engine's 2^P tables: scalar reference path
        from .reference import replicate_local_search_reference
        out_masks, cost = replicate_local_search_reference(
            hg, masks, P, eps, max_replicas=max_replicas,
            max_passes=max_passes, seed=seed)
        return HeuristicResult(masks=out_masks, cost=cost)
    rng = np.random.default_rng(seed)
    st = (state if state is not None
          else PartitionState(hg, P, masks=np.asarray(masks, dtype=np.int64)))
    cap = capacity(hg, P, eps) + 1e-9
    xpins, pins = hg.xpins, hg.pins
    cache = None
    dev = None
    W = 64
    use_windows = len(st.pins) <= 128 * max(hg.n, 1)  # cf. fm_refine
    allowed = None
    if nodes is not None:
        nodes = np.asarray(nodes, dtype=np.int64)
        allowed = np.zeros(hg.n, dtype=bool)
        allowed[nodes] = True
    if frontier != "off" and nodes is None:
        # device-resident node sweep (cf. fm_refine): the edge-guided phase
        # stays on the host engine, whose apply/undo hook keeps the device
        # mirror synced; the add/drop sweep runs on device with one host
        # sync per committed move
        from ..frontier.partition_front import device_pass
        dev = device_pass(st, cap, backend=frontier)
    if frontier != "off" and dev is None:
        from ..frontier import (GainCache, connected_add_candidates,
                                lookahead_window, refresh_boundary_window)
        cache = GainCache(st, connected_add_candidates, backend=frontier)
        W = lookahead_window(st)
    # memoized boundary status, invalidated through the pin-adjacency on
    # every applied mutation (cf. fm_refine: exact at visit time)
    bnd = np.zeros(hg.n, dtype=bool)
    bnd_fresh = np.zeros(hg.n, dtype=bool)

    def _moved(v: int) -> None:
        if cache is not None:
            cache.invalidate_move(v)
        bnd_fresh[hg.adj_nodes[hg.xadj[v]:hg.xadj[v + 1]]] = False
        bnd_fresh[v] = False

    allp = np.arange(P, dtype=np.int64)

    def try_edge_move(ei: int) -> bool:
        """Edge-guided move: a hyperedge with lambda>=2 whose minority side
        has few pins can often be closed by replicating ALL minority pins
        at once (single-node moves cannot improve an 8-pin hyperedge).

        One vectorized (|e|, P) scan replaces the per-processor python
        listcomps; the winner rule is unchanged (fewest movers, ties to
        the lowest processor id)."""
        if st.lambda_of(ei) < 2:
            return False
        e = pins[xpins[ei]:xpins[ei + 1]]
        masks_e = st.masks[e]
        off = ((masks_e[:, None] >> allp[None, :]) & 1) == 0   # (|e|, P)
        cnt = off.sum(axis=0)
        w = hg.omega[e] @ off
        ok = (cnt > 0) & (np.asarray(st.loads) + w <= cap)
        if allowed is not None:
            # shard discipline: only processors whose minority pins are all
            # permitted movers are eligible (other pins stay untouched)
            ok &= ~(off & ~allowed[e][:, None]).any(axis=0)
        if max_replicas is not None:
            at_cap = st.popcnt[masks_e] >= max_replicas
            ok &= ~(off & at_cap[:, None]).any(axis=0)
        if not ok.any():
            return False
        cnt_ok = np.where(ok, cnt, len(e) + 1)
        p = int(np.argmin(cnt_ok))        # fewest movers, ties: lowest p
        movers = [int(v) for v in e[off[:, p]]]
        delta = 0.0
        for v in movers:
            delta += st.apply(v, int(st.masks[v]) | (1 << p))
        if delta < -1e-12:
            st.commit()
            for v in movers:
                _moved(v)
            return True
        st.undo(len(movers))
        return False

    def _node_sweep(perm: np.ndarray) -> bool:
        improved = False
        for i, v in enumerate(perm):
            m = int(st.masks[v])
            k = bin(m).count("1")
            # boundary filter for the add step (visit-time exact, mirrors
            # fm_refine): adding a replica can only lower an edge's lambda
            # if some incident edge has lambda >= 2, so interior nodes have
            # no strictly improving add candidate and skip the pricing
            if not bnd_fresh[v]:
                inc = st.inc_edges[st.xinc[v]:st.xinc[v + 1]]
                bnd[v] = inc.size > 0 and int(st.edge_lambda[inc].max()) > 1
                bnd_fresh[v] = True
            # --- try adding a replica ---
            if bnd[v] and (max_replicas is None or k < max_replicas):
                if cache is not None:
                    if use_windows and cache.is_dirty(v):
                        refresh_boundary_window(cache, perm, i, W)
                    cands, deltas = cache.get(v)
                    sel = [j for j in range(len(cands))
                           if st.fits(v, (int(cands[j]) ^ m).bit_length() - 1,
                                      cap)]
                else:
                    adds = [p for p in range(P)
                            if not (m >> p) & 1 and st.fits(v, p, cap)]
                    sel = []
                    if adds:
                        cands = np.array([m | (1 << p) for p in adds],
                                         dtype=np.int64)
                        deltas = st.delta_masks(v, cands)
                        sel = list(range(len(adds)))
                if sel:
                    sub = deltas[sel]
                    best = int(np.argmin(sub))  # ties: lowest processor id
                    if sub[best] < -1e-12:
                        st.apply(v, int(cands[sel[best]]))
                        st.commit()
                        _moved(v)
                        improved = True
                        continue
            # --- try dropping a replica (free the balance slack) ---
            if k > 1:
                for p in range(P):
                    m = int(st.masks[v])
                    if bin(m).count("1") <= 1:
                        break
                    if not (m >> p) & 1:
                        continue
                    if st.delta_drop_replica(v, p) <= 1e-12:
                        st.apply(v, m & ~(1 << p))
                        st.commit()
                        _moved(v)
                        improved = True
        return improved

    try:
        for _ in range(max_passes):
            improved = False
            for ei in rng.permutation(len(hg.edges)):
                if try_edge_move(int(ei)):
                    improved = True
            perm = (rng.permutation(hg.n) if nodes is None
                    else nodes[rng.permutation(len(nodes))])
            if dev is not None:
                # device node sweep: same permutation, same decisions
                if dev.rep_pass(perm, max_replicas):
                    improved = True
            elif _node_sweep(perm):
                improved = True
            if not improved:
                break
    finally:
        if dev is not None:
            dev.detach()
    return HeuristicResult(masks=st.masks.copy(), cost=float(st.cost))


def partition_with_replication(
    hg: Hypergraph,
    P: int,
    eps: float,
    mode: str = "rep",
    exact_node_limit: int = 24,
    time_limit: float | None = 20.0,
    seed: int = 0,
    frontier: str | None = None,
    multilevel: bool = False,
    workers: int | None = None,
):
    """End-to-end entry: returns (non_repl_result, repl_result).

    Small instances are solved exactly (both with and without replication,
    i.e. the paper's base-ILP vs ILP/D or ILP/R comparison) regardless of
    ``multilevel``; larger ones use the heuristic + replication local
    search.  ``multilevel=True`` routes that *heuristic* path through the
    V-cycle driver (``multilevel.partition_with_replication_multilevel``)
    -- required for production-scale instances (n ~ 10^4-10^5), same
    semantics as the flat search (never-worse cost, identical validity).
    ``workers=W`` (multilevel only) runs the V-cycle's coarsening scores
    and refinement shards on a W-process shared-memory pool
    (``core.partition.parallel``); cost stays never-worse -- the parallel
    reconciliation accepts improving moves only -- but the refinement
    trajectory may diverge from serial (disclosed in the benches).
    """
    from .exact import exact_partition

    if hg.n <= exact_node_limit and P <= _MAX_P:
        base = exact_partition(hg, P, eps, mode="none", time_limit=time_limit)
        rep = exact_partition(hg, P, eps, mode=mode, time_limit=time_limit,
                              ub_masks=base.masks)
        return base, rep
    if multilevel:
        from .multilevel import partition_with_replication_multilevel
        return partition_with_replication_multilevel(
            hg, P, eps, mode=mode, seed=seed, frontier=frontier,
            workers=workers)
    base = partition_heuristic(hg, P, eps, seed=seed, frontier=frontier)
    max_replicas = 2 if mode == "dup" else None
    # alternate replication local search with FM passes on the primary
    # copies (the paper's ILP optimizes base assignment and replicas
    # jointly; two-phase search alone gets stuck, cf. §C.1.1)
    best = replicate_local_search(hg, base.masks.copy(), P, eps,
                                  max_replicas=max_replicas, seed=seed,
                                  frontier=frontier)
    if P > _MAX_P:
        from .reference import fm_refine_reference as _refine
    else:
        _refine = functools.partial(fm_refine, frontier=frontier)
    for r in range(3):
        masks = best.masks.copy()
        # re-run FM treating each node's first replica as its home
        primary = np.array([1 << (int(m).bit_length() - 1) for m in masks])
        moved = _refine(hg, primary.copy(), P, eps,
                        np.random.default_rng(seed + r + 1))
        cand = replicate_local_search(hg, moved, P, eps,
                                      max_replicas=max_replicas,
                                      seed=seed + r + 1,
                                      frontier=frontier)
        if cand.cost < best.cost - 1e-12:
            best = cand
        else:
            break
    return base, best


# Pre-PR 4 private names of the stage entry points, kept as aliases.
_greedy_initial = greedy_initial
_fm_refine = fm_refine
