"""Heuristic partitioner + replication local search for paper-scale instances.

The paper solves instances of 80-500 nodes with a commercial ILP solver and a
5-hour budget; offline, we complement the exact branch-and-bound
(`exact.py`, viable to n ~ 25-40) with:

  * a multi-restart greedy + FM-style refinement baseline (no replication);
  * a replication local search that starts from the non-replicating solution
    and keeps adding (or dropping) replicas while the connectivity cost
    decreases and the balance constraint allows it.  ``max_replicas=2``
    gives the ILP/D search space, ``None`` the ILP/R one.

This mirrors the paper's observation (§8) that replication comes "for free":
the per-partition capacity is unchanged, replicas only consume slack.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..hypergraph import Hypergraph
from .cost import capacity, edge_cost, min_cover, partition_cost  # noqa: F401


@dataclasses.dataclass
class HeuristicResult:
    masks: np.ndarray
    cost: float


def _greedy_initial(hg: Hypergraph, P: int, eps: float, rng: np.random.Generator) -> np.ndarray:
    """BFS-grow partitions over the pin-adjacency, balanced by weight."""
    cap_target = float(hg.omega.sum()) / P  # aim for perfect balance
    inc = hg.incident_edges()
    visited = np.zeros(hg.n, dtype=bool)
    part = np.zeros(hg.n, dtype=np.int64)
    order = rng.permutation(hg.n)
    cur_p, cur_w = 0, 0.0
    from collections import deque

    queue: deque[int] = deque()
    qi = 0
    while True:
        if not queue:
            while qi < hg.n and visited[order[qi]]:
                qi += 1
            if qi == hg.n:
                break
            queue.append(order[qi])
        v = queue.popleft()
        if visited[v]:
            continue
        visited[v] = True
        if cur_w + hg.omega[v] > cap_target and cur_p < P - 1:
            cur_p += 1
            cur_w = 0.0
        part[v] = cur_p
        cur_w += hg.omega[v]
        for ei in inc[v]:
            for u in hg.edges[ei]:
                if not visited[u]:
                    queue.append(u)
    return (1 << part).astype(np.int64)


def _fm_refine(hg: Hypergraph, masks: np.ndarray, P: int, eps: float,
               rng: np.random.Generator, passes: int = 6) -> np.ndarray:
    """Move-based refinement (single-assignment masks)."""
    cap = capacity(hg, P, eps) + 1e-9
    inc = hg.incident_edges()
    load = np.zeros(P)
    for v in range(hg.n):
        load[int(masks[v]).bit_length() - 1] += hg.omega[v]

    def incident_cost(v: int) -> float:
        return sum(edge_cost(hg, masks, ei, P) for ei in inc[v])

    for _ in range(passes):
        improved = False
        for v in rng.permutation(hg.n):
            p = int(masks[v]).bit_length() - 1
            base = incident_cost(v)
            best_gain, best_q = 0.0, -1
            for q in range(P):
                if q == p or load[q] + hg.omega[v] > cap:
                    continue
                masks[v] = 1 << q
                gain = base - incident_cost(v)
                masks[v] = 1 << p
                if gain > best_gain + 1e-12:
                    best_gain, best_q = gain, q
            if best_q >= 0:
                masks[v] = 1 << best_q
                load[p] -= hg.omega[v]
                load[best_q] += hg.omega[v]
                improved = True
        if not improved:
            break
    return masks


def partition_heuristic(hg: Hypergraph, P: int, eps: float,
                        restarts: int = 4, seed: int = 0) -> HeuristicResult:
    """Non-replicating baseline: greedy initial + FM refinement, best of restarts."""
    rng = np.random.default_rng(seed)
    best_masks, best_cost = None, np.inf
    for _ in range(restarts):
        masks = _greedy_initial(hg, P, eps, rng)
        masks = _fm_refine(hg, masks, P, eps, rng)
        c = partition_cost(hg, masks, P)
        if c < best_cost:
            best_cost, best_masks = c, masks.copy()
    return HeuristicResult(masks=best_masks, cost=float(best_cost))


def replicate_local_search(
    hg: Hypergraph,
    masks: np.ndarray,
    P: int,
    eps: float,
    max_replicas: int | None = None,
    max_passes: int = 30,
    seed: int = 0,
) -> HeuristicResult:
    """Add/drop replicas while the (lambda_e - 1) cost decreases.

    Starts from any valid assignment (typically the non-replicating optimum
    or heuristic solution, as the paper suggests for warm-starting ILPs in
    §C.1.1).
    """
    rng = np.random.default_rng(seed)
    masks = np.asarray(masks, dtype=np.int64).copy()
    cap = capacity(hg, P, eps) + 1e-9
    inc = hg.incident_edges()
    load = np.zeros(P)
    for v in range(hg.n):
        m = int(masks[v])
        for p in range(P):
            if (m >> p) & 1:
                load[p] += hg.omega[v]

    def incident_cost(v: int) -> float:
        return sum(edge_cost(hg, masks, ei, P) for ei in inc[v])

    def try_edge_move(ei: int) -> bool:
        """Edge-guided move: a hyperedge with lambda=2 whose minority side
        has few pins can often be closed by replicating ALL minority pins
        at once (single-node moves cannot improve an 8-pin hyperedge)."""
        e = hg.edges[ei]
        pin_masks = [int(masks[v]) for v in e]
        lam = min_cover(pin_masks, P)
        if lam < 2:
            return False
        # try to cover the edge with each single processor
        best = None
        for p in range(P):
            movers = [v for v in e if not (int(masks[v]) >> p) & 1]
            if not movers:
                continue
            if max_replicas is not None and any(
                    bin(int(masks[v])).count("1") >= max_replicas
                    for v in movers):
                continue
            w = sum(hg.omega[v] for v in movers)
            if load[p] + w > cap:
                continue
            if best is None or len(movers) < len(best[1]):
                best = (p, movers, w)
        if best is None:
            return False
        p, movers, w = best
        touched = sorted({e2 for v in movers for e2 in inc[v]})
        before = sum(edge_cost(hg, masks, e2, P) for e2 in touched)
        old = [int(masks[v]) for v in movers]
        for v in movers:
            masks[v] = int(masks[v]) | (1 << p)
        after = sum(edge_cost(hg, masks, e2, P) for e2 in touched)
        if after < before - 1e-12:
            load[p] += w
            return True
        for v, m_old in zip(movers, old):
            masks[v] = m_old
        return False

    for _ in range(max_passes):
        improved = False
        for ei in rng.permutation(len(hg.edges)):
            if try_edge_move(int(ei)):
                improved = True
        for v in rng.permutation(hg.n):
            m = int(masks[v])
            k = bin(m).count("1")
            base = incident_cost(v)
            # --- try adding a replica ---
            if max_replicas is None or k < max_replicas:
                best_gain, best_p = 0.0, -1
                for p in range(P):
                    if (m >> p) & 1 or load[p] + hg.omega[v] > cap:
                        continue
                    masks[v] = m | (1 << p)
                    gain = base - incident_cost(v)
                    masks[v] = m
                    if gain > best_gain + 1e-12:
                        best_gain, best_p = gain, p
                if best_p >= 0:
                    masks[v] = m | (1 << best_p)
                    load[best_p] += hg.omega[v]
                    improved = True
                    continue
            # --- try dropping a replica (free the balance slack) ---
            if k > 1:
                for p in range(P):
                    if bin(m).count("1") <= 1:
                        break
                    if not (m >> p) & 1:
                        continue
                    masks[v] = m & ~(1 << p)
                    if incident_cost(v) <= base + 1e-12:
                        load[p] -= hg.omega[v]
                        improved = True
                        m = int(masks[v])
                        base = incident_cost(v)
                    else:
                        masks[v] = m
        if not improved:
            break
    return HeuristicResult(masks=masks, cost=partition_cost(hg, masks, P))


def partition_with_replication(
    hg: Hypergraph,
    P: int,
    eps: float,
    mode: str = "rep",
    exact_node_limit: int = 24,
    time_limit: float | None = 20.0,
    seed: int = 0,
):
    """End-to-end entry: returns (non_repl_result, repl_result).

    Small instances are solved exactly (both with and without replication,
    i.e. the paper's base-ILP vs ILP/D or ILP/R comparison); larger ones use
    the heuristic + replication local search.
    """
    from .exact import exact_partition

    if hg.n <= exact_node_limit:
        base = exact_partition(hg, P, eps, mode="none", time_limit=time_limit)
        rep = exact_partition(hg, P, eps, mode=mode, time_limit=time_limit,
                              ub_masks=base.masks)
        return base, rep
    base = partition_heuristic(hg, P, eps, seed=seed)
    max_replicas = 2 if mode == "dup" else None
    # alternate replication local search with FM passes on the primary
    # copies (the paper's ILP optimizes base assignment and replicas
    # jointly; two-phase search alone gets stuck, cf. §C.1.1)
    best = replicate_local_search(hg, base.masks.copy(), P, eps,
                                  max_replicas=max_replicas, seed=seed)
    for r in range(3):
        masks = best.masks.copy()
        # re-run FM treating each node's first replica as its home
        primary = np.array([1 << (int(m).bit_length() - 1) for m in masks])
        moved = _fm_refine(hg, primary.copy(), P, eps,
                           np.random.default_rng(seed + r + 1))
        cand = replicate_local_search(hg, moved, P, eps,
                                      max_replicas=max_replicas,
                                      seed=seed + r + 1)
        if cand.cost < best.cost - 1e-12:
            best = cand
        else:
            break
    return base, best
