"""Heuristic partitioner + replication local search for paper-scale instances.

The paper solves instances of 80-500 nodes with a commercial ILP solver and a
5-hour budget; offline, we complement the exact branch-and-bound
(`exact.py`, viable to n ~ 25-40) with:

  * a multi-restart greedy + FM-style refinement baseline (no replication);
  * a replication local search that starts from the non-replicating solution
    and keeps adding (or dropping) replicas while the connectivity cost
    decreases and the balance constraint allows it.  ``max_replicas=2``
    gives the ILP/D search space, ``None`` the ILP/R one.

All move evaluation runs on the incremental-gain ``PartitionState`` engine
(O(degree) per candidate instead of full set-cover recomputation; see
``engine.py``), which is what lets the local search reach hundreds-to-
thousands of nodes.  On top of it sits the frontier-pricing layer
(``core.frontier``): a ``GainCache`` holds every node's candidate deltas,
priced in batched vectorized fronts and invalidated through the
pin-adjacency, so refinement passes are *output-sensitive* -- only nodes
whose gain actually changed are repriced, and they are repriced together
instead of one engine call per node.  Decisions are identical to the
per-node rescan (kept as ``frontier="off"`` for benchmarking); the seed
full-recompute implementation survives in ``reference.py`` as the
equivalence/benchmark oracle.

Tie-breaking rule (shared by every move selection below, and pinned by
``tests/test_frontier.py``): candidate masks are generated in **ascending
processor order** and the first minimum wins (``int(np.argmin(...))``
returns the lowest index), i.e. ties go to the lowest processor id.  Any
batched backend must reproduce this, which is why the frontier candidate
builders emit masks in ascending-q order and the front reduction is
bit-equal to the scalar engine deltas.

This mirrors the paper's observation (§8) that replication comes "for free":
the per-partition capacity is unchanged, replicas only consume slack.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque

import numpy as np

from ..hypergraph import Hypergraph
from .cost import capacity, edge_cost, min_cover, partition_cost  # noqa: F401
from .engine import _MAX_P, PartitionState


@dataclasses.dataclass
class HeuristicResult:
    masks: np.ndarray
    cost: float


def _greedy_initial(hg: Hypergraph, P: int, eps: float, rng: np.random.Generator) -> np.ndarray:
    """BFS-grow partitions over the pin-adjacency, balanced by weight."""
    cap_target = float(hg.omega.sum()) / P  # aim for perfect balance
    xadj, adj = hg.xadj, hg.adj_nodes
    visited = np.zeros(hg.n, dtype=bool)
    part = np.zeros(hg.n, dtype=np.int64)
    order = rng.permutation(hg.n)
    cur_p, cur_w = 0, 0.0

    # in_queue dedupes the multiset pin-adjacency: only a node's *first*
    # queue occurrence is ever visited, so dropping later duplicates keeps
    # the BFS order (and hence the partition) bit-identical while cutting
    # queue traffic from O(sum deg^2) to O(n)
    queue: deque[int] = deque()
    in_queue = np.zeros(hg.n, dtype=bool)
    qi = 0
    while True:
        if not queue:
            while qi < hg.n and visited[order[qi]]:
                qi += 1
            if qi == hg.n:
                break
            queue.append(order[qi])
            in_queue[order[qi]] = True
        v = queue.popleft()
        if visited[v]:
            continue
        visited[v] = True
        if cur_w + hg.omega[v] > cap_target and cur_p < P - 1:
            cur_p += 1
            cur_w = 0.0
        part[v] = cur_p
        cur_w += hg.omega[v]
        nbr = adj[xadj[v]:xadj[v + 1]]
        fresh = nbr[~(visited[nbr] | in_queue[nbr])]
        if len(fresh):
            first = np.sort(np.unique(fresh, return_index=True)[1])
            fresh = fresh[first]
            in_queue[fresh] = True
            queue.extend(fresh.tolist())
    return (1 << part).astype(np.int64)


def _fm_refine(hg: Hypergraph, masks: np.ndarray, P: int, eps: float,
               rng: np.random.Generator, passes: int = 6,
               state: PartitionState | None = None,
               frontier: str | None = None) -> np.ndarray:
    """Move-based refinement (single-assignment masks), engine-backed.

    Default path: a frontier ``GainCache`` prices the whole node front in
    one batched call per pass and thereafter only nodes adjacent to an
    applied move (output-sensitive FM).  ``frontier="off"`` keeps the
    per-node rescan; both take identical decisions (ties to the lowest
    processor id, see the module docstring).
    """
    cap = capacity(hg, P, eps) + 1e-9
    st = state if state is not None else PartitionState(hg, P, masks=masks)
    if frontier == "off":
        for _ in range(passes):
            improved = False
            for v in rng.permutation(hg.n):
                p = int(st.masks[v]).bit_length() - 1
                targets = [q for q in range(P)
                           if q != p and st.fits(v, q, cap)]
                if not targets:
                    continue
                deltas = st.delta_masks(v, np.array([1 << q for q in targets]))
                best = int(np.argmin(deltas))
                if deltas[best] < -1e-12:
                    st.apply(v, 1 << targets[best])
                    st.commit()
                    improved = True
            if not improved:
                break
        masks[:] = st.masks
        return masks
    from ..frontier import GainCache, move_candidates
    cache = GainCache(st, move_candidates, backend=frontier)
    for _ in range(passes):
        improved = False
        cache.refresh_dirty()  # batch-reprice everything a move touched
        perm = rng.permutation(hg.n)
        for i, v in enumerate(perm):
            if cache.is_dirty(v):  # lookahead: reprice the window in one go
                cache.refresh_window(perm[i:i + 64])
            cands, deltas = cache.get(v)
            # capacity filter at decision time (loads move on every apply;
            # cost deltas do not depend on them) -- ascending q order
            sel = [j for j in range(len(cands))
                   if st.fits(v, int(cands[j]).bit_length() - 1, cap)]
            if not sel:
                continue
            sub = deltas[sel]
            best = int(np.argmin(sub))  # first minimum: lowest processor id
            if sub[best] < -1e-12:
                st.apply(v, int(cands[sel[best]]))
                st.commit()
                cache.invalidate_move(v)
                improved = True
        if not improved:
            break
    masks[:] = st.masks
    return masks


def partition_heuristic(hg: Hypergraph, P: int, eps: float,
                        restarts: int = 4, seed: int = 0,
                        frontier: str | None = None) -> HeuristicResult:
    """Non-replicating baseline: greedy initial + FM refinement, best of restarts.

    ``frontier`` selects the gain-pricing path: ``None`` (the frontier
    layer's default backend), ``"numpy"`` / ``"jax"`` explicitly, or
    ``"off"`` for the pre-frontier per-node rescan -- all decision-
    identical.
    """
    if P > _MAX_P:  # beyond the engine's 2^P tables: scalar reference path
        from .reference import partition_heuristic_reference
        masks, cost = partition_heuristic_reference(hg, P, eps,
                                                    restarts=restarts,
                                                    seed=seed)
        return HeuristicResult(masks=masks, cost=cost)
    rng = np.random.default_rng(seed)
    best_masks, best_cost = None, np.inf
    for _ in range(restarts):
        masks = _greedy_initial(hg, P, eps, rng)
        st = PartitionState(hg, P, masks=masks)
        _fm_refine(hg, masks, P, eps, rng, state=st, frontier=frontier)
        if st.cost < best_cost:
            best_cost, best_masks = st.cost, st.masks.copy()
    return HeuristicResult(masks=best_masks, cost=float(best_cost))


def replicate_local_search(
    hg: Hypergraph,
    masks: np.ndarray,
    P: int,
    eps: float,
    max_replicas: int | None = None,
    max_passes: int = 30,
    seed: int = 0,
    frontier: str | None = None,
) -> HeuristicResult:
    """Add/drop replicas while the (lambda_e - 1) cost decreases.

    Starts from any valid assignment (typically the non-replicating optimum
    or heuristic solution, as the paper suggests for warm-starting ILPs in
    §C.1.1).  Add-replica candidates are priced through the frontier
    ``GainCache`` (batched, output-sensitive; ``frontier="off"`` keeps the
    per-node engine rescan -- identical decisions, ties to the lowest
    processor id); drops and the multi-pin edge-guided move stay on the
    engine's scalar delta / apply+undo path.
    """
    if P > _MAX_P:  # beyond the engine's 2^P tables: scalar reference path
        from .reference import replicate_local_search_reference
        out_masks, cost = replicate_local_search_reference(
            hg, masks, P, eps, max_replicas=max_replicas,
            max_passes=max_passes, seed=seed)
        return HeuristicResult(masks=out_masks, cost=cost)
    rng = np.random.default_rng(seed)
    st = PartitionState(hg, P, masks=np.asarray(masks, dtype=np.int64))
    cap = capacity(hg, P, eps) + 1e-9
    xpins, pins = hg.xpins, hg.pins
    cache = None
    if frontier != "off":
        from ..frontier import GainCache, add_replica_candidates
        cache = GainCache(st, add_replica_candidates, backend=frontier)

    def try_edge_move(ei: int) -> bool:
        """Edge-guided move: a hyperedge with lambda>=2 whose minority side
        has few pins can often be closed by replicating ALL minority pins
        at once (single-node moves cannot improve an 8-pin hyperedge)."""
        if st.lambda_of(ei) < 2:
            return False
        e = pins[xpins[ei]:xpins[ei + 1]]
        # try to cover the edge with each single processor
        best = None
        for p in range(P):
            movers = [int(v) for v in e if not (int(st.masks[v]) >> p) & 1]
            if not movers:
                continue
            if max_replicas is not None and any(
                    bin(int(st.masks[v])).count("1") >= max_replicas
                    for v in movers):
                continue
            w = sum(hg.omega[v] for v in movers)
            if st.loads[p] + w > cap:
                continue
            if best is None or len(movers) < len(best[1]):
                best = (p, movers)
        if best is None:
            return False
        p, movers = best
        delta = 0.0
        for v in movers:
            delta += st.apply(v, int(st.masks[v]) | (1 << p))
        if delta < -1e-12:
            st.commit()
            if cache is not None:
                for v in movers:
                    cache.invalidate_move(v)
            return True
        st.undo(len(movers))
        return False

    for _ in range(max_passes):
        improved = False
        for ei in rng.permutation(len(hg.edges)):
            if try_edge_move(int(ei)):
                improved = True
        if cache is not None:
            cache.refresh_dirty()  # one batched front instead of n calls
        perm = rng.permutation(hg.n)
        for i, v in enumerate(perm):
            m = int(st.masks[v])
            k = bin(m).count("1")
            # --- try adding a replica ---
            if max_replicas is None or k < max_replicas:
                if cache is not None:
                    if cache.is_dirty(v):
                        cache.refresh_window(perm[i:i + 64])
                    cands, deltas = cache.get(v)
                    sel = [j for j in range(len(cands))
                           if st.fits(v, (int(cands[j]) ^ m).bit_length() - 1,
                                      cap)]
                else:
                    adds = [p for p in range(P)
                            if not (m >> p) & 1 and st.fits(v, p, cap)]
                    sel = []
                    if adds:
                        cands = np.array([m | (1 << p) for p in adds],
                                         dtype=np.int64)
                        deltas = st.delta_masks(v, cands)
                        sel = list(range(len(adds)))
                if sel:
                    sub = deltas[sel]
                    best = int(np.argmin(sub))  # ties: lowest processor id
                    if sub[best] < -1e-12:
                        st.apply(v, int(cands[sel[best]]))
                        st.commit()
                        if cache is not None:
                            cache.invalidate_move(v)
                        improved = True
                        continue
            # --- try dropping a replica (free the balance slack) ---
            if k > 1:
                for p in range(P):
                    m = int(st.masks[v])
                    if bin(m).count("1") <= 1:
                        break
                    if not (m >> p) & 1:
                        continue
                    if st.delta_drop_replica(v, p) <= 1e-12:
                        st.apply(v, m & ~(1 << p))
                        st.commit()
                        if cache is not None:
                            cache.invalidate_move(v)
                        improved = True
        if not improved:
            break
    return HeuristicResult(masks=st.masks.copy(), cost=float(st.cost))


def partition_with_replication(
    hg: Hypergraph,
    P: int,
    eps: float,
    mode: str = "rep",
    exact_node_limit: int = 24,
    time_limit: float | None = 20.0,
    seed: int = 0,
    frontier: str | None = None,
):
    """End-to-end entry: returns (non_repl_result, repl_result).

    Small instances are solved exactly (both with and without replication,
    i.e. the paper's base-ILP vs ILP/D or ILP/R comparison); larger ones use
    the heuristic + replication local search.
    """
    from .exact import exact_partition

    if hg.n <= exact_node_limit and P <= _MAX_P:
        base = exact_partition(hg, P, eps, mode="none", time_limit=time_limit)
        rep = exact_partition(hg, P, eps, mode=mode, time_limit=time_limit,
                              ub_masks=base.masks)
        return base, rep
    base = partition_heuristic(hg, P, eps, seed=seed, frontier=frontier)
    max_replicas = 2 if mode == "dup" else None
    # alternate replication local search with FM passes on the primary
    # copies (the paper's ILP optimizes base assignment and replicas
    # jointly; two-phase search alone gets stuck, cf. §C.1.1)
    best = replicate_local_search(hg, base.masks.copy(), P, eps,
                                  max_replicas=max_replicas, seed=seed,
                                  frontier=frontier)
    if P > _MAX_P:
        from .reference import fm_refine_reference as _refine
    else:
        _refine = functools.partial(_fm_refine, frontier=frontier)
    for r in range(3):
        masks = best.masks.copy()
        # re-run FM treating each node's first replica as its home
        primary = np.array([1 << (int(m).bit_length() - 1) for m in masks])
        moved = _refine(hg, primary.copy(), P, eps,
                        np.random.default_rng(seed + r + 1))
        cand = replicate_local_search(hg, moved, P, eps,
                                      max_replicas=max_replicas,
                                      seed=seed + r + 1,
                                      frontier=frontier)
        if cand.cost < best.cost - 1e-12:
            best = cand
        else:
            break
    return base, best
