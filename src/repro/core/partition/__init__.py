from .cost import (capacity, edge_cost, edge_lambdas, is_balanced, is_valid,
                   loads, min_cover, partition_cost)
from .engine import PartitionState
from .exact import ExactResult, exact_partition
from .heuristic import (HeuristicResult, partition_heuristic,
                        partition_with_replication, replicate_local_search)

__all__ = [
    "capacity", "edge_cost", "edge_lambdas", "is_balanced", "is_valid",
    "loads", "min_cover", "partition_cost", "PartitionState", "ExactResult",
    "exact_partition", "HeuristicResult", "partition_heuristic",
    "partition_with_replication", "replicate_local_search",
]
