from .cost import (capacity, edge_cost, edge_lambdas, is_balanced, is_valid,
                   loads, min_cover, partition_cost)
from .engine import PartitionState
from .exact import ExactResult, exact_partition
from .heuristic import (HeuristicResult, fm_refine, greedy_initial,
                        partition_heuristic, partition_with_replication,
                        replicate_local_search)
from .multilevel import (MultilevelOptions, multilevel_partition,
                         partition_with_replication_multilevel)
from .parallel import (ParallelContext, ShmRegistry, parallel_refine,
                       plan_shards, shm_available)

__all__ = [
    "capacity", "edge_cost", "edge_lambdas", "is_balanced", "is_valid",
    "loads", "min_cover", "partition_cost", "PartitionState", "ExactResult",
    "exact_partition", "HeuristicResult", "fm_refine", "greedy_initial",
    "partition_heuristic", "partition_with_replication",
    "replicate_local_search", "MultilevelOptions", "multilevel_partition",
    "partition_with_replication_multilevel", "ParallelContext",
    "ShmRegistry", "parallel_refine", "plan_shards", "shm_available",
]
