"""Incremental-gain partition engine (the FM-style core of this package).

The seed implementation re-ran exact set cover (``min_cover``) over every
incident hyperedge for each candidate move -- O(deg(v) * pins * 2^P) per
evaluation, which caps local search at toy instance sizes.  ``PartitionState``
maintains enough per-edge state to evaluate any single-node mask change in
O(deg(v) * 2^P) and apply/undo it in the same bound, with exact
``min_cover`` semantics (not the connectivity approximation classical FM
uses).

Representation
--------------
For each hyperedge ``e`` and each processor subset ``S`` (all ``2^P`` of
them) we keep

    uncov[e, S] = #\\{assigned pins v in e : masks[v] & S == 0\\}

i.e. the number of pins *not* covered by ``S``.  Then

    lambda_e = min\\{ popcount(S) : S != 0, uncov[e, S] == 0 \\}

which is exactly the minimum set cover of the pin masks (``uncov[e, 0]``
doubles as the count of assigned pins; unassigned pins -- mask 0 -- are
excluded, so the same state drives the exact solver's monotone lower bound
over partial assignments).  Changing one pin's mask from ``a`` to ``b``
adds the precomputed row ``contrib[b] - contrib[a]`` to ``uncov[e]``: a
table lookup plus a vector add of length ``2^P``.

Complexity (P constant): ``delta_*`` and ``apply`` are O(deg(v) * 2^P);
``undo`` is the same; construction is O(pins * 2^P).  Memory is
O(|E| * 2^P) for ``uncov`` plus the O(4^P) mask tables, which bounds the
engine to P <= 12 (the paper's experiments use P in {2, 4, 8}).

Invariants (asserted by ``check()``):
  * ``uncov`` matches a from-scratch count over current masks;
  * ``edge_lambda[e]`` equals ``min_cover`` of e's assigned pin masks;
  * ``cost == sum_e mu[e] * max(0, edge_lambda[e] - 1)``;
  * ``loads[p] == sum_{v: masks[v] has bit p} omega[v]``.
"""
from __future__ import annotations

import functools

import numpy as np

from ..hypergraph import Hypergraph

_MAX_P = 12


@functools.lru_cache(maxsize=None)
def _tables(P: int):
    """(popcnt, order, order_pc, contrib) for processor count P.

    ``order`` lists the non-empty subsets sorted by popcount (ties by
    value), so the first subset with ``uncov == 0`` is a minimum cover.
    ``contrib[m]`` is the row a pin with mask ``m`` adds to ``uncov``:
    zero for unassigned pins, else ``1 - (m & S != 0)`` over all S.
    """
    if P < 1 or P > _MAX_P:
        raise ValueError(f"engine supports 1 <= P <= {_MAX_P}, got {P}")
    nsub = 1 << P
    subsets = np.arange(nsub)
    popcnt = np.array([bin(s).count("1") for s in range(nsub)], dtype=np.int16)
    order = np.array(sorted(range(1, nsub), key=lambda s: (popcnt[s], s)),
                     dtype=np.int64)
    hits = (subsets[:, None] & subsets[None, :]) != 0        # hits[m, S]
    contrib = (1 - hits.astype(np.int16))
    contrib[0] = 0                                           # mask 0 = unassigned
    return popcnt, order, popcnt[order], contrib


# cap on the (pins x 2^P) gather scratch of one _uncov_rows block
# (elements): construction memory stays bounded at any instance size, which
# is what keeps fresh PartitionState builds from projected masks cheap at
# multilevel scale (n=65536 would otherwise materialize a multi-hundred-MB
# intermediate).  Integer sums are associative, so blocking cannot change
# any row.
_UNCOV_CHUNK_ELEMS = 4_000_000


def _uncov_rows(masks: np.ndarray, pins: np.ndarray, xpins: np.ndarray,
                contrib: np.ndarray) -> np.ndarray:
    """uncov matrix (|E|, 2^P): per edge, sum of its pins' contrib rows.

    Single home of the reduceat segmentation, shared by the engine and the
    batch cost path.  Empty edges (including trailing ones, whose start
    index would fall off the pins array) come out as all-zero rows.
    Processes edges in blocks of at most ``_UNCOV_CHUNK_ELEMS`` scratch
    elements (never splitting an edge), so peak memory is bounded.
    """
    m = len(xpins) - 1
    nsub = contrib.shape[0]
    rows = np.zeros((m, nsub), dtype=np.int32)
    if m == 0 or len(pins) == 0:
        return rows
    # reduceat over non-empty edges only: their starts are strictly
    # increasing and in range, and consecutive non-empty starts delimit
    # exactly one edge's pins (empty edges contribute no pins in between)
    nonempty = xpins[:-1] < xpins[1:]
    chunk_pins = max(_UNCOV_CHUNK_ELEMS // nsub, 1)
    e0 = 0
    while e0 < m:
        # last edge fully contained in the pin budget (at least one edge)
        e1 = int(np.searchsorted(xpins, xpins[e0] + chunk_pins,
                                 side="right")) - 1
        e1 = min(max(e1, e0 + 1), m)
        ne = nonempty[e0:e1]
        if ne.any():
            seg = contrib[masks[pins[xpins[e0]:xpins[e1]]]]
            rows[e0:e1][ne] = np.add.reduceat(
                seg, xpins[e0:e1][ne] - xpins[e0], axis=0)
        e0 = e1
    return rows


def _lambda_from_rows(rows: np.ndarray, order: np.ndarray,
                      order_pc: np.ndarray) -> np.ndarray:
    """Min-cover size per uncov row (0 for rows with no assigned pin).

    Scans the popcount classes of ``order`` smallest-first and retires a
    row at the first class containing a zero -- in a refined partition
    almost every edge has lambda 1 or 2, so most rows only ever touch the
    P singleton columns instead of all 2^P - 1 (output identical to the
    full scan: the value is the *popcount* of the first zero subset, which
    any zero inside the class determines).  For small tables (P <= 6) the
    one-shot argmax over all columns is cheaper than the class loop.
    """
    m = rows.shape[0]
    if m == 0:
        return np.zeros(0, dtype=np.int16)
    if len(order) <= 63:  # P <= 6: full scan is a single vectorized op
        lam = order_pc[np.argmax(rows[:, order] == 0, axis=1)].astype(np.int16)
        lam[rows[:, 0] == 0] = 0
        return lam
    lam = np.zeros(m, dtype=np.int16)
    remaining = np.arange(m)
    # class boundaries: order_pc is sorted ascending (1, ..., P)
    bounds = np.searchsorted(order_pc, np.arange(order_pc[-1] + 2))
    for pc in range(1, int(order_pc[-1]) + 1):
        lo, hi = bounds[pc], bounds[pc + 1]
        hit = (rows[np.ix_(remaining, order[lo:hi])] == 0).any(axis=1)
        lam[remaining[hit]] = pc
        remaining = remaining[~hit]
        if not len(remaining):
            break
    lam[rows[:, 0] == 0] = 0
    return lam


class PartitionState:
    """Mutable partition assignment with O(degree) incremental costs.

    ``masks[v]`` is the processor bitmask of node v; 0 means *unassigned*
    (allowed -- the exact solver grows partial assignments through the same
    engine).  All ``delta_*`` methods are pure; ``apply`` mutates and pushes
    an undo record.

    Two interchangeable backends share the semantics:

      * ``backend='numpy'`` (default): ``uncov`` is one (|E|, 2^P) array and
        every operation is a few vectorized calls -- right for heuristic
        local search, where ``delta_masks`` prices many candidates at once;
      * ``backend='python'``: ``uncov`` rows are plain lists updated in
        pure python -- per-operation numpy dispatch (~microseconds) would
        dominate the branch-and-bound solver, which applies/undoes one tiny
        assignment per search node.
    """

    def __init__(self, hg: Hypergraph, P: int,
                 masks: np.ndarray | None = None,
                 backend: str = "numpy",
                 lambda_hint: np.ndarray | None = None) -> None:
        if backend not in ("numpy", "python"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.hg = hg
        self.P = int(P)
        self.popcnt, self._order, self._order_pc, self._contrib = _tables(P)
        self.xpins = hg.xpins
        self.pins = hg.pins
        self.xinc = hg.xinc
        self.inc_edges = hg.inc_edges
        self.mu = np.asarray(hg.mu, dtype=np.float64)
        self.omega = np.asarray(hg.omega, dtype=np.float64)
        m = len(hg.edges)
        nsub = 1 << self.P
        if masks is None:
            self.masks = np.zeros(hg.n, dtype=np.int64)
        else:
            self.masks = np.asarray(masks, dtype=np.int64).copy()
            if self.masks.shape != (hg.n,):
                raise ValueError("masks must have shape (n,)")
            if np.any(self.masks < 0) or np.any(self.masks >= (1 << self.P)):
                raise ValueError("mask out of range for P")
        # uncov[e] = sum of contrib rows of e's pins  (vectorized build)
        self.uncov = _uncov_rows(self.masks, self.pins, self.xpins,
                                 self._contrib)
        if lambda_hint is not None:
            # caller-supplied per-edge lambdas (``from_projection``): must
            # equal what the subset scan would compute -- skipping the scan
            # is the single costly reduction of a from-masks build
            self.edge_lambda = np.asarray(lambda_hint, dtype=np.int16)
            if self.edge_lambda.shape != (m,):
                raise ValueError("lambda_hint must have shape (|E|,)")
        else:
            self.edge_lambda = self._lambda_rows(self.uncov)
        self.cost = float(
            (self.mu * np.maximum(self.edge_lambda - 1, 0)).sum())
        bits = (self.masks[:, None] >> np.arange(self.P)) & 1
        self.loads = (bits * self.omega[:, None]).sum(axis=0)
        self._undo: list[tuple[int, int, list | np.ndarray]] = []
        # optional device mirror (kernels.front_pass.DevicePartitionPass):
        # when attached, every numpy-backend apply/undo forwards the
        # (v, old, new) mutation so the device buffers stay in lockstep
        self.device = None
        if backend == "python":
            # plain-python mirrors; the numpy arrays above are build-only
            self._uncov_l = self.uncov.tolist()
            self._lam_l = self.edge_lambda.tolist()
            self.uncov = None
            self.edge_lambda = None
            self._contrib_l = self._contrib.tolist()
            self._order_pairs = list(zip(self._order.tolist(),
                                         self._order_pc.tolist()))
            self._inc_l = [self.inc_edges[self.xinc[v]:self.xinc[v + 1]]
                           .tolist() for v in range(hg.n)]
            self._mu_l = self.mu.tolist()
            self._nsub = nsub
            self.loads = self.loads.tolist()
            self._omega_l = self.omega.tolist()

    # ------------------------------------------------------------- adoption
    @classmethod
    def from_arrays(cls, hg: Hypergraph, P: int, masks: np.ndarray,
                    uncov: np.ndarray, edge_lambda: np.ndarray,
                    loads: np.ndarray | None = None) -> "PartitionState":
        """Adopt prebuilt engine arrays without any rebuild (numpy backend).

        The process-parallel layer uses this twice over: workers slice the
        parent state's shared-memory ``uncov``/``edge_lambda`` rows for
        their shard's edges and resume refinement on them directly, and the
        parent re-adopts shared-memory copies of its own arrays so later
        mutations stay zero-copy visible.  The arrays are adopted, NOT
        copied (except ``loads``, which each side mutates privately) --
        callers own the aliasing discipline.  ``uncov``/``edge_lambda``
        must be consistent with ``masks`` over ``hg``'s edges; ``check()``
        verifies exactly that.
        """
        st = cls.__new__(cls)
        st.backend = "numpy"
        st.hg = hg
        st.P = int(P)
        st.popcnt, st._order, st._order_pc, st._contrib = _tables(P)
        st.xpins = hg.xpins
        st.pins = hg.pins
        st.xinc = hg.xinc
        st.inc_edges = hg.inc_edges
        st.mu = np.asarray(hg.mu, dtype=np.float64)
        st.omega = np.asarray(hg.omega, dtype=np.float64)
        st.masks = np.asarray(masks, dtype=np.int64)
        st.uncov = uncov
        st.edge_lambda = edge_lambda
        st.cost = float(
            (st.mu * np.maximum(st.edge_lambda - 1, 0)).sum())
        if loads is None:
            bits = (st.masks[:, None] >> np.arange(st.P)) & 1
            st.loads = (bits * st.omega[:, None]).sum(axis=0)
        else:
            st.loads = np.asarray(loads, dtype=np.float64).copy()
        st._undo = []
        st.device = None
        return st

    # ------------------------------------------------------------- projection
    @classmethod
    def from_projection(cls, hg: Hypergraph, P: int,
                        coarse_state: "PartitionState",
                        cmap: np.ndarray,
                        edge_map: np.ndarray) -> "PartitionState":
        """Fine-level state from a coarse state's masks, projected down.

        ``cmap``/``edge_map`` come from ``Hypergraph.contract`` (``hg`` is
        the *fine* hypergraph the coarse one was contracted from).  Fine
        masks are ``coarse_state.masks[cmap]`` -- replication masks project
        as unions, see ``Hypergraph.contract`` -- and because a fine edge's
        *distinct* pin-mask set equals its coarse image's, per-edge lambdas
        carry over verbatim: surviving edges reuse the coarse lambda, the
        dropped ones (single coarse pin) are 1 (0 if empty).  That skips
        the subset-order scan, the dominant term of a from-masks build; the
        uncov table itself is rebuilt blockwise (memory-bounded).

        The result is *bit-identical* to ``PartitionState(hg, P,
        masks=coarse_state.masks[cmap])`` -- same uncov, lambdas, cost and
        loads (property-tested by ``tests/test_multilevel.py``), which is
        the cost-exactness contract of the multilevel V-cycle: projection
        changes the level, never the cost.
        """
        cmap = np.asarray(cmap, dtype=np.int64)
        edge_map = np.asarray(edge_map, dtype=np.int64)
        masks = coarse_state.masks[cmap]
        m = len(hg.edges)
        lam = np.zeros(m, dtype=np.int16)
        kept = edge_map >= 0
        coarse_lam = (coarse_state.edge_lambda if coarse_state.backend ==
                      "numpy" else np.asarray(coarse_state._lam_l,
                                              dtype=np.int16))
        lam[kept] = coarse_lam[edge_map[kept]]
        # dropped non-empty edges sit inside one coarse node: every pin
        # shares that node's mask, so lambda is 1 (0 when unassigned)
        dropped = np.flatnonzero(~kept & (hg.xpins[1:] > hg.xpins[:-1]))
        if len(dropped):
            lam[dropped] = (masks[hg.pins[hg.xpins[dropped]]] != 0)
        return cls(hg, P, masks=masks, lambda_hint=lam)

    # ---------------------------------------------------------------- lambdas
    def _lambda_rows(self, rows: np.ndarray) -> np.ndarray:
        return _lambda_from_rows(rows, self._order, self._order_pc)

    def _incident(self, v: int) -> np.ndarray:
        return self.inc_edges[self.xinc[v]:self.xinc[v + 1]]

    # ------------------------------------------------- scalar (python) backend
    def _delta_py(self, v: int, new_mask: int) -> float:
        old = int(self.masks[v])
        if new_mask == old:
            return 0.0
        ca, cb = self._contrib_l[old], self._contrib_l[new_mask]
        d = 0.0
        for ei in self._inc_l[v]:
            row = self._uncov_l[ei]
            if row[0] + cb[0] - ca[0] == 0:
                lam_new = 0
            else:
                for s, pc in self._order_pairs:
                    if row[s] + cb[s] - ca[s] == 0:
                        lam_new = pc
                        break
            lam_old = self._lam_l[ei]
            d += self._mu_l[ei] * ((lam_new - 1 if lam_new else 0)
                                   - (lam_old - 1 if lam_old else 0))
        return d

    def _apply_py(self, v: int, new_mask: int) -> float:
        old = int(self.masks[v])
        inc = self._inc_l[v]
        self._undo.append((v, old, [self._lam_l[ei] for ei in inc]))
        if new_mask == old:
            return 0.0
        ca, cb = self._contrib_l[old], self._contrib_l[new_mask]
        delta = 0.0
        for ei in inc:
            row = self._uncov_l[ei]
            for s in range(self._nsub):
                row[s] += cb[s] - ca[s]
            if row[0] == 0:
                lam_new = 0
            else:
                for s, pc in self._order_pairs:
                    if row[s] == 0:
                        lam_new = pc
                        break
            lam_old = self._lam_l[ei]
            delta += self._mu_l[ei] * ((lam_new - 1 if lam_new else 0)
                                       - (lam_old - 1 if lam_old else 0))
            self._lam_l[ei] = lam_new
        self.cost += delta
        self._shift_loads(v, old, new_mask)
        self.masks[v] = new_mask
        return delta

    def _undo_py(self) -> None:
        v, old, old_lams = self._undo.pop()
        cur = int(self.masks[v])
        if cur == old:
            return
        ca, cb = self._contrib_l[cur], self._contrib_l[old]
        delta = 0.0
        for ei, lam_old in zip(self._inc_l[v], old_lams):
            row = self._uncov_l[ei]
            for s in range(self._nsub):
                row[s] += cb[s] - ca[s]
            lam_cur = self._lam_l[ei]
            delta += self._mu_l[ei] * ((lam_old - 1 if lam_old else 0)
                                       - (lam_cur - 1 if lam_cur else 0))
            self._lam_l[ei] = lam_old
        self.cost += delta
        self._shift_loads(v, cur, old)
        self.masks[v] = old

    def _shift_loads(self, v: int, old: int, new: int) -> None:
        w = (self._omega_l[v] if self.backend == "python"
             else self.omega[v])
        diff = new ^ old
        p = 0
        while diff:
            if diff & 1:
                self.loads[p] += w if (new >> p) & 1 else -w
            diff >>= 1
            p += 1

    # ----------------------------------------------------------------- deltas
    def delta_set_mask(self, v: int, new_mask: int) -> float:
        """Cost change of ``masks[v] -> new_mask`` (pure, O(deg * 2^P))."""
        if self.backend == "python":
            return self._delta_py(v, new_mask)
        old = int(self.masks[v])
        if new_mask == old:
            return 0.0
        inc = self._incident(v)
        if inc.size == 0:
            return 0.0
        rows = self.uncov[inc] + (self._contrib[new_mask]
                                  - self._contrib[old])[None, :]
        lam_new = self._lambda_rows(rows).astype(np.float64)
        lam_old = self.edge_lambda[inc].astype(np.float64)
        return float((self.mu[inc] * (np.maximum(lam_new - 1, 0)
                                      - np.maximum(lam_old - 1, 0))).sum())

    def delta_masks(self, v: int, new_masks: np.ndarray) -> np.ndarray:
        """Cost change for each candidate mask in ``new_masks`` at once.

        Single-node front of the frontier layer's batched evaluator
        (``core.frontier.price_mask_front``), which amortizes numpy call
        overhead across all K candidates and -- because the frontier
        reduction is the single shared implementation -- is bit-equal to
        pricing the same candidates as part of any larger node front.
        """
        new_masks = np.asarray(new_masks, dtype=np.int64)
        if self.backend == "python":
            return np.array([self._delta_py(v, int(m)) for m in new_masks])
        from ..frontier.partition_front import price_mask_front
        return price_mask_front(
            self, np.array([v], dtype=np.int64), new_masks,
            np.array([0, len(new_masks)], dtype=np.int64), backend="numpy")

    def delta_move(self, v: int, p_from: int, p_to: int) -> float:
        m = int(self.masks[v])
        return self.delta_set_mask(v, (m & ~(1 << p_from)) | (1 << p_to))

    def delta_add_replica(self, v: int, p: int) -> float:
        return self.delta_set_mask(v, int(self.masks[v]) | (1 << p))

    def delta_drop_replica(self, v: int, p: int) -> float:
        return self.delta_set_mask(v, int(self.masks[v]) & ~(1 << p))

    # ------------------------------------------------------------ application
    def apply(self, v: int, new_mask: int) -> float:
        """Set ``masks[v] = new_mask``; returns the cost delta.

        Records an undo entry (see ``undo``/``commit``).
        """
        if self.backend == "python":
            return self._apply_py(v, new_mask)
        old = int(self.masks[v])
        inc = self._incident(v)
        old_lams = self.edge_lambda[inc].copy()
        self._undo.append((v, old, old_lams))
        if new_mask == old:
            return 0.0
        delta = 0.0
        if inc.size:
            self.uncov[inc] += (self._contrib[new_mask]
                                - self._contrib[old])[None, :]
            lam_new = self._lambda_rows(self.uncov[inc])
            delta = float(
                (self.mu[inc] * (np.maximum(lam_new - 1, 0)
                                 - np.maximum(old_lams - 1, 0))).sum())
            self.edge_lambda[inc] = lam_new
        self.cost += delta
        self._shift_loads(v, old, new_mask)
        self.masks[v] = new_mask
        if self.device is not None:
            self.device.apply(v, old, new_mask)
        return delta

    def undo(self, count: int = 1) -> None:
        """Revert the last ``count`` ``apply`` calls."""
        if count > len(self._undo):
            raise IndexError(
                f"undo({count}): only {len(self._undo)} applied operations "
                "on the undo log")
        if self.backend == "python":
            for _ in range(count):
                self._undo_py()
            return
        for _ in range(count):
            v, old, old_lams = self._undo.pop()
            cur = int(self.masks[v])
            if cur == old:
                continue
            inc = self._incident(v)
            if inc.size:
                self.uncov[inc] += (self._contrib[old]
                                    - self._contrib[cur])[None, :]
                cur_lams = self.edge_lambda[inc].astype(np.float64)
                self.cost += float(
                    (self.mu[inc] * (np.maximum(old_lams - 1, 0)
                                     - np.maximum(cur_lams - 1, 0))).sum())
                self.edge_lambda[inc] = old_lams
            self._shift_loads(v, cur, old)
            self.masks[v] = old
            if self.device is not None:
                self.device.apply(v, cur, old)

    def commit(self) -> None:
        """Drop undo history (accept everything applied so far)."""
        self._undo.clear()

    @property
    def depth(self) -> int:
        """Number of undoable ``apply`` records."""
        return len(self._undo)

    # -------------------------------------------------------------- utilities
    def fits(self, v: int, p: int, cap: float) -> bool:
        return self.loads[p] + self.omega[v] <= cap

    def lambda_of(self, ei: int) -> int:
        if self.backend == "python":
            return self._lam_l[ei]
        return int(self.edge_lambda[ei])

    def check(self) -> None:
        """Assert all invariants against a from-scratch rebuild (tests)."""
        fresh = PartitionState(self.hg, self.P, masks=self.masks)
        if self.backend == "python":
            uncov = np.asarray(self._uncov_l, dtype=np.int32).reshape(
                fresh.uncov.shape)
            lam = np.asarray(self._lam_l, dtype=np.int16)
        else:
            uncov, lam = self.uncov, self.edge_lambda
        assert np.array_equal(fresh.uncov, uncov), "uncov drifted"
        assert np.array_equal(fresh.edge_lambda, lam), "edge_lambda drifted"
        assert abs(fresh.cost - self.cost) < 1e-6, \
            f"cost drifted: {self.cost} vs {fresh.cost}"
        assert np.allclose(fresh.loads, self.loads), "loads drifted"
