"""Process-parallel shared-memory execution layer (PR 7 tentpole).

One box, many cores: the multilevel V-cycle's three heavy phases --
matching, contraction bookkeeping, refinement -- are data-parallel over
node ranges, but Python processes cannot share a `Hypergraph` without
either pickling the pin arrays into every worker (copies the instance W
times) or going through a file.  This module provides the third option:

* ``ShmRegistry`` -- owns ``multiprocessing.shared_memory`` segments.
  ``share(a)`` copies an array into a fresh segment once and returns the
  segment-backed view plus a picklable ``ArrayRef``; ``alloc`` creates
  zeroed segment-backed arrays for code that wants to *stream* data
  straight into shared memory (``datagen.spmv.large_row_net``).  All
  segments are unlinked on ``close()`` -- also after worker crashes, the
  registry never relies on worker-side cleanup.

* ``ParallelContext`` -- worker-pool lifecycle (``fork`` preferred,
  ``spawn`` fallback -- both tested), per-``Hypergraph`` export cache (the
  six CSR arrays + omega + mu are shared once per level), and
  ``adopt_state``: re-back a live ``PartitionState``'s ``uncov`` /
  ``edge_lambda`` / ``masks`` with shared segments so the engine's
  in-place updates are immediately visible to the next worker dispatch
  with zero copies.

* ``parallel_match_pref`` -- shards the heavy-pin scoring pass over node
  ranges.  Per-(v, u) score sums accumulate in the same ascending-edge
  order inside a shard as in the full pass, so the concatenated ``pref``
  -- and therefore the matching ``cmap`` -- is *bit-identical* to serial
  for every worker count (pinned by ``tests/test_parallel.py``).

* ``parallel_refine`` -- splits an FM / replication pass into contiguous
  node shards (degree-balanced, ``plan_shards``).  Each worker extracts
  its shard's incident-edge sub-hypergraph (every edge touching the
  shard, with full pin sets, so move deltas are globally exact against
  the snapshot), runs the ordinary frontier-priced pass restricted to its
  nodes, and sends back only the changed masks.  The parent then replays
  proposals through ``PartitionState.apply`` and keeps a move only if it
  still improves (or is cost-neutral and drops a replica) and respects
  capacity -- stale proposals are undone.  A serial boundary pass over
  nodes of cross-shard edges mops up what sharding hid.  Final cost is
  therefore never worse than the projected cost; divergence from the
  serial trajectory is disclosed in the ``parallel_scale`` bench rows.

Workers never touch the JAX backend (``frontier="numpy"`` end to end), so
the pool is safe under ``fork`` even when the parent has device state.
Worker-side attaches suppress resource-tracker registration (bpo-38119:
Python <= 3.12 registers attach-only segments too, and the process tree
shares one tracker, so a worker's registration would let the tracker
unlink the creator's segment when the pool retires).
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import secrets
from concurrent.futures import ProcessPoolExecutor

import numpy as np

from ..hypergraph import Hypergraph
from .engine import PartitionState

PARALLEL_MIN_NODES = 4096   # below this, sharding overhead beats the work
_SEG_PREFIX = "repro"

_CSR_KEYS = ("xpins", "pins", "xinc", "inc_edges", "xadj", "adj_nodes")


def shm_available() -> bool:
    """True when POSIX shared memory actually works here (CI guard)."""
    try:
        from multiprocessing import shared_memory
        seg = shared_memory.SharedMemory(create=True, size=8)
        seg.close()
        seg.unlink()
        return True
    except Exception:
        return False


@dataclasses.dataclass(frozen=True)
class ArrayRef:
    """Picklable handle to a shared-memory array (``name is None`` encodes
    a zero-byte array, which POSIX shm cannot represent)."""

    name: str | None
    shape: tuple
    dtype: str


class ShmRegistry:
    """Owner of shared-memory segments; unlinks everything on ``close``."""

    def __init__(self):
        self._segs = {}          # name -> SharedMemory (created here)
        self._by_id = {}         # id(array) -> (array, ArrayRef)
        self.created = []        # every name ever created (tests/cleanup)

    def _new_segment(self, nbytes: int):
        from multiprocessing import shared_memory
        name = f"{_SEG_PREFIX}_{secrets.token_hex(6)}"
        seg = shared_memory.SharedMemory(create=True, size=nbytes, name=name)
        self._segs[seg.name] = seg
        self.created.append(seg.name)
        return seg

    def alloc(self, shape, dtype) -> np.ndarray:
        """Zeroed segment-backed array (for streaming writers)."""
        shape = tuple(int(s) for s in np.atleast_1d(shape))
        dtype = np.dtype(dtype)
        nbytes = int(np.prod(shape)) * dtype.itemsize
        if nbytes == 0:
            a = np.zeros(shape, dtype=dtype)
            self._by_id[id(a)] = (a, ArrayRef(None, shape, dtype.str))
            return a
        seg = self._new_segment(nbytes)
        a = np.ndarray(shape, dtype=dtype, buffer=seg.buf)
        a[:] = 0
        self._by_id[id(a)] = (a, ArrayRef(seg.name, shape, dtype.str))
        return a

    def share(self, a: np.ndarray) -> tuple[np.ndarray, ArrayRef]:
        """Copy ``a`` into a fresh segment; returns ``(view, ref)``.

        If ``a`` already came out of this registry (``alloc``/``share``),
        it is returned as-is -- zero-copy round trips for arrays that were
        streamed into shared memory at build time.
        """
        got = self._by_id.get(id(a))
        if got is not None and got[0] is a:
            return got
        a = np.ascontiguousarray(a)
        if a.nbytes == 0:
            ref = ArrayRef(None, a.shape, a.dtype.str)
            self._by_id[id(a)] = (a, ref)
            return a, ref
        seg = self._new_segment(a.nbytes)
        out = np.ndarray(a.shape, dtype=a.dtype, buffer=seg.buf)
        out[:] = a
        ref = ArrayRef(seg.name, a.shape, a.dtype.str)
        self._by_id[id(out)] = (out, ref)
        return out, ref

    def close(self) -> None:
        """Unlink every segment created here (idempotent, crash-safe)."""
        self._by_id.clear()
        segs, self._segs = self._segs, {}
        for seg in segs.values():
            try:
                seg.close()
            except Exception:
                pass
            try:
                seg.unlink()
            except Exception:
                pass  # already gone (e.g. unlinked by a dying tracker)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ------------------------------------------------------------- worker side

_ATTACHED: dict[str, tuple] = {}     # per-process: name -> (seg, array)
_HG_CACHE: dict[str, Hypergraph] = {}  # per-process: xpins name -> hg


def attach_array(ref: ArrayRef) -> np.ndarray:
    """Map a shared segment read-write; cached per process.

    Attach-only ``SharedMemory`` registers itself with the resource
    tracker (bpo-38119); the process tree shares one tracker, so that
    re-registration is a no-op -- but an *unregister* here would erase the
    creator's entry.  Registration is therefore suppressed for the attach
    call instead, leaving the parent's bookkeeping untouched.
    """
    if ref.name is None:
        return np.zeros(ref.shape, dtype=np.dtype(ref.dtype))
    got = _ATTACHED.get(ref.name)
    if got is None:
        from multiprocessing import resource_tracker, shared_memory
        orig_register = resource_tracker.register
        resource_tracker.register = lambda *a, **k: None
        try:
            seg = shared_memory.SharedMemory(name=ref.name)
        finally:
            resource_tracker.register = orig_register
        a = np.ndarray(ref.shape, dtype=np.dtype(ref.dtype), buffer=seg.buf)
        got = (seg, a)
        _ATTACHED[ref.name] = got
    return got[1]


def _attach_hg(hgd: dict) -> Hypergraph:
    """Rebuild a ``Hypergraph`` from shared CSR refs; cached per process
    (keyed by the xpins segment, one entry per level)."""
    key = hgd["xpins"].name or f"empty-{hgd['n']}"
    hg = _HG_CACHE.get(key)
    if hg is not None:
        return hg
    arrs = {k: attach_array(hgd[k]) for k in _CSR_KEYS}
    hg = Hypergraph.from_csr(hgd["n"], arrs["xpins"], arrs["pins"],
                             omega=attach_array(hgd["omega"]),
                             mu=attach_array(hgd["mu"]), name=hgd["name"])
    # seed the full lazy-CSR cache: the incidence/adjacency halves were
    # built once in the parent, workers must never rebuild them
    hg._csr = tuple(arrs[k] for k in _CSR_KEYS)
    _HG_CACHE[key] = hg
    return hg


def _pref_task(arg):
    """Worker: heavy-pin scoring for one node range (bit-identity contract
    documented on ``multilevel._match_pref``)."""
    hgd, max_edge_size, lo, hi = arg
    from .multilevel import _match_pref
    hg = _attach_hg(hgd)
    return _match_pref(hg, max_edge_size, lo, hi)


def _sched_pair_task(arg):
    """Worker: same-level pair generation for one owner-node range
    (bit-identity contract documented on
    ``schedule.multilevel._pair_parts``)."""
    refs, max_fanout, lo, hi = arg
    from ..schedule.multilevel import _pair_parts
    xch, ch_arr, xpar, par_arr, mu, level = (attach_array(r) for r in refs)
    return _pair_parts(xch, ch_arr, xpar, par_arr, mu, level,
                       max_fanout, lo, hi)


def _refine_task(arg):
    """Worker: refine one node shard against a state snapshot.

    Extracts the shard's incident-edge sub-hypergraph (full pin sets, so
    every delta a worker prices is globally exact w.r.t. the snapshot),
    runs the ordinary pass restricted to ``nodes`` in ``[lo, hi)``, and
    returns ``(changed_nodes, new_masks)`` proposals.
    """
    (hgd, mref, uref, lref, loads, P, eps, kind, passes, seed,
     max_replicas, lo, hi) = arg
    from .heuristic import fm_refine, replicate_local_search
    hg = _attach_hg(hgd)
    masks_live = attach_array(mref)
    uncov_live = attach_array(uref)
    lam_live = attach_array(lref)
    empty = (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
    xinc, inc = hg.xinc, hg.inc_edges
    E_s = np.unique(inc[xinc[lo]:xinc[hi]])
    if len(E_s) == 0:
        return empty
    # shard sub-hypergraph: only E_s rows, full node space / pin sets
    lens = np.diff(hg.xpins)[E_s]
    xp_s = np.zeros(len(E_s) + 1, dtype=np.int64)
    np.cumsum(lens, out=xp_s[1:])
    offs = np.arange(int(xp_s[-1]), dtype=np.int64) - np.repeat(xp_s[:-1],
                                                                lens)
    pins_s = hg.pins[np.repeat(hg.xpins[E_s], lens) + offs]
    shard = Hypergraph.from_csr(hg.n, xp_s, pins_s, omega=hg.omega,
                                mu=np.asarray(hg.mu)[E_s],
                                name=f"{hg.name}[{lo}:{hi}]")
    masks = masks_live.copy()          # private snapshot; parent is blocked
    st = PartitionState.from_arrays(shard, P, masks, uncov_live[E_s],
                                    lam_live[E_s], loads=np.asarray(loads))
    nodes = np.arange(lo, hi, dtype=np.int64)
    if kind == "fm":
        fm_refine(shard, masks, P, eps, np.random.default_rng(seed),
                  passes=passes, state=st, frontier="numpy", nodes=nodes)
    else:
        replicate_local_search(shard, masks, P, eps,
                               max_replicas=max_replicas, max_passes=passes,
                               seed=seed, frontier="numpy", state=st,
                               nodes=nodes)
    changed = np.flatnonzero(st.masks != masks_live)
    return changed, st.masks[changed].copy()


def _crash_task(arg):
    """Worker that dies mid-task (shm-cleanup regression tests only)."""
    import os
    os._exit(17)


# ------------------------------------------------------------- parent side

class ParallelContext:
    """Pool + registry lifecycle for one partitioning run.

    The pool starts lazily on first use; ``failed`` flips sticky-true on
    the first worker-layer error, after which every call site falls back
    to its serial path (never abort the partition over a pool problem).
    """

    def __init__(self, workers: int, start_method: str | None = None,
                 min_nodes: int | None = None):
        self.workers = max(int(workers), 1)
        self.min_nodes = (PARALLEL_MIN_NODES if min_nodes is None
                          else int(min_nodes))
        if start_method is None:
            start_method = ("fork" if "fork" in mp.get_all_start_methods()
                            else "spawn")
        self.start_method = start_method
        self.reg = ShmRegistry()
        self.failed = False
        self._pool = None
        # per-context caches (strong refs pin object ids): segments die
        # with this context, so the cache must never outlive it either --
        # an attribute on the hg/state would go stale across contexts
        self._hg_exports: dict[int, tuple] = {}
        self._state_refs: dict[int, tuple] = {}

    # -- pool ------------------------------------------------------------
    def _get_pool(self) -> ProcessPoolExecutor:
        if self._pool is None:
            self._pool = ProcessPoolExecutor(
                max_workers=self.workers,
                mp_context=mp.get_context(self.start_method))
        return self._pool

    def run(self, fn, tasks: list) -> list:
        """Map ``fn`` over ``tasks`` on the pool (raises on worker death;
        callers catch, set ``failed`` and go serial)."""
        return list(self._get_pool().map(fn, tasks))

    # -- shared exports --------------------------------------------------
    def export_hg(self, hg: Hypergraph) -> dict:
        """Share a hypergraph's six CSR arrays + omega + mu (once per
        context)."""
        got = self._hg_exports.get(id(hg))
        if got is not None:
            return got[1]
        csr = hg._build_csr()
        d = {"n": hg.n, "name": hg.name}
        for key, a in zip(_CSR_KEYS, csr):
            _, d[key] = self.reg.share(a)
        _, d["omega"] = self.reg.share(
            np.asarray(hg.omega, dtype=np.float64))
        _, d["mu"] = self.reg.share(np.asarray(hg.mu, dtype=np.float64))
        self._hg_exports[id(hg)] = (hg, d)
        return d

    def adopt_state(self, st: PartitionState) -> tuple:
        """Re-back ``st.masks`` / ``st.uncov`` / ``st.edge_lambda`` with
        shared segments (once per state).  The engine mutates these arrays
        in place, so after adoption every committed move is visible to
        workers with no further copies."""
        got = self._state_refs.get(id(st))
        if got is not None:
            return got[1]
        st.masks, mref = self.reg.share(st.masks)
        st.uncov, uref = self.reg.share(st.uncov)
        st.edge_lambda, lref = self.reg.share(st.edge_lambda)
        refs = (mref, uref, lref)
        self._state_refs[id(st)] = (st, refs)
        return refs

    def close(self) -> None:
        if self._pool is not None:
            pool, self._pool = self._pool, None
            try:
                pool.shutdown(wait=True, cancel_futures=True)
            except Exception:
                pass
        # detach adopted states: their arrays live inside segments about
        # to be unmapped -- hand each state private copies so it stays
        # usable after the context is gone
        for st, _ in self._state_refs.values():
            st.masks = st.masks.copy()
            st.uncov = st.uncov.copy()
            st.edge_lambda = st.edge_lambda.copy()
        self._hg_exports.clear()
        self._state_refs.clear()
        self.reg.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def plan_shards(hg: Hypergraph, W: int) -> np.ndarray:
    """Contiguous node-range bounds (len W+1), balanced by incidence
    degree (+1 per node so isolated nodes still spread)."""
    n = hg.n
    W = max(1, min(int(W), n))
    work = np.diff(hg.xinc).astype(np.int64) + 1
    cum = np.cumsum(work)
    targets = cum[-1] / W * np.arange(1, W)
    cuts = np.searchsorted(cum, targets, side="left") + 1
    bounds = np.concatenate(([0], np.minimum(cuts, n), [n]))
    return np.maximum.accumulate(bounds)


def boundary_nodes(hg: Hypergraph, bounds: np.ndarray) -> np.ndarray:
    """Nodes incident to an edge whose pins span more than one shard --
    the set the serial reconciliation pass re-sweeps."""
    xpins, pins = hg.xpins, hg.pins
    m = len(xpins) - 1
    if m == 0 or len(pins) == 0:
        return np.zeros(0, dtype=np.int64)
    shard = np.searchsorted(bounds[1:-1], pins, side="right")
    lens = np.diff(xpins)
    ne = lens > 0
    starts = xpins[:-1][ne]
    mn = np.minimum.reduceat(shard, starts)
    mx = np.maximum.reduceat(shard, starts)
    cross = np.zeros(m, dtype=bool)
    cross[ne] = mn != mx
    return np.unique(pins[np.repeat(cross, lens)])


def parallel_match_pref(hg: Hypergraph, ctx: ParallelContext,
                        max_edge_size: int) -> np.ndarray:
    """Sharded heavy-pin scoring; concatenation is bit-identical to the
    serial ``_match_pref`` (see its docstring for the why)."""
    from .multilevel import _match_pref
    try:
        bounds = plan_shards(hg, ctx.workers)
        hgd = ctx.export_hg(hg)
        tasks = [(hgd, int(max_edge_size), int(bounds[w]),
                  int(bounds[w + 1]))
                 for w in range(len(bounds) - 1)
                 if bounds[w + 1] > bounds[w]]
        parts = ctx.run(_pref_task, tasks)
        return np.concatenate(parts)
    except Exception:
        ctx.failed = True
        return _match_pref(hg, max_edge_size)


def parallel_pair_parts(dag, xch: np.ndarray, level: np.ndarray,
                        ctx: ParallelContext, max_fanout: int) -> list:
    """Sharded same-level pair generation for the scheduling V-cycle's
    coarsening (``schedule.multilevel.same_level_matching``).

    Shares the DAG's flat group arrays once per call (coarsening builds a
    fresh ``Dag`` and level array every round, so there is nothing to
    cache across calls) and maps ``_pair_parts`` over contiguous
    owner-node ranges.  Returns the per-shard 6-tuples in shard order;
    the caller concatenates child blocks then parent blocks, which equals
    the serial arrays byte-for-byte (see ``_pair_parts``).  Raises on
    pool trouble -- the call site flips ``ctx.failed`` and goes serial.
    """
    n = int(dag.n)
    refs = []
    for a in (xch, dag.edge_dst, dag.xpar, dag.par_arr,
              np.asarray(dag.mu, dtype=np.float64),
              np.asarray(level, dtype=np.int64)):
        _, ref = ctx.reg.share(a)
        refs.append(ref)
    refs = tuple(refs)
    # balance shards by quadratic group work (pairs scale with len^2)
    lens_ch = np.diff(xch)
    lens_pa = np.diff(dag.xpar)
    work = np.ones(n, dtype=np.int64)
    for lens in (lens_ch, lens_pa):
        ok = (lens >= 2) & (lens <= max_fanout)
        work[ok] += (lens[ok] * lens[ok]).astype(np.int64)
    cum = np.cumsum(work)
    W = max(1, min(ctx.workers, n))
    targets = cum[-1] / W * np.arange(1, W)
    cuts = np.searchsorted(cum, targets, side="left") + 1
    bounds = np.maximum.accumulate(
        np.concatenate(([0], np.minimum(cuts, n), [n])))
    tasks = [(refs, int(max_fanout), int(bounds[w]), int(bounds[w + 1]))
             for w in range(len(bounds) - 1) if bounds[w + 1] > bounds[w]]
    return ctx.run(_sched_pair_task, tasks)


def parallel_refine(hg: Hypergraph, st: PartitionState, P: int, eps: float,
                    ctx: ParallelContext, kind: str, passes: int,
                    seed: int, max_replicas: int | None = None) -> dict:
    """One sharded refinement stop; mutates ``st`` in place.

    Shard -> propose -> reconcile -> boundary pass (module docstring has
    the full story).  Cost-not-worse by construction: reconciliation
    replays every proposal through ``st.apply`` and keeps it only when it
    still improves (or is cost-neutral and strictly drops replicas) under
    capacity; the boundary pass applies only improving moves too.
    Returns a stats dict (workers / proposed / accepted / boundary).
    """
    from .cost import capacity
    from .heuristic import fm_refine, replicate_local_search
    stats = {"n": hg.n, "kind": kind, "workers": 0, "proposed": 0,
             "accepted": 0, "boundary": 0, "serial_fallback": False}
    cost0 = float(st.cost)
    cap = capacity(hg, P, eps) + 1e-9
    results = None
    bounds = None
    if not ctx.failed and ctx.workers > 1:
        try:
            bounds = plan_shards(hg, ctx.workers)
            hgd = ctx.export_hg(hg)
            mref, uref, lref = ctx.adopt_state(st)
            loads = np.asarray(st.loads, dtype=np.float64).copy()
            tasks = []
            for w in range(len(bounds) - 1):
                lo, hi = int(bounds[w]), int(bounds[w + 1])
                if hi > lo:
                    tasks.append((hgd, mref, uref, lref, loads, P, eps,
                                  kind, passes, seed + 7919 * w,
                                  max_replicas, lo, hi))
            results = ctx.run(_refine_task, tasks)
            stats["workers"] = len(tasks)
        except Exception:
            ctx.failed = True
            results = None
    if results is None:
        # pool unavailable/broken: the ordinary serial pass on ``st``
        stats["serial_fallback"] = True
        if kind == "fm":
            fm_refine(hg, st.masks, P, eps, np.random.default_rng(seed),
                      passes=passes, state=st, frontier="numpy")
        else:
            replicate_local_search(hg, st.masks, P, eps,
                                   max_replicas=max_replicas,
                                   max_passes=passes, seed=seed,
                                   frontier="numpy", state=st)
        return stats
    # reconcile: replay proposals on the live state, keep only what still
    # helps (workers priced against a snapshot; earlier acceptances may
    # have gone stale) -- deterministic order: shard-major, node-ascending
    proposed = accepted = 0
    for changed, new_masks in results:
        for v, m_new in zip(changed.tolist(), new_masks.tolist()):
            proposed += 1
            m_old = int(st.masks[v])
            if m_new == m_old:
                continue
            delta = st.apply(v, int(m_new))
            better = delta < -1e-12 or (
                delta <= 1e-12
                and int(st.popcnt[m_new]) < int(st.popcnt[m_old]))
            if better and bool(np.all(st.loads <= cap)):
                st.commit()
                accepted += 1
            else:
                st.undo()
    # serial boundary pass: nodes whose edges cross shards are the only
    # places the sharded passes could not price full moves
    bnodes = boundary_nodes(hg, bounds)
    if len(bnodes):
        if kind == "fm":
            fm_refine(hg, st.masks, P, eps, np.random.default_rng(seed),
                      passes=passes, state=st, frontier="numpy",
                      nodes=bnodes)
        else:
            replicate_local_search(hg, st.masks, P, eps,
                                   max_replicas=max_replicas,
                                   max_passes=passes, seed=seed,
                                   frontier="numpy", state=st, nodes=bnodes)
    stats.update(proposed=proposed, accepted=accepted,
                 boundary=int(len(bnodes)))
    assert st.cost <= cost0 + 1e-6, \
        f"parallel refine worsened cost: {cost0} -> {st.cost}"
    return stats
