"""Cost model for balanced hypergraph partitioning with replication.

An assignment is an array ``masks`` of length n; ``masks[v]`` is a bitmask of
the processors node v is assigned to (possibly several -> replication).

Paper §3.2: with replication, lambda_e is the minimal number of processors
that *cover* hyperedge e (a set-cover instance, tractable because P is a
small constant); the cost of a partitioning is  sum_e mu(e) * (lambda_e - 1).
The balance constraint is  omega(V_p) <= (1+eps)/P * omega(V)  for every p.
"""
from __future__ import annotations

from functools import reduce
from itertools import combinations

import numpy as np

from ..hypergraph import Hypergraph


def capacity(hg: Hypergraph, P: int, eps: float) -> float:
    return (1.0 + eps) / P * float(hg.omega.sum())


def min_cover(pin_masks, P: int) -> int:
    """Minimum number of processors covering every pin mask (lambda_e).

    ``pin_masks`` are the processor bitmasks of the nodes of one hyperedge.
    Exact set cover by enumeration in popcount order -- fine for P <= ~10.
    """
    distinct = set(pin_masks)
    distinct.discard(0)
    if not distinct:
        return 0
    inter = reduce(lambda a, b: a & b, distinct)
    if inter:
        return 1
    union = reduce(lambda a, b: a | b, distinct)
    procs = [p for p in range(P) if (union >> p) & 1]
    masks = sorted(distinct)
    for k in range(2, len(procs)):
        for combo in combinations(procs, k):
            s = 0
            for p in combo:
                s |= 1 << p
            if all(m & s for m in masks):
                return k
    return len(procs)


def edge_cost(hg: Hypergraph, masks: np.ndarray, ei: int, P: int) -> float:
    e = hg.edges[ei]
    lam = min_cover([int(masks[v]) for v in e], P)
    return float(hg.mu[ei]) * max(0, lam - 1)


def edge_lambdas(hg: Hypergraph, masks: np.ndarray, P: int) -> np.ndarray:
    """Vectorized lambda_e for every hyperedge at once.

    Batch analogue of the engine's uncovered-subset table: one reduceat
    over the CSR pin array replaces a python set-cover per edge.  Falls
    back to the scalar path for P beyond the table limit.
    """
    from .engine import _MAX_P, _lambda_from_rows, _tables, _uncov_rows

    m = len(hg.edges)
    if m == 0:
        return np.zeros(0, dtype=np.int16)
    if P > _MAX_P:
        return np.array([min_cover([int(masks[v]) for v in e], P)
                         for e in hg.edges], dtype=np.int16)
    _, order, order_pc, contrib = _tables(P)
    masks = np.asarray(masks, dtype=np.int64)
    uncov = _uncov_rows(masks, hg.pins, hg.xpins, contrib)
    return _lambda_from_rows(uncov, order, order_pc)


def partition_cost(hg: Hypergraph, masks: np.ndarray, P: int) -> float:
    """Total (lambda_e - 1) connectivity cost under replication semantics."""
    lam = edge_lambdas(hg, masks, P).astype(np.float64)
    return float((hg.mu * np.maximum(lam - 1, 0)).sum())


def loads(hg: Hypergraph, masks: np.ndarray, P: int) -> np.ndarray:
    masks = np.asarray(masks, dtype=np.int64)
    bits = (masks[:, None] >> np.arange(P)) & 1
    return (bits * hg.omega[:, None]).sum(axis=0).astype(np.float64)


def is_balanced(hg: Hypergraph, masks: np.ndarray, P: int, eps: float) -> bool:
    cap = capacity(hg, P, eps)
    # tolerance for float weight sums
    return bool(np.all(loads(hg, masks, P) <= cap + 1e-9))


def is_valid(hg: Hypergraph, masks: np.ndarray, P: int, eps: float,
             max_replicas: int | None = None) -> bool:
    if len(masks) != hg.n:
        return False
    for v in range(hg.n):
        m = int(masks[v])
        if m <= 0 or m >= (1 << P):
            return False
        if max_replicas is not None and bin(m).count("1") > max_replicas:
            return False
    return is_balanced(hg, masks, P, eps)
