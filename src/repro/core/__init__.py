from .hypergraph import Dag, Hypergraph, connected_components

__all__ = ["Dag", "Hypergraph", "connected_components"]
