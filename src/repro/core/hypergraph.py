"""Hypergraph and DAG data structures for partitioning / scheduling.

These mirror the paper's Section 3 definitions:
  * a hypergraph is (V, E) with each e in E a subset of V; a (v, e) pair with
    v in e is a *pin*;
  * node weights ``omega`` express compute cost, hyperedge weights ``mu``
    express communicated data size (both default to 1);
  * a DAG is a directed acyclic graph with node compute weights ``omega``
    and node communication weights ``mu`` (size of a node's output value).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass
class Hypergraph:
    n: int
    edges: list[tuple[int, ...]]
    omega: np.ndarray | None = None  # node weights, shape (n,)
    mu: np.ndarray | None = None     # hyperedge weights, shape (len(edges),)
    name: str = "hypergraph"
    # edges already sorted, deduplicated tuples of in-range ints: skip the
    # per-edge python normalization pass (used by vectorized constructors --
    # ``contract`` and the streaming datagen -- where it would dominate)
    presorted: bool = False

    def __post_init__(self) -> None:
        if self.omega is None:
            self.omega = np.ones(self.n, dtype=np.float64)
        else:
            self.omega = np.asarray(self.omega, dtype=np.float64)
        if self.mu is None:
            self.mu = np.ones(len(self.edges), dtype=np.float64)
        else:
            self.mu = np.asarray(self.mu, dtype=np.float64)
        if not self.presorted:
            self.edges = [tuple(sorted(set(e))) for e in self.edges]
            for e in self.edges:
                if any(v < 0 or v >= self.n for v in e):
                    raise ValueError(f"edge {e} out of range for n={self.n}")
        self._csr: tuple[np.ndarray, ...] | None = None

    @property
    def num_pins(self) -> int:
        return sum(len(e) for e in self.edges)

    # ------------------------------------------------------------- CSR layout
    # Two cached compressed-sparse-row views of the pin relation; everything
    # in core/partition iterates these flat arrays instead of python lists.
    #   * edge -> pins:  pins[xpins[e] : xpins[e+1]]      (node ids)
    #   * node -> edges: inc_edges[xinc[v] : xinc[v+1]]   (edge ids)
    # ``edges`` must not be mutated after construction (the cache would go
    # stale); build a new Hypergraph instead.
    def _build_csr(self) -> tuple[np.ndarray, ...]:
        if self._csr is not None:
            return self._csr
        m = len(self.edges)
        lens = np.fromiter((len(e) for e in self.edges), dtype=np.int64,
                           count=m)
        xpins = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(lens, out=xpins[1:])
        total = int(xpins[-1])
        pins = np.fromiter((v for e in self.edges for v in e),
                           dtype=np.int64, count=total)
        edge_of_pin = np.repeat(np.arange(m, dtype=np.int64), lens)
        order = np.argsort(pins, kind="stable")
        inc_edges = edge_of_pin[order]
        xinc = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(pins, minlength=self.n), out=xinc[1:])
        # pin-adjacency: for node v, the concatenated pins of its incident
        # edges (multiset, edge order) -- the BFS frontier of greedy growth.
        e_lens = lens[inc_edges]
        node_tot = np.zeros(self.n, dtype=np.int64)
        np.add.at(node_tot, pins, lens[edge_of_pin])
        xadj = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(node_tot, out=xadj[1:])
        if e_lens.sum():
            starts = xpins[inc_edges]
            offs = np.arange(int(e_lens.sum()), dtype=np.int64)
            offs -= np.repeat(np.cumsum(e_lens) - e_lens, e_lens)
            adj = pins[np.repeat(starts, e_lens) + offs]
        else:
            adj = np.zeros(0, dtype=np.int64)
        self._csr = (xpins, pins, xinc, inc_edges, xadj, adj)
        return self._csr

    @property
    def xpins(self) -> np.ndarray:
        return self._build_csr()[0]

    @property
    def pins(self) -> np.ndarray:
        return self._build_csr()[1]

    @property
    def xinc(self) -> np.ndarray:
        return self._build_csr()[2]

    @property
    def inc_edges(self) -> np.ndarray:
        return self._build_csr()[3]

    @property
    def xadj(self) -> np.ndarray:
        return self._build_csr()[4]

    @property
    def adj_nodes(self) -> np.ndarray:
        return self._build_csr()[5]

    def incident_edges(self) -> list[list[int]]:
        """For each node, the list of edge indices containing it.

        .. deprecated:: PR 4
            List-of-lists compatibility view over the incident CSR, kept
            only so external callers keep working.  It materializes O(pins)
            python lists on every call; everything in-repo now reads
            ``xinc``/``inc_edges`` directly and new code should too.
        """
        xinc, inc_edges = self.xinc, self.inc_edges
        return [inc_edges[xinc[v]:xinc[v + 1]].tolist()
                for v in range(self.n)]

    # --------------------------------------------------- contraction layer
    # Multilevel coarsening support (multilevel V-cycle, PR 4): given a
    # cluster map ``cmap`` (fine node -> coarse node id), ``contract``
    # builds the contracted hypergraph fully vectorized over the CSR pin
    # arrays and returns the edge prolongation map alongside it.  The node
    # prolongation map is ``cmap`` itself: coarse masks project to fine
    # masks as ``coarse_masks[cmap]`` (replication masks project as unions
    # -- every member of a cluster inherits the cluster's full mask, which
    # *is* the union since the cluster is one coarse node).
    def contract(self, cmap: np.ndarray,
                 nc: int | None = None) -> tuple["Hypergraph", np.ndarray]:
        """Contract clusters of nodes into single coarse nodes.

        ``cmap[v]`` is the coarse id of fine node v (0 <= cmap[v] < nc).
        Coarse node weights are the cluster sums of ``omega``.  Each fine
        edge maps its pins through ``cmap`` and deduplicates; edges left
        with fewer than two distinct coarse pins are dropped (their
        ``lambda`` is at most 1 under any assignment, so they can never
        cost anything), and edges with *identical* coarse pin sets collapse
        into one coarse edge whose ``mu`` is their sum (identical-net
        collapsing).  Returns ``(coarse, edge_map)`` with ``edge_map[e]``
        the coarse edge id of fine edge e, or -1 if it was dropped.

        Cost identity (the multilevel contract): for any coarse masks ``M``
        the fine cost of the projected masks ``M[cmap]`` equals the coarse
        cost of ``M``, and the per-processor loads agree exactly -- see
        ``PartitionState.from_projection`` and ``tests/test_multilevel.py``.
        """
        cmap = np.asarray(cmap, dtype=np.int64)
        if cmap.shape != (self.n,):
            raise ValueError("cmap must have shape (n,)")
        if nc is None:
            nc = int(cmap.max()) + 1 if self.n else 0
        if self.n and (cmap.min() < 0 or cmap.max() >= nc):
            raise ValueError("cmap out of range")
        omega_c = np.bincount(cmap, weights=self.omega, minlength=nc)
        m = len(self.edges)
        edge_map = np.full(m, -1, dtype=np.int64)
        if m == 0:
            coarse = Hypergraph(n=nc, edges=[], omega=omega_c,
                                mu=np.zeros(0), name=f"{self.name}_c",
                                presorted=True)
            return coarse, edge_map
        xpins, pins = self.xpins, self.pins
        lens = np.diff(xpins)
        cpins = cmap[pins]
        edge_of_pin = np.repeat(np.arange(m, dtype=np.int64), lens)
        # sort pins within each edge by coarse id, keep first of each run
        order = np.lexsort((cpins, edge_of_pin))
        ep, cp = edge_of_pin[order], cpins[order]
        first = np.ones(len(cp), dtype=bool)
        first[1:] = (ep[1:] != ep[:-1]) | (cp[1:] != cp[:-1])
        ep, cp = ep[first], cp[first]
        lens_c = np.bincount(ep, minlength=m)
        xk = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(lens_c, out=xk[1:])
        keep = lens_c >= 2
        # identical-net collapsing: canonical key = the sorted coarse pin
        # run; fine-edge order decides coarse edge ids (deterministic)
        groups: dict[bytes, int] = {}
        coarse_edges: list[tuple[int, ...]] = []
        mu_list: list[float] = []
        for e in np.flatnonzero(keep):
            seg = cp[xk[e]:xk[e + 1]]
            key = seg.tobytes()
            idx = groups.get(key)
            if idx is None:
                idx = len(coarse_edges)
                groups[key] = idx
                coarse_edges.append(tuple(seg.tolist()))
                mu_list.append(float(self.mu[e]))
            else:
                mu_list[idx] += float(self.mu[e])
            edge_map[e] = idx
        coarse = Hypergraph(n=nc, edges=coarse_edges, omega=omega_c,
                            mu=np.asarray(mu_list, dtype=np.float64),
                            name=f"{self.name}_c", presorted=True)
        return coarse, edge_map

    def remove_isolated(self) -> "Hypergraph":
        """Drop nodes appearing in no hyperedge (paper §B.1 does the same)."""
        used = sorted({v for e in self.edges for v in e})
        remap = {v: i for i, v in enumerate(used)}
        edges = [tuple(remap[v] for v in e) for e in self.edges]
        return Hypergraph(
            n=len(used),
            edges=edges,
            omega=self.omega[used],
            mu=self.mu.copy(),
            name=self.name,
        )

    @staticmethod
    def from_graph(n: int, pairs: Iterable[tuple[int, int]], **kw) -> "Hypergraph":
        return Hypergraph(n=n, edges=[tuple(p) for p in pairs], **kw)


@dataclasses.dataclass
class Dag:
    """Computational DAG.  ``parents[v]`` / ``children[v]`` are index lists."""

    n: int
    edge_list: list[tuple[int, int]]
    omega: np.ndarray | None = None  # compute weight per node
    mu: np.ndarray | None = None     # communication weight (output size) per node
    name: str = "dag"

    def __post_init__(self) -> None:
        if self.omega is None:
            self.omega = np.ones(self.n, dtype=np.float64)
        else:
            self.omega = np.asarray(self.omega, dtype=np.float64)
        if self.mu is None:
            self.mu = np.ones(self.n, dtype=np.float64)
        else:
            self.mu = np.asarray(self.mu, dtype=np.float64)
        self.parents: list[list[int]] = [[] for _ in range(self.n)]
        self.children: list[list[int]] = [[] for _ in range(self.n)]
        seen = set()
        for (u, v) in self.edge_list:
            if (u, v) in seen:
                continue
            seen.add((u, v))
            if not (0 <= u < self.n and 0 <= v < self.n):
                raise ValueError(f"edge ({u},{v}) out of range")
            self.parents[v].append(u)
            self.children[u].append(v)
        self._topo: list[int] | None = None

    @property
    def num_edges(self) -> int:
        return sum(len(c) for c in self.children)

    def topo_order(self) -> list[int]:
        if self._topo is not None:
            return self._topo
        indeg = [len(p) for p in self.parents]
        stack = [v for v in range(self.n) if indeg[v] == 0]
        order: list[int] = []
        while stack:
            v = stack.pop()
            order.append(v)
            for c in self.children[v]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    stack.append(c)
        if len(order) != self.n:
            raise ValueError("graph has a directed cycle")
        self._topo = order
        return order

    def sources(self) -> list[int]:
        return [v for v in range(self.n) if not self.parents[v]]

    def sinks(self) -> list[int]:
        return [v for v in range(self.n) if not self.children[v]]


def connected_components(hg: Hypergraph) -> list[list[int]]:
    parent = list(range(hg.n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in hg.edges:
        for v in e[1:]:
            ra, rb = find(e[0]), find(v)
            if ra != rb:
                parent[ra] = rb
    comps: dict[int, list[int]] = {}
    for v in range(hg.n):
        comps.setdefault(find(v), []).append(v)
    return list(comps.values())
