"""Hypergraph and DAG data structures for partitioning / scheduling.

These mirror the paper's Section 3 definitions:
  * a hypergraph is (V, E) with each e in E a subset of V; a (v, e) pair with
    v in e is a *pin*;
  * node weights ``omega`` express compute cost, hyperedge weights ``mu``
    express communicated data size (both default to 1);
  * a DAG is a directed acyclic graph with node compute weights ``omega``
    and node communication weights ``mu`` (size of a node's output value).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

# Bound (pins) on the per-block scratch of one ``Hypergraph.contract``
# dedup/collapse block.  Blocks never split an edge, and both the run-length
# dedup and the hash grouping are per-edge computations, so blocking cannot
# change any output byte -- it only caps the transient (order, edge-of-pin)
# arrays so the fine instance is never materialized twice (the out-of-core
# half of the process-parallel V-cycle).
_CONTRACT_CHUNK_PINS = 4_000_000


class _CsrEdgeView(Sequence):
    """Read-only ``edges`` sequence backed by CSR arrays (no python tuples).

    ``Hypergraph.from_csr`` stores this in place of the edge-tuple list so a
    10^7-pin instance never materializes per-edge python objects; indexing
    still yields plain tuples, and equality against any sequence of tuples
    (or another view) is element-wise, so existing callers and tests see a
    list-compatible object.  Segments must be sorted, deduplicated and
    in-range -- the ``presorted=True`` contract.
    """

    __slots__ = ("xpins", "pins")

    def __init__(self, xpins: np.ndarray, pins: np.ndarray) -> None:
        self.xpins = xpins
        self.pins = pins

    def __len__(self) -> int:
        return len(self.xpins) - 1

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        i = int(i)
        if i < 0:
            i += len(self)
        if not 0 <= i < len(self):
            raise IndexError(i)
        return tuple(self.pins[self.xpins[i]:self.xpins[i + 1]].tolist())

    def __iter__(self):
        x = self.xpins
        for i in range(len(self)):
            yield tuple(self.pins[x[i]:x[i + 1]].tolist())

    def __eq__(self, other):
        if isinstance(other, _CsrEdgeView):
            return (np.array_equal(self.xpins, other.xpins)
                    and np.array_equal(self.pins, other.pins))
        if isinstance(other, (list, tuple)):
            return len(self) == len(other) and all(
                a == b for a, b in zip(self, other))
        return NotImplemented

    def __repr__(self) -> str:
        return f"<CsrEdgeView m={len(self)} pins={len(self.pins)}>"

    # __slots__ classes need explicit pickle support (spawn-start workers)
    def __getstate__(self):
        return (self.xpins, self.pins)

    def __setstate__(self, state):
        self.xpins, self.pins = state


@dataclasses.dataclass
class Hypergraph:
    n: int
    edges: list[tuple[int, ...]]
    omega: np.ndarray | None = None  # node weights, shape (n,)
    mu: np.ndarray | None = None     # hyperedge weights, shape (len(edges),)
    name: str = "hypergraph"
    # edges already sorted, deduplicated tuples of in-range ints: skip the
    # per-edge python normalization pass (used by vectorized constructors --
    # ``contract`` and the streaming datagen -- where it would dominate)
    presorted: bool = False

    def __post_init__(self) -> None:
        if self.omega is None:
            self.omega = np.ones(self.n, dtype=np.float64)
        else:
            self.omega = np.asarray(self.omega, dtype=np.float64)
        if self.mu is None:
            self.mu = np.ones(len(self.edges), dtype=np.float64)
        else:
            self.mu = np.asarray(self.mu, dtype=np.float64)
        if not self.presorted:
            self.edges = [tuple(sorted(set(e))) for e in self.edges]
            for e in self.edges:
                if any(v < 0 or v >= self.n for v in e):
                    raise ValueError(f"edge {e} out of range for n={self.n}")
        self._csr: tuple[np.ndarray, ...] | None = None

    @property
    def num_pins(self) -> int:
        if isinstance(self.edges, _CsrEdgeView):
            return len(self.edges.pins)
        return sum(len(e) for e in self.edges)

    # pickling (spawn-start workers): ship the instance without the lazy CSR
    # cache -- a 10^7-pin hypergraph pickled with it would carry every pin
    # twice, and the cache rebuilds deterministically from ``edges`` anyway
    # (for ``from_csr`` instances the edge view *is* the primary CSR, so
    # nothing is recomputed but the incidence/adjacency halves)
    def __getstate__(self):
        state = self.__dict__.copy()
        state["_csr"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._csr = None

    # ------------------------------------------------------------- CSR layout
    # Two cached compressed-sparse-row views of the pin relation; everything
    # in core/partition iterates these flat arrays instead of python lists.
    #   * edge -> pins:  pins[xpins[e] : xpins[e+1]]      (node ids)
    #   * node -> edges: inc_edges[xinc[v] : xinc[v+1]]   (edge ids)
    # ``edges`` must not be mutated after construction (the cache would go
    # stale); build a new Hypergraph instead.
    @classmethod
    def from_csr(cls, n: int, xpins: np.ndarray, pins: np.ndarray,
                 omega: np.ndarray | None = None,
                 mu: np.ndarray | None = None,
                 name: str = "hypergraph") -> "Hypergraph":
        """Vectorized constructor from a CSR edge layout (no edge tuples).

        ``pins[xpins[e] : xpins[e+1]]`` are edge e's pins, already sorted,
        deduplicated and in range (the ``presorted=True`` contract -- the
        streaming datagen and ``contract`` guarantee it).  The arrays are
        adopted, not copied, so shared-memory-backed inputs stay
        shared-memory-backed (the zero-copy half of the parallel layer).
        """
        xpins = np.asarray(xpins, dtype=np.int64)
        pins = np.asarray(pins, dtype=np.int64)
        return cls(n=n, edges=_CsrEdgeView(xpins, pins), omega=omega, mu=mu,
                   name=name, presorted=True)

    def _build_csr(self) -> tuple[np.ndarray, ...]:
        if self._csr is not None:
            return self._csr
        m = len(self.edges)
        if isinstance(self.edges, _CsrEdgeView):
            xpins, pins = self.edges.xpins, self.edges.pins
            lens = np.diff(xpins)
        else:
            lens = np.fromiter((len(e) for e in self.edges), dtype=np.int64,
                               count=m)
            xpins = np.zeros(m + 1, dtype=np.int64)
            np.cumsum(lens, out=xpins[1:])
            total = int(xpins[-1])
            pins = np.fromiter((v for e in self.edges for v in e),
                               dtype=np.int64, count=total)
        edge_of_pin = np.repeat(np.arange(m, dtype=np.int64), lens)
        order = np.argsort(pins, kind="stable")
        inc_edges = edge_of_pin[order]
        xinc = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(pins, minlength=self.n), out=xinc[1:])
        # pin-adjacency: for node v, the concatenated pins of its incident
        # edges (multiset, edge order) -- the BFS frontier of greedy growth.
        e_lens = lens[inc_edges]
        node_tot = np.zeros(self.n, dtype=np.int64)
        np.add.at(node_tot, pins, lens[edge_of_pin])
        xadj = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(node_tot, out=xadj[1:])
        if e_lens.sum():
            starts = xpins[inc_edges]
            offs = np.arange(int(e_lens.sum()), dtype=np.int64)
            offs -= np.repeat(np.cumsum(e_lens) - e_lens, e_lens)
            adj = pins[np.repeat(starts, e_lens) + offs]
        else:
            adj = np.zeros(0, dtype=np.int64)
        self._csr = (xpins, pins, xinc, inc_edges, xadj, adj)
        return self._csr

    @property
    def xpins(self) -> np.ndarray:
        return self._build_csr()[0]

    @property
    def pins(self) -> np.ndarray:
        return self._build_csr()[1]

    @property
    def xinc(self) -> np.ndarray:
        return self._build_csr()[2]

    @property
    def inc_edges(self) -> np.ndarray:
        return self._build_csr()[3]

    @property
    def xadj(self) -> np.ndarray:
        return self._build_csr()[4]

    @property
    def adj_nodes(self) -> np.ndarray:
        return self._build_csr()[5]

    # --------------------------------------------------- contraction layer
    # Multilevel coarsening support (multilevel V-cycle, PR 4): given a
    # cluster map ``cmap`` (fine node -> coarse node id), ``contract``
    # builds the contracted hypergraph fully vectorized over the CSR pin
    # arrays and returns the edge prolongation map alongside it.  The node
    # prolongation map is ``cmap`` itself: coarse masks project to fine
    # masks as ``coarse_masks[cmap]`` (replication masks project as unions
    # -- every member of a cluster inherits the cluster's full mask, which
    # *is* the union since the cluster is one coarse node).
    def contract(self, cmap: np.ndarray, nc: int | None = None,
                 chunk_pins: int | None = None
                 ) -> tuple["Hypergraph", np.ndarray]:
        """Contract clusters of nodes into single coarse nodes.

        ``cmap[v]`` is the coarse id of fine node v (0 <= cmap[v] < nc).
        Coarse node weights are the cluster sums of ``omega``.  Each fine
        edge maps its pins through ``cmap`` and deduplicates; edges left
        with fewer than two distinct coarse pins are dropped (their
        ``lambda`` is at most 1 under any assignment, so they can never
        cost anything), and edges with *identical* coarse pin sets collapse
        into one coarse edge whose ``mu`` is their sum (identical-net
        collapsing).  Returns ``(coarse, edge_map)`` with ``edge_map[e]``
        the coarse edge id of fine edge e, or -1 if it was dropped.

        The pin dedup streams over edge-range blocks of at most
        ``chunk_pins`` pins (default ``_CONTRACT_CHUNK_PINS``; an edge is
        never split), so the transient sort scratch stays bounded and the
        fine pin expansion is never held twice -- blocking is invisible in
        the output.  Identical-net collapsing is a dual-64-bit polynomial
        hash grouping with exact verification against each group's
        representative segment; any verification miss (probability ~2^-128)
        falls back to the byte-key dict path, so the result is always exact.

        Cost identity (the multilevel contract): for any coarse masks ``M``
        the fine cost of the projected masks ``M[cmap]`` equals the coarse
        cost of ``M``, and the per-processor loads agree exactly -- see
        ``PartitionState.from_projection`` and ``tests/test_multilevel.py``.
        """
        cmap = np.asarray(cmap, dtype=np.int64)
        if cmap.shape != (self.n,):
            raise ValueError("cmap must have shape (n,)")
        if nc is None:
            nc = int(cmap.max()) + 1 if self.n else 0
        if self.n and (cmap.min() < 0 or cmap.max() >= nc):
            raise ValueError("cmap out of range")
        omega_c = np.bincount(cmap, weights=self.omega, minlength=nc)
        m = len(self.edges)
        edge_map = np.full(m, -1, dtype=np.int64)
        if m == 0:
            coarse = Hypergraph(n=nc, edges=[], omega=omega_c,
                                mu=np.zeros(0), name=f"{self.name}_c",
                                presorted=True)
            return coarse, edge_map
        xpins, pins = self.xpins, self.pins
        lens = np.diff(xpins)
        chunk = (_CONTRACT_CHUNK_PINS if chunk_pins is None
                 else max(int(chunk_pins), 1))
        # sort pins within each edge by coarse id, keep first of each run --
        # streamed: lexsort keys on (edge, coarse pin) segment by edge, so
        # per-block results concatenate to exactly the monolithic output
        lens_c = np.zeros(m, dtype=np.int64)
        cp_parts: list[np.ndarray] = []
        e0 = 0
        while e0 < m:
            e1 = int(np.searchsorted(xpins, xpins[e0] + chunk,
                                     side="right")) - 1
            e1 = min(max(e1, e0 + 1), m)
            cp_b = cmap[pins[xpins[e0]:xpins[e1]]]
            ep_b = np.repeat(np.arange(e0, e1, dtype=np.int64),
                             lens[e0:e1]) - e0
            order = np.lexsort((cp_b, ep_b))
            ep_b, cp_b = ep_b[order], cp_b[order]
            first = np.ones(len(cp_b), dtype=bool)
            first[1:] = (ep_b[1:] != ep_b[:-1]) | (cp_b[1:] != cp_b[:-1])
            cp_parts.append(cp_b[first])
            lens_c[e0:e1] = np.bincount(ep_b[first], minlength=e1 - e0)
            e0 = e1
        cp = np.concatenate(cp_parts)
        xk = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(lens_c, out=xk[1:])
        kept = np.flatnonzero(lens_c >= 2)
        if not len(kept):
            coarse = Hypergraph(n=nc, edges=[], omega=omega_c,
                                mu=np.zeros(0), name=f"{self.name}_c",
                                presorted=True)
            return coarse, edge_map
        ids = _collapse_ids_hash(cp, xk, kept, lens_c[kept])
        if ids is None:  # dual-hash collision (~2^-128): exact dict path
            ids = _collapse_ids_dict(cp, xk, kept)
        edge_map[kept] = ids
        ncc = int(ids.max()) + 1
        # mu sums accumulate in ascending fine-edge order (bincount walks
        # the array in order), matching the dict path float-for-float
        mu_c = np.bincount(ids, weights=self.mu[kept], minlength=ncc)
        # coarse edge id -> its first (representative) fine edge; the
        # coarse CSR gathers each representative's deduped segment
        rep_fine = np.zeros(ncc, dtype=np.int64)
        rep_fine[ids[::-1]] = kept[::-1]          # first occurrence wins
        lens_cc = lens_c[rep_fine]
        xpins_c = np.zeros(ncc + 1, dtype=np.int64)
        np.cumsum(lens_cc, out=xpins_c[1:])
        total_c = int(xpins_c[-1])
        offs = (np.arange(total_c, dtype=np.int64)
                - np.repeat(xpins_c[:-1], lens_cc))
        pins_c = cp[np.repeat(xk[rep_fine], lens_cc) + offs]
        coarse = Hypergraph.from_csr(nc, xpins_c, pins_c, omega=omega_c,
                                     mu=mu_c, name=f"{self.name}_c")
        return coarse, edge_map

    def remove_isolated(self) -> "Hypergraph":
        """Drop nodes appearing in no hyperedge (paper §B.1 does the same)."""
        used = sorted({v for e in self.edges for v in e})
        remap = {v: i for i, v in enumerate(used)}
        edges = [tuple(remap[v] for v in e) for e in self.edges]
        return Hypergraph(
            n=len(used),
            edges=edges,
            omega=self.omega[used],
            mu=self.mu.copy(),
            name=self.name,
        )

    @staticmethod
    def from_graph(n: int, pairs: Iterable[tuple[int, int]], **kw) -> "Hypergraph":
        return Hypergraph(n=n, edges=[tuple(p) for p in pairs], **kw)


# odd multipliers of the dual wraparound polynomial hash (splitmix64-ish
# constants); two independent 64-bit hashes make an accidental group merge
# a ~2^-128 event, and the merge is *verified* before being trusted anyway
_HASH_M1 = np.uint64(0x9E3779B97F4A7C15)
_HASH_M2 = np.uint64(0xC2B2AE3D27D4EB4F)


def _collapse_ids_hash(cp: np.ndarray, xk: np.ndarray, kept: np.ndarray,
                       klens: np.ndarray) -> np.ndarray | None:
    """Identical-net group ids for the kept segments, or None on collision.

    Segments ``cp[xk[e] : xk[e] + klens]`` (sorted coarse pins) hash to a
    (length, h1, h2) key; equal-key runs are groups, each verified exactly
    against its first (smallest fine id) member.  Returned ids follow the
    first-fine-occurrence order of the dict path byte for byte.
    """
    K = len(kept)
    total = int(klens.sum())
    starts_flat = np.cumsum(klens) - klens
    offs = np.arange(total, dtype=np.int64) - np.repeat(starts_flat, klens)
    idx = np.repeat(xk[kept], klens) + offs
    vals = cp[idx].astype(np.uint64) + np.uint64(1)
    maxlen = int(klens.max())
    pows1 = np.ones(maxlen, dtype=np.uint64)
    pows1[1:] = _HASH_M1
    np.cumprod(pows1, out=pows1)                  # M1^pos mod 2^64
    pows2 = np.ones(maxlen, dtype=np.uint64)
    pows2[1:] = _HASH_M2
    np.cumprod(pows2, out=pows2)
    h1 = np.add.reduceat(vals * pows1[offs], starts_flat)
    h2 = np.add.reduceat(vals * pows2[offs], starts_flat)
    # group by (len, h1, h2); within a group, fine ids stay ascending
    order_h = np.lexsort((kept, h2, h1, klens))
    ks_len, ks_h1, ks_h2 = klens[order_h], h1[order_h], h2[order_h]
    new = np.ones(K, dtype=bool)
    new[1:] = ((ks_len[1:] != ks_len[:-1]) | (ks_h1[1:] != ks_h1[:-1])
               | (ks_h2[1:] != ks_h2[:-1]))
    gid = np.cumsum(new) - 1
    kept_sorted = kept[order_h]
    rep_sorted = kept_sorted[new]       # per group: its smallest fine id
    memb = np.flatnonzero(~new)         # non-representative members
    if len(memb):
        ln = ks_len[memb]
        tot = int(ln.sum())
        off2 = (np.arange(tot, dtype=np.int64)
                - np.repeat(np.cumsum(ln) - ln, ln))
        own = np.repeat(xk[kept_sorted[memb]], ln) + off2
        rep = np.repeat(xk[rep_sorted[gid[memb]]], ln) + off2
        if not np.array_equal(cp[own], cp[rep]):
            return None
    # coarse ids in first-fine-occurrence order == groups sorted by their
    # representative's fine id (the representative IS the first occurrence)
    order_g = np.argsort(rep_sorted, kind="stable")
    cid = np.empty(len(rep_sorted), dtype=np.int64)
    cid[order_g] = np.arange(len(rep_sorted), dtype=np.int64)
    ids = np.empty(K, dtype=np.int64)
    ids[order_h] = cid[gid]
    return ids


def _collapse_ids_dict(cp: np.ndarray, xk: np.ndarray,
                       kept: np.ndarray) -> np.ndarray:
    """Byte-key reference path of identical-net collapsing (exact, serial);
    also the fallback should the dual hash ever collide."""
    groups: dict[bytes, int] = {}
    ids = np.empty(len(kept), dtype=np.int64)
    for j, e in enumerate(kept):
        key = cp[xk[e]:xk[e + 1]].tobytes()
        idx = groups.get(key)
        if idx is None:
            idx = len(groups)
            groups[key] = idx
        ids[j] = idx
    return ids


@dataclasses.dataclass
class Dag:
    """Computational DAG.  ``parents[v]`` / ``children[v]`` are index lists."""

    n: int
    edge_list: list[tuple[int, int]]
    omega: np.ndarray | None = None  # compute weight per node
    mu: np.ndarray | None = None     # communication weight (output size) per node
    name: str = "dag"

    def __post_init__(self) -> None:
        if self.omega is None:
            self.omega = np.ones(self.n, dtype=np.float64)
        else:
            self.omega = np.asarray(self.omega, dtype=np.float64)
        if self.mu is None:
            self.mu = np.ones(self.n, dtype=np.float64)
        else:
            self.mu = np.asarray(self.mu, dtype=np.float64)
        self.parents: list[list[int]] = [[] for _ in range(self.n)]
        self.children: list[list[int]] = [[] for _ in range(self.n)]
        seen = set()
        for (u, v) in self.edge_list:
            if (u, v) in seen:
                continue
            seen.add((u, v))
            if not (0 <= u < self.n and 0 <= v < self.n):
                raise ValueError(f"edge ({u},{v}) out of range")
            self.parents[v].append(u)
            self.children[u].append(v)
        self._topo: list[int] | None = None
        self._csr: tuple[np.ndarray, ...] | None = None

    @property
    def num_edges(self) -> int:
        return sum(len(c) for c in self.children)

    # pickling (spawn-start workers): drop the lazy CSR/topo caches -- they
    # rebuild deterministically and would otherwise double the payload
    def __getstate__(self):
        state = self.__dict__.copy()
        state["_csr"] = None
        state["_topo"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._csr = None
        self._topo = None

    # ------------------------------------------------------------- CSR layout
    # Cached flat views of the (deduplicated) edge relation; the multilevel
    # scheduling coarsener iterates these arrays instead of the python
    # adjacency lists.  ``edge_list``/``parents``/``children`` must not be
    # mutated after construction (build a new Dag instead).
    #   * ``edge_src``/``edge_dst``: all edges, sorted by (src, dst);
    #   * parents CSR: ``par_arr[xpar[v] : xpar[v+1]]`` (sorted parent ids).
    @staticmethod
    def _edge_csr(n: int, src: np.ndarray,
                  dst: np.ndarray) -> tuple[np.ndarray, ...]:
        """(src, dst) sorted by (src, dst) plus the parents CSR -- the one
        layout both constructors seed, so CSR bytes never depend on which
        constructor built the Dag."""
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        xpar = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(np.bincount(dst, minlength=n), out=xpar[1:])
        par_arr = src[np.lexsort((src, dst))]
        return src, dst, xpar, par_arr

    def _build_csr(self) -> tuple[np.ndarray, ...]:
        if self._csr is not None:
            return self._csr
        m = self.num_edges
        src = np.fromiter((u for u in range(self.n)
                           for _ in self.children[u]),
                          dtype=np.int64, count=m)
        dst = np.fromiter((v for u in range(self.n)
                           for v in self.children[u]),
                          dtype=np.int64, count=m)
        self._csr = self._edge_csr(self.n, src, dst)
        return self._csr

    @property
    def edge_src(self) -> np.ndarray:
        return self._build_csr()[0]

    @property
    def edge_dst(self) -> np.ndarray:
        return self._build_csr()[1]

    @property
    def xpar(self) -> np.ndarray:
        return self._build_csr()[2]

    @property
    def par_arr(self) -> np.ndarray:
        return self._build_csr()[3]

    @classmethod
    def from_arrays(cls, n: int, src: np.ndarray, dst: np.ndarray,
                    omega: np.ndarray | None = None,
                    mu: np.ndarray | None = None,
                    name: str = "dag") -> "Dag":
        """Vectorized constructor from flat edge arrays (streaming datagen,
        ``contract``).  Deduplicates, range-checks and builds the adjacency
        lists via one sort + split instead of the per-edge python loop of
        ``__post_init__`` -- n = 100k DAGs construct in well under a second.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if len(src) and (src.min() < 0 or src.max() >= n
                         or dst.min() < 0 or dst.max() >= n):
            raise ValueError("edge endpoint out of range")
        key = np.unique(src * np.int64(n) + dst)   # dedup + (src, dst) sort
        src, dst = key // n, key % n
        d = cls.__new__(cls)
        d.n = n
        d.name = name
        d.omega = (np.ones(n, dtype=np.float64) if omega is None
                   else np.asarray(omega, dtype=np.float64))
        d.mu = (np.ones(n, dtype=np.float64) if mu is None
                else np.asarray(mu, dtype=np.float64))
        d.edge_list = list(zip(src.tolist(), dst.tolist()))
        ch_counts = np.bincount(src, minlength=n)
        d.children = [a.tolist()
                      for a in np.split(dst, np.cumsum(ch_counts)[:-1])]
        d._csr = cls._edge_csr(n, src, dst)
        par_arr, xpar = d._csr[3], d._csr[2]
        d.parents = [a.tolist()
                     for a in np.split(par_arr, xpar[1:-1])]
        d._topo = None
        return d

    # --------------------------------------------------- contraction layer
    # Multilevel scheduling support (PR 5): ``contract`` collapses clusters
    # of a cluster map into single coarse nodes, fully vectorized over the
    # edge arrays.  Unlike ``Hypergraph.contract`` there is no edge
    # prolongation map to return -- fine communications are re-derived
    # canonically from the expanded assignment (``Schedule.from_projection``)
    # rather than projected, because one coarse comm stands for one comm per
    # boundary member at the fine level.
    def contract(self, cmap: np.ndarray, nc: int | None = None) -> "Dag":
        """Contract clusters of nodes into single coarse nodes.

        ``cmap[v]`` is the coarse id of fine node v.  Coarse compute
        weights are the cluster sums of ``omega``; the coarse communication
        weight is the sum of ``mu`` over the cluster's *boundary* members
        (nodes with at least one child outside the cluster) -- exactly the
        values a consumer on another processor would need delivered.
        Intra-cluster edges vanish; parallel cross edges collapse.

        The coarse graph must remain acyclic -- contracting an arbitrary
        cluster map can create cycles, so callers must use an
        acyclicity-safe clustering (same-topological-level matching or
        unique-parent funnels, see ``core.schedule.multilevel``).  The
        contraction *validates* this eagerly and raises ``ValueError``
        (from the topological sort) on a cyclic cluster map.
        """
        cmap = np.asarray(cmap, dtype=np.int64)
        if cmap.shape != (self.n,):
            raise ValueError("cmap must have shape (n,)")
        if nc is None:
            nc = int(cmap.max()) + 1 if self.n else 0
        if self.n and (cmap.min() < 0 or cmap.max() >= nc):
            raise ValueError("cmap out of range")
        omega_c = np.bincount(cmap, weights=self.omega, minlength=nc)
        src, dst = self.edge_src, self.edge_dst
        cu, cv = cmap[src], cmap[dst]
        cross = cu != cv
        boundary = np.unique(src[cross])   # members with an external child
        mu_c = np.bincount(cmap[boundary], weights=self.mu[boundary],
                           minlength=nc)
        coarse = Dag.from_arrays(nc, cu[cross], cv[cross], omega=omega_c,
                                 mu=mu_c, name=f"{self.name}_c")
        coarse.topo_order()   # raises on a cycle-creating cluster map
        return coarse

    def topo_order(self) -> list[int]:
        if self._topo is not None:
            return self._topo
        indeg = [len(p) for p in self.parents]
        stack = [v for v in range(self.n) if indeg[v] == 0]
        order: list[int] = []
        while stack:
            v = stack.pop()
            order.append(v)
            for c in self.children[v]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    stack.append(c)
        if len(order) != self.n:
            raise ValueError("graph has a directed cycle")
        self._topo = order
        return order

    def sources(self) -> list[int]:
        return [v for v in range(self.n) if not self.parents[v]]

    def sinks(self) -> list[int]:
        return [v for v in range(self.n) if not self.children[v]]


def connected_components(hg: Hypergraph) -> list[list[int]]:
    parent = list(range(hg.n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in hg.edges:
        for v in e[1:]:
            ra, rb = find(e[0]), find(v)
            if ra != rb:
                parent[ra] = rb
    comps: dict[int, list[int]] = {}
    for v in range(hg.n):
        comps.setdefault(find(v), []).append(v)
    return list(comps.values())
