"""Hypergraph and DAG data structures for partitioning / scheduling.

These mirror the paper's Section 3 definitions:
  * a hypergraph is (V, E) with each e in E a subset of V; a (v, e) pair with
    v in e is a *pin*;
  * node weights ``omega`` express compute cost, hyperedge weights ``mu``
    express communicated data size (both default to 1);
  * a DAG is a directed acyclic graph with node compute weights ``omega``
    and node communication weights ``mu`` (size of a node's output value).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass
class Hypergraph:
    n: int
    edges: list[tuple[int, ...]]
    omega: np.ndarray | None = None  # node weights, shape (n,)
    mu: np.ndarray | None = None     # hyperedge weights, shape (len(edges),)
    name: str = "hypergraph"
    # edges already sorted, deduplicated tuples of in-range ints: skip the
    # per-edge python normalization pass (used by vectorized constructors --
    # ``contract`` and the streaming datagen -- where it would dominate)
    presorted: bool = False

    def __post_init__(self) -> None:
        if self.omega is None:
            self.omega = np.ones(self.n, dtype=np.float64)
        else:
            self.omega = np.asarray(self.omega, dtype=np.float64)
        if self.mu is None:
            self.mu = np.ones(len(self.edges), dtype=np.float64)
        else:
            self.mu = np.asarray(self.mu, dtype=np.float64)
        if not self.presorted:
            self.edges = [tuple(sorted(set(e))) for e in self.edges]
            for e in self.edges:
                if any(v < 0 or v >= self.n for v in e):
                    raise ValueError(f"edge {e} out of range for n={self.n}")
        self._csr: tuple[np.ndarray, ...] | None = None

    @property
    def num_pins(self) -> int:
        return sum(len(e) for e in self.edges)

    # ------------------------------------------------------------- CSR layout
    # Two cached compressed-sparse-row views of the pin relation; everything
    # in core/partition iterates these flat arrays instead of python lists.
    #   * edge -> pins:  pins[xpins[e] : xpins[e+1]]      (node ids)
    #   * node -> edges: inc_edges[xinc[v] : xinc[v+1]]   (edge ids)
    # ``edges`` must not be mutated after construction (the cache would go
    # stale); build a new Hypergraph instead.
    def _build_csr(self) -> tuple[np.ndarray, ...]:
        if self._csr is not None:
            return self._csr
        m = len(self.edges)
        lens = np.fromiter((len(e) for e in self.edges), dtype=np.int64,
                           count=m)
        xpins = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(lens, out=xpins[1:])
        total = int(xpins[-1])
        pins = np.fromiter((v for e in self.edges for v in e),
                           dtype=np.int64, count=total)
        edge_of_pin = np.repeat(np.arange(m, dtype=np.int64), lens)
        order = np.argsort(pins, kind="stable")
        inc_edges = edge_of_pin[order]
        xinc = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(pins, minlength=self.n), out=xinc[1:])
        # pin-adjacency: for node v, the concatenated pins of its incident
        # edges (multiset, edge order) -- the BFS frontier of greedy growth.
        e_lens = lens[inc_edges]
        node_tot = np.zeros(self.n, dtype=np.int64)
        np.add.at(node_tot, pins, lens[edge_of_pin])
        xadj = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(node_tot, out=xadj[1:])
        if e_lens.sum():
            starts = xpins[inc_edges]
            offs = np.arange(int(e_lens.sum()), dtype=np.int64)
            offs -= np.repeat(np.cumsum(e_lens) - e_lens, e_lens)
            adj = pins[np.repeat(starts, e_lens) + offs]
        else:
            adj = np.zeros(0, dtype=np.int64)
        self._csr = (xpins, pins, xinc, inc_edges, xadj, adj)
        return self._csr

    @property
    def xpins(self) -> np.ndarray:
        return self._build_csr()[0]

    @property
    def pins(self) -> np.ndarray:
        return self._build_csr()[1]

    @property
    def xinc(self) -> np.ndarray:
        return self._build_csr()[2]

    @property
    def inc_edges(self) -> np.ndarray:
        return self._build_csr()[3]

    @property
    def xadj(self) -> np.ndarray:
        return self._build_csr()[4]

    @property
    def adj_nodes(self) -> np.ndarray:
        return self._build_csr()[5]

    # --------------------------------------------------- contraction layer
    # Multilevel coarsening support (multilevel V-cycle, PR 4): given a
    # cluster map ``cmap`` (fine node -> coarse node id), ``contract``
    # builds the contracted hypergraph fully vectorized over the CSR pin
    # arrays and returns the edge prolongation map alongside it.  The node
    # prolongation map is ``cmap`` itself: coarse masks project to fine
    # masks as ``coarse_masks[cmap]`` (replication masks project as unions
    # -- every member of a cluster inherits the cluster's full mask, which
    # *is* the union since the cluster is one coarse node).
    def contract(self, cmap: np.ndarray,
                 nc: int | None = None) -> tuple["Hypergraph", np.ndarray]:
        """Contract clusters of nodes into single coarse nodes.

        ``cmap[v]`` is the coarse id of fine node v (0 <= cmap[v] < nc).
        Coarse node weights are the cluster sums of ``omega``.  Each fine
        edge maps its pins through ``cmap`` and deduplicates; edges left
        with fewer than two distinct coarse pins are dropped (their
        ``lambda`` is at most 1 under any assignment, so they can never
        cost anything), and edges with *identical* coarse pin sets collapse
        into one coarse edge whose ``mu`` is their sum (identical-net
        collapsing).  Returns ``(coarse, edge_map)`` with ``edge_map[e]``
        the coarse edge id of fine edge e, or -1 if it was dropped.

        Cost identity (the multilevel contract): for any coarse masks ``M``
        the fine cost of the projected masks ``M[cmap]`` equals the coarse
        cost of ``M``, and the per-processor loads agree exactly -- see
        ``PartitionState.from_projection`` and ``tests/test_multilevel.py``.
        """
        cmap = np.asarray(cmap, dtype=np.int64)
        if cmap.shape != (self.n,):
            raise ValueError("cmap must have shape (n,)")
        if nc is None:
            nc = int(cmap.max()) + 1 if self.n else 0
        if self.n and (cmap.min() < 0 or cmap.max() >= nc):
            raise ValueError("cmap out of range")
        omega_c = np.bincount(cmap, weights=self.omega, minlength=nc)
        m = len(self.edges)
        edge_map = np.full(m, -1, dtype=np.int64)
        if m == 0:
            coarse = Hypergraph(n=nc, edges=[], omega=omega_c,
                                mu=np.zeros(0), name=f"{self.name}_c",
                                presorted=True)
            return coarse, edge_map
        xpins, pins = self.xpins, self.pins
        lens = np.diff(xpins)
        cpins = cmap[pins]
        edge_of_pin = np.repeat(np.arange(m, dtype=np.int64), lens)
        # sort pins within each edge by coarse id, keep first of each run
        order = np.lexsort((cpins, edge_of_pin))
        ep, cp = edge_of_pin[order], cpins[order]
        first = np.ones(len(cp), dtype=bool)
        first[1:] = (ep[1:] != ep[:-1]) | (cp[1:] != cp[:-1])
        ep, cp = ep[first], cp[first]
        lens_c = np.bincount(ep, minlength=m)
        xk = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(lens_c, out=xk[1:])
        keep = lens_c >= 2
        # identical-net collapsing: canonical key = the sorted coarse pin
        # run; fine-edge order decides coarse edge ids (deterministic)
        groups: dict[bytes, int] = {}
        coarse_edges: list[tuple[int, ...]] = []
        mu_list: list[float] = []
        for e in np.flatnonzero(keep):
            seg = cp[xk[e]:xk[e + 1]]
            key = seg.tobytes()
            idx = groups.get(key)
            if idx is None:
                idx = len(coarse_edges)
                groups[key] = idx
                coarse_edges.append(tuple(seg.tolist()))
                mu_list.append(float(self.mu[e]))
            else:
                mu_list[idx] += float(self.mu[e])
            edge_map[e] = idx
        coarse = Hypergraph(n=nc, edges=coarse_edges, omega=omega_c,
                            mu=np.asarray(mu_list, dtype=np.float64),
                            name=f"{self.name}_c", presorted=True)
        return coarse, edge_map

    def remove_isolated(self) -> "Hypergraph":
        """Drop nodes appearing in no hyperedge (paper §B.1 does the same)."""
        used = sorted({v for e in self.edges for v in e})
        remap = {v: i for i, v in enumerate(used)}
        edges = [tuple(remap[v] for v in e) for e in self.edges]
        return Hypergraph(
            n=len(used),
            edges=edges,
            omega=self.omega[used],
            mu=self.mu.copy(),
            name=self.name,
        )

    @staticmethod
    def from_graph(n: int, pairs: Iterable[tuple[int, int]], **kw) -> "Hypergraph":
        return Hypergraph(n=n, edges=[tuple(p) for p in pairs], **kw)


@dataclasses.dataclass
class Dag:
    """Computational DAG.  ``parents[v]`` / ``children[v]`` are index lists."""

    n: int
    edge_list: list[tuple[int, int]]
    omega: np.ndarray | None = None  # compute weight per node
    mu: np.ndarray | None = None     # communication weight (output size) per node
    name: str = "dag"

    def __post_init__(self) -> None:
        if self.omega is None:
            self.omega = np.ones(self.n, dtype=np.float64)
        else:
            self.omega = np.asarray(self.omega, dtype=np.float64)
        if self.mu is None:
            self.mu = np.ones(self.n, dtype=np.float64)
        else:
            self.mu = np.asarray(self.mu, dtype=np.float64)
        self.parents: list[list[int]] = [[] for _ in range(self.n)]
        self.children: list[list[int]] = [[] for _ in range(self.n)]
        seen = set()
        for (u, v) in self.edge_list:
            if (u, v) in seen:
                continue
            seen.add((u, v))
            if not (0 <= u < self.n and 0 <= v < self.n):
                raise ValueError(f"edge ({u},{v}) out of range")
            self.parents[v].append(u)
            self.children[u].append(v)
        self._topo: list[int] | None = None
        self._csr: tuple[np.ndarray, ...] | None = None

    @property
    def num_edges(self) -> int:
        return sum(len(c) for c in self.children)

    # ------------------------------------------------------------- CSR layout
    # Cached flat views of the (deduplicated) edge relation; the multilevel
    # scheduling coarsener iterates these arrays instead of the python
    # adjacency lists.  ``edge_list``/``parents``/``children`` must not be
    # mutated after construction (build a new Dag instead).
    #   * ``edge_src``/``edge_dst``: all edges, sorted by (src, dst);
    #   * parents CSR: ``par_arr[xpar[v] : xpar[v+1]]`` (sorted parent ids).
    @staticmethod
    def _edge_csr(n: int, src: np.ndarray,
                  dst: np.ndarray) -> tuple[np.ndarray, ...]:
        """(src, dst) sorted by (src, dst) plus the parents CSR -- the one
        layout both constructors seed, so CSR bytes never depend on which
        constructor built the Dag."""
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        xpar = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(np.bincount(dst, minlength=n), out=xpar[1:])
        par_arr = src[np.lexsort((src, dst))]
        return src, dst, xpar, par_arr

    def _build_csr(self) -> tuple[np.ndarray, ...]:
        if self._csr is not None:
            return self._csr
        m = self.num_edges
        src = np.fromiter((u for u in range(self.n)
                           for _ in self.children[u]),
                          dtype=np.int64, count=m)
        dst = np.fromiter((v for u in range(self.n)
                           for v in self.children[u]),
                          dtype=np.int64, count=m)
        self._csr = self._edge_csr(self.n, src, dst)
        return self._csr

    @property
    def edge_src(self) -> np.ndarray:
        return self._build_csr()[0]

    @property
    def edge_dst(self) -> np.ndarray:
        return self._build_csr()[1]

    @property
    def xpar(self) -> np.ndarray:
        return self._build_csr()[2]

    @property
    def par_arr(self) -> np.ndarray:
        return self._build_csr()[3]

    @classmethod
    def from_arrays(cls, n: int, src: np.ndarray, dst: np.ndarray,
                    omega: np.ndarray | None = None,
                    mu: np.ndarray | None = None,
                    name: str = "dag") -> "Dag":
        """Vectorized constructor from flat edge arrays (streaming datagen,
        ``contract``).  Deduplicates, range-checks and builds the adjacency
        lists via one sort + split instead of the per-edge python loop of
        ``__post_init__`` -- n = 100k DAGs construct in well under a second.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if len(src) and (src.min() < 0 or src.max() >= n
                         or dst.min() < 0 or dst.max() >= n):
            raise ValueError("edge endpoint out of range")
        key = np.unique(src * np.int64(n) + dst)   # dedup + (src, dst) sort
        src, dst = key // n, key % n
        d = cls.__new__(cls)
        d.n = n
        d.name = name
        d.omega = (np.ones(n, dtype=np.float64) if omega is None
                   else np.asarray(omega, dtype=np.float64))
        d.mu = (np.ones(n, dtype=np.float64) if mu is None
                else np.asarray(mu, dtype=np.float64))
        d.edge_list = list(zip(src.tolist(), dst.tolist()))
        ch_counts = np.bincount(src, minlength=n)
        d.children = [a.tolist()
                      for a in np.split(dst, np.cumsum(ch_counts)[:-1])]
        d._csr = cls._edge_csr(n, src, dst)
        par_arr, xpar = d._csr[3], d._csr[2]
        d.parents = [a.tolist()
                     for a in np.split(par_arr, xpar[1:-1])]
        d._topo = None
        return d

    # --------------------------------------------------- contraction layer
    # Multilevel scheduling support (PR 5): ``contract`` collapses clusters
    # of a cluster map into single coarse nodes, fully vectorized over the
    # edge arrays.  Unlike ``Hypergraph.contract`` there is no edge
    # prolongation map to return -- fine communications are re-derived
    # canonically from the expanded assignment (``Schedule.from_projection``)
    # rather than projected, because one coarse comm stands for one comm per
    # boundary member at the fine level.
    def contract(self, cmap: np.ndarray, nc: int | None = None) -> "Dag":
        """Contract clusters of nodes into single coarse nodes.

        ``cmap[v]`` is the coarse id of fine node v.  Coarse compute
        weights are the cluster sums of ``omega``; the coarse communication
        weight is the sum of ``mu`` over the cluster's *boundary* members
        (nodes with at least one child outside the cluster) -- exactly the
        values a consumer on another processor would need delivered.
        Intra-cluster edges vanish; parallel cross edges collapse.

        The coarse graph must remain acyclic -- contracting an arbitrary
        cluster map can create cycles, so callers must use an
        acyclicity-safe clustering (same-topological-level matching or
        unique-parent funnels, see ``core.schedule.multilevel``).  The
        contraction *validates* this eagerly and raises ``ValueError``
        (from the topological sort) on a cyclic cluster map.
        """
        cmap = np.asarray(cmap, dtype=np.int64)
        if cmap.shape != (self.n,):
            raise ValueError("cmap must have shape (n,)")
        if nc is None:
            nc = int(cmap.max()) + 1 if self.n else 0
        if self.n and (cmap.min() < 0 or cmap.max() >= nc):
            raise ValueError("cmap out of range")
        omega_c = np.bincount(cmap, weights=self.omega, minlength=nc)
        src, dst = self.edge_src, self.edge_dst
        cu, cv = cmap[src], cmap[dst]
        cross = cu != cv
        boundary = np.unique(src[cross])   # members with an external child
        mu_c = np.bincount(cmap[boundary], weights=self.mu[boundary],
                           minlength=nc)
        coarse = Dag.from_arrays(nc, cu[cross], cv[cross], omega=omega_c,
                                 mu=mu_c, name=f"{self.name}_c")
        coarse.topo_order()   # raises on a cycle-creating cluster map
        return coarse

    def topo_order(self) -> list[int]:
        if self._topo is not None:
            return self._topo
        indeg = [len(p) for p in self.parents]
        stack = [v for v in range(self.n) if indeg[v] == 0]
        order: list[int] = []
        while stack:
            v = stack.pop()
            order.append(v)
            for c in self.children[v]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    stack.append(c)
        if len(order) != self.n:
            raise ValueError("graph has a directed cycle")
        self._topo = order
        return order

    def sources(self) -> list[int]:
        return [v for v in range(self.n) if not self.parents[v]]

    def sinks(self) -> list[int]:
        return [v for v in range(self.n) if not self.children[v]]


def connected_components(hg: Hypergraph) -> list[list[int]]:
    parent = list(range(hg.n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in hg.edges:
        for v in e[1:]:
            ra, rb = find(e[0]), find(v)
            if ra != rb:
                parent[ra] = rb
    comps: dict[int, list[int]] = {}
    for v in range(hg.n):
        comps.setdefault(find(v), []).append(v)
    return list(comps.values())
