"""HLO text parsing for the roofline analysis.

``cost_analysis()`` provides FLOPs and bytes accessed but not collective
traffic; we parse the compiled HLO text and sum the result-buffer sizes of
every collective op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), per op kind.  Sizes are per-participant buffer bytes,
i.e. what one chip's ICI links carry for that op (the roofline's
collective_bytes / (chips x link_bw) uses exactly this quantity).
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = f32[16,1024]{1,0} all-reduce(...)
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+([a-z\-]+)")
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def collective_bytes_from_text(hlo: str) -> dict:
    per_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        stripped = line.strip()
        # find the op name after '='
        m = re.search(r"=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(",
                        rhs)
        if not opm:
            continue
        kind = opm.group(1)
        if "-done(" in rhs:
            continue  # avoid double counting async pairs (count the start)
        # result type(s): possibly a tuple
        head = rhs.split(kind)[0]
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _TUPLE_RE.findall(head))
        per_kind[kind] += total
        counts[kind] += 1
    return {
        "per_kind_bytes": per_kind,
        "counts": counts,
        "total_bytes": int(sum(per_kind.values())),
    }


def summarize_cost(cost) -> dict:
    """cost_analysis() returns a dict (or list of dicts) of named scalars."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out = {}
    for key in ("flops", "bytes accessed", "transcendentals",
                "optimal_seconds"):
        if key in cost:
            out[key.replace(" ", "_")] = float(cost[key])
    # per-memory-space bytes where present
    for k, v in cost.items():
        if k.startswith("bytes accessed") and k != "bytes accessed":
            out[k.replace(" ", "_").replace("'", "")] = float(v)
    return out
