"""Analytic per-device cost model for the roofline analysis.

Why analytic: XLA's HloCostAnalysis does not scale ``while``-loop bodies by
trip count, so any scanned-layer or scanned-sequence computation (all our
models) is undercounted by ~n_layers x in ``compiled.cost_analysis()``;
textual HLO collective parsing has the same problem.  We therefore compute
FLOPs / HBM bytes / collective bytes per layer from first principles and
scale by layer counts; ``benchmarks/calibration.py`` validates the model
against *unrolled* 1-vs-2-layer compiles (where XLA counts correctly).
Peak memory still comes from the full compile (buffer assignment is
loop-aware).

All quantities are PER DEVICE per step.  Training FLOPs = fwd x (1 + 2 +
remat); serve = fwd.  SSM mixers are costed with the Pallas-kernel traffic
model (VMEM-resident state), not the materialized XLA reference.
"""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig, Segment

BF16 = 2
F32 = 4


def dataclasses_replace_local_fraction(plan, local_fraction: float):
    import dataclasses as _dc
    return _dc.replace(plan, local_fraction=local_fraction)


@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    B: int            # global batch
    S: int            # query seq len (1 for decode)
    K: int            # kv/context length
    dp: int           # data-parallel ways (pod*data)
    tp: int           # model-parallel ways
    kind: str         # train | prefill | decode

    @property
    def T(self) -> float:       # tokens per device
        return self.B * self.S / self.dp

    @property
    def fwd_mult(self) -> float:
        if self.kind != "train":
            return 1.0
        return 4.0 if self.cfg.remat == "full" else 3.0


def _mm(ctx: Ctx, d_in: float, d_out: float, tp_shard: bool = True):
    """One activation x weight matmul: returns (flops, act_bytes, w_bytes)."""
    tp = ctx.tp if tp_shard else 1
    flops = 2 * ctx.T * d_in * d_out / tp
    act = ctx.T * (d_in + d_out / tp) * BF16
    w = d_in * d_out / tp * BF16
    return flops, act, w


def _attn_core(ctx: Ctx, H: float, hd_qk: float, hd_v: float,
               causal: bool, window: int):
    """Score + context matmuls per device (heads sharded over tp)."""
    Keff = min(window, ctx.K) if window else ctx.K
    frac = 0.5 if (causal and ctx.S == ctx.K and not window) else 1.0
    flops = 2 * ctx.T * Keff * (hd_qk + hd_v) * (H / ctx.tp) * frac
    # bytes: read q/k/v + write out; kv cache read dominates decode
    kv_bytes = ctx.B / ctx.dp * Keff * (ctx.cfg.n_kv_heads or H) \
        * (hd_qk + hd_v) * BF16 / (ctx.tp if ctx.kind == "decode" else 1)
    act = ctx.T * H / ctx.tp * (hd_qk + hd_v) * BF16 + kv_bytes
    return flops, act


def _segment_layer_cost(ctx: Ctx, seg: Segment) -> dict:
    cfg = ctx.cfg
    D = cfg.d_model
    flops = act = wbytes = coll = 0.0

    def add(f, a, w):
        nonlocal flops, act, wbytes
        flops += f
        act += a
        wbytes += w

    # ---- mixers -------------------------------------------------------
    if seg.attn == "gqa" and seg.kind != "mamba":
        KV, hd = cfg.n_kv_heads, cfg.hd
        H = cfg.n_heads_padded or cfg.n_heads  # physical (padded) heads
        add(*_mm(ctx, D, H * hd, H % ctx.tp == 0))
        add(*_mm(ctx, D, 2 * KV * hd, KV % ctx.tp == 0))
        tp_eff = ctx.tp if H % ctx.tp == 0 else 1
        f, a = _attn_core(ctx, H, hd, hd, seg.causal, seg.sliding_window)
        flops += f * ctx.tp / tp_eff  # unsharded heads replicate core work
        act += a
        add(*_mm(ctx, H * hd, D, H % ctx.tp == 0))
        coll += ctx.T * D * BF16          # output all-reduce (TP)
    elif seg.attn == "mla":
        H = cfg.n_heads
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        nope, rp, vh = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                        cfg.v_head_dim)
        add(*_mm(ctx, D, qr, False))                   # wq_a (replicated)
        add(*_mm(ctx, qr, H * (nope + rp)))            # wq_b
        add(*_mm(ctx, D, kvr + rp, False))             # wkv_a
        if ctx.kind == "decode" and cfg.mla_absorb:
            # latent-space attention: q absorb + scores/ctx vs (K, kvr);
            # the latent cache is SHARED across heads (read once)
            flops += 2 * ctx.T * (H / ctx.tp) * nope * kvr      # absorb q
            flops += 2 * ctx.T * ctx.K * (H / ctx.tp) * (kvr + rp)  # scores
            flops += 2 * ctx.T * ctx.K * (H / ctx.tp) * kvr     # latent ctx
            act += ctx.B / ctx.dp * ctx.K * (kvr + rp) * BF16   # cache read
            flops += 2 * ctx.T * (H / ctx.tp) * kvr * vh        # un-absorb
        elif ctx.kind == "decode":
            # naive decode: re-expand EVERY cached latent each step
            rows = ctx.B / ctx.dp * ctx.K
            flops += 2 * rows * kvr * (H / ctx.tp) * (nope + vh)
            act += rows * (kvr + (H / ctx.tp) * (nope + vh)) * BF16
            f, a = _attn_core(ctx, H, nope + rp, vh, True, 0)
            flops += f
            act += a
        else:
            add(*_mm(ctx, kvr, H * (nope + vh)))       # expand latents
            f, a = _attn_core(ctx, H, nope + rp, vh, seg.causal, 0)
            flops += f
            act += a
        add(*_mm(ctx, H * vh, D))
        coll += ctx.T * D * BF16
    if seg.kind in ("mamba", "hybrid"):
        di, N, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
        add(*_mm(ctx, D, 2 * di))
        add(*_mm(ctx, di, r + 2 * N))
        add(*_mm(ctx, r, di))
        add(*_mm(ctx, di, D))
        # selective scan (Pallas traffic model): state stays in VMEM
        flops += 9 * ctx.T * (di / ctx.tp) * N
        act += ctx.T * (3 * di / ctx.tp + 2 * N) * BF16
        flops += 2 * ctx.T * (di / ctx.tp) * cfg.d_conv   # depthwise conv
        coll += ctx.T * D * BF16
    if seg.cross_attn:
        # one cross-attn layer per group: amortize over sub_layers
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        Nimg = cfg.n_image_tokens
        share = 1.0 / seg.sub_layers
        f1, a1, w1 = _mm(ctx, D, H * hd)
        flops += f1 * share
        act += a1 * share
        wbytes += w1 * share
        flops += 2 * ctx.T * Nimg * 2 * hd * (H / ctx.tp) * share
        act += ctx.B / ctx.dp * Nimg * KV * 2 * hd * BF16 * share

    # ---- FFN ----------------------------------------------------------
    if seg.kind == "moe":
        E, k, F = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
        add(*_mm(ctx, D, E, False))                    # router
        # cost exactly what the implementation runs: static-capacity buffers
        from ..models.moe import a2a_capacities, round_robin_plan
        import dataclasses as _dc
        plan = round_robin_plan(E, ctx.tp)
        if isinstance(cfg.expert_placement, float):
            plan = _dc.replace(plan, local_fraction=cfg.expert_placement)
        elif isinstance(cfg.expert_placement, tuple):
            lf, cf = cfg.expert_placement
            plan = _dc.replace(plan, local_fraction=lf, capacity_factor=cf)
        if ctx.kind == "decode":
            # tp path: one buffer over all local slots
            T_loc = ctx.B / ctx.dp * ctx.S
            rows = max(1, int(T_loc * k / plan.total_slots
                              * plan.capacity_factor * plan.n_shards)) \
                * plan.slots_per_shard
            coll += ctx.T * D * BF16                   # psum combine
        else:
            T_loc = max(1, int(ctx.B * ctx.S / ctx.dp / ctx.tp))
            cap_local, cap_send, cap_in = a2a_capacities(plan, T_loc, k)
            rows = plan.slots_per_shard * (cap_local + cap_in)
            # dispatch + return all_to_all buffers (bf16 payload)
            coll += 2 * plan.n_shards * cap_send * D * BF16
        flops += 3 * 2 * rows * D * F
        act += rows * (2 * D + F) * BF16
        wbytes += 3 * plan.slots_per_shard * D * F * BF16
        if cfg.n_shared_experts:
            add(*_mm(ctx, D, 3 * cfg.n_shared_experts * F))
    elif cfg.d_ff and seg.kind != "mamba":
        add(*_mm(ctx, D, cfg.d_ff))
        add(*_mm(ctx, D, cfg.d_ff))
        add(*_mm(ctx, cfg.d_ff, D))
        coll += ctx.T * D * BF16
    # norms
    act += 2 * ctx.T * D * BF16
    return {"flops": flops, "act_bytes": act, "w_bytes": wbytes,
            "coll_bytes": coll}


def step_cost(cfg: ModelConfig, B: int, S: int, K: int, dp: int, tp: int,
              kind: str) -> dict:
    """Total per-device cost for one step."""
    if cfg.strategy == "dp_seq":
        dp, tp = dp * tp, 1  # pure data(+sequence) parallelism
    ctx = Ctx(cfg, B, S, K, dp, tp, kind)
    flops = act = wbytes = coll = 0.0
    for seg in cfg.segments:
        c = _segment_layer_cost(ctx, seg)
        n = seg.n_layers * seg.sub_layers
        flops += c["flops"] * n
        act += c["act_bytes"] * n
        wbytes += c["w_bytes"] * n
        coll += c["coll_bytes"] * n
    # embed + head
    V, D = cfg.vocab, cfg.d_model
    flops += 2 * ctx.T * D * V / tp
    act += ctx.T * (D + V / tp) * BF16 + ctx.T * D * BF16
    wbytes += 2 * V * D / tp * BF16
    coll += ctx.T * D * BF16  # logits reduce
    if kind == "train" and cfg.mtp_depth:
        flops *= (1.0 + 0.03 * cfg.mtp_depth)  # one extra layer + head
    mult = ctx.fwd_mult
    flops *= mult
    act *= mult
    coll_bwd = 2.0 if kind == "train" else 1.0
    coll *= coll_bwd
    if kind == "train":
        # gradient reduction over dp + optimizer update traffic
        n_params_dev = cfg.param_count() / tp
        if "ep_data" in cfg.strategy and cfg.n_experts:
            # expert weights also sharded over dp
            expert = (sum(s.n_layers for s in cfg.segments if s.kind == "moe")
                      * 3 * cfg.n_experts * cfg.d_model * cfg.moe_d_ff)
            n_params_dev -= expert / tp * (1 - 1.0 / dp)
        if dp > 1:
            if cfg.zero_opt_state:
                # ZeRO: bf16 reduce-scatter only (each rank owns a shard)
                coll += n_params_dev * BF16 * (dp - 1) / dp
            else:
                # bf16 ring all-reduce (grads are in the param dtype)
                coll += n_params_dev * BF16 * 2 * (dp - 1) / dp
        opt_div = dp if cfg.zero_opt_state else 1
        wbytes += n_params_dev * (BF16 + F32 * 3) * 2 / opt_div
    return {"flops": flops, "hbm_bytes": act + wbytes, "coll_bytes": coll,
            "act_bytes": act, "w_bytes": wbytes}
