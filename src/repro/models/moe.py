"""Mixture-of-Experts layer with replication-aware expert placement.

This is where the paper's contribution lands in the runtime.  The paper's
moe-8 benchmark *is* expert co-activation partitioning for serving (§3.2):
hyperedges = frequently co-invoked expert 8-tuples, processors = devices,
and replication lets hot experts live on several devices so tokens reach
all their experts with fewer cross-device hops (the (lambda_e - 1) metric).

TPU adaptation (DESIGN.md §3): experts are sharded over the 'model' mesh
axis ("EP shards").  A ``PlacementPlan`` maps physical *slots* (shard,
slot) -> expert; replication = an expert occupying slots on several shards.

  * training / prefill (`mode='a2a'`): tokens are sequence-sharded over the
    model axis; each token-choice either hits a *local* replica (free) or
    is sent through a static-capacity all_to_all.  Replication-aware
    placement raises the local fraction, which statically shrinks the
    all_to_all buffers -- the communication saving of the paper, visible in
    HLO collective bytes.
  * decode (`mode='tp'`): tokens are replicated across the model axis; each
    shard computes its slots and results are psum-combined.
  * no mesh: dense single-device reference.

Dispatch is sort-based (argsort by slot + static-capacity buffers), not
one-hot einsum: at E=256 the (T,E,C) dispatch matmuls would dwarf the
expert FLOPs.  Training uses the no-replication plan (replicated slots
would need gradient tying); serving transforms weights into the replicated
slot layout (`materialize_slots`) -- mirroring the paper's decode-phase
setting (§B.1).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..parallel.sharding import active_mesh, batch_axes, shard_map
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Static expert->device placement with replication."""
    n_experts: int
    n_shards: int
    slots_per_shard: int
    slot_expert: tuple   # (n_shards, slots_per_shard); -1 = empty slot
    local_slot: tuple    # (n_shards, n_experts): local slot id or -1
    home_shard: tuple    # (n_shards, n_experts): dest shard when remote
    home_slot: tuple     # (n_shards, n_experts): slot id on dest shard
    local_fraction: float
    capacity_factor: float = 1.25

    def arrays(self):
        return (np.array(self.slot_expert, np.int32),
                np.array(self.local_slot, np.int32),
                np.array(self.home_shard, np.int32),
                np.array(self.home_slot, np.int32))

    @property
    def total_slots(self) -> int:
        return self.n_shards * self.slots_per_shard

    def replicas(self, e: int) -> int:
        return int(sum(1 for row in self.local_slot if row[e] >= 0))


def _finalize_plan(shard_slots, n_experts, n_shards, expert_freq,
                   capacity_factor):
    sps = max(len(s) for s in shard_slots)
    slot_expert = -np.ones((n_shards, sps), np.int64)
    local_slot = -np.ones((n_shards, n_experts), np.int64)
    for p, slots in enumerate(shard_slots):
        for i, e in enumerate(slots):
            slot_expert[p, i] = e
            local_slot[p, e] = i
    home_shard = np.zeros((n_shards, n_experts), np.int64)
    home_slot = np.zeros((n_shards, n_experts), np.int64)
    for e in range(n_experts):
        replicas = [p for p in range(n_shards) if local_slot[p, e] >= 0]
        if not replicas:
            raise ValueError(f"expert {e} unplaced")
        for m in range(n_shards):
            best = min(replicas, key=lambda r: min((r - m) % n_shards,
                                                   (m - r) % n_shards))
            home_shard[m, e] = best
            home_slot[m, e] = local_slot[best, e]
    freq = np.ones(n_experts) if expert_freq is None else np.asarray(
        expert_freq, np.float64)
    freq = freq / max(freq.sum(), 1e-9)
    local_fraction = float(sum(
        freq[e] * (np.sum(local_slot[:, e] >= 0) / n_shards)
        for e in range(n_experts)))
    return PlacementPlan(
        n_experts=n_experts, n_shards=n_shards, slots_per_shard=sps,
        slot_expert=tuple(map(tuple, slot_expert.tolist())),
        local_slot=tuple(map(tuple, local_slot.tolist())),
        home_shard=tuple(map(tuple, home_shard.tolist())),
        home_slot=tuple(map(tuple, home_slot.tolist())),
        local_fraction=local_fraction,
        capacity_factor=capacity_factor,
    )


def round_robin_plan(n_experts: int, n_shards: int,
                     capacity_factor: float = 1.25) -> PlacementPlan:
    """No replication: expert e on shard e % n_shards (the baseline)."""
    shard_slots = [[] for _ in range(n_shards)]
    for e in range(n_experts):
        shard_slots[e % n_shards].append(e)
    return _finalize_plan(shard_slots, n_experts, n_shards, None,
                          capacity_factor)


def plan_from_masks(masks, n_experts: int, n_shards: int,
                    expert_freq=None,
                    capacity_factor: float = 1.25) -> PlacementPlan:
    """Plan from partitioner output ``masks`` (bit p of masks[e] = replica
    of expert e on shard p) -- the solution of hypergraph partitioning with
    replication on the co-activation hypergraph."""
    shard_slots = [[] for _ in range(n_shards)]
    for e in range(n_experts):
        m = int(masks[e])
        for p in range(n_shards):
            if (m >> p) & 1:
                shard_slots[p].append(e)
    return _finalize_plan(shard_slots, n_experts, n_shards, expert_freq,
                          capacity_factor)


def a2a_capacities(plan: PlacementPlan, T_loc: int, top_k: int):
    """Static buffer capacities of the a2a path (shared with the roofline
    cost model so analysis costs exactly what the implementation runs)."""
    n_sh = plan.n_shards
    loc_frac = max(plan.local_fraction, 1.0 / n_sh)
    cap_local = max(1, int(np.ceil(
        T_loc * top_k * loc_frac / plan.slots_per_shard
        * plan.capacity_factor * 2)))
    cap_send = max(1, int(np.ceil(
        T_loc * top_k * (1.0 - loc_frac) / n_sh * plan.capacity_factor)))
    cap_in = max(1, int(np.ceil(
        n_sh * cap_send / plan.slots_per_shard * 2)))
    return cap_local, cap_send, cap_in


# ------------------------------------------------------------------ routing

def router_topk(router_w, x: jax.Array, cfg: ModelConfig):
    """x: (T, D) -> weights (T, k), experts (T, k), aux loss scalar."""
    logits = jnp.einsum("td,de->te", x, router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)
    return w.astype(x.dtype), idx, aux


def sort_dispatch(xt: jax.Array, slot_ids: jax.Array, keep: jax.Array,
                  n_slots: int, capacity: int):
    """Static-shape sparse dispatch.

    xt: (T, D); slot_ids/keep: (T, k).  Returns
      xin:      (n_slots, capacity, D)  tokens grouped per slot (drops over
                                        capacity, standard MoE semantics)
      buf_of:   (T, k) int32            buffer row of each choice, or -1
    """
    T, k = slot_ids.shape
    D = xt.shape[-1]
    flat = jnp.where(keep, slot_ids, n_slots).reshape(-1)       # (T*k,)
    order = jnp.argsort(flat, stable=True)
    sorted_slot = flat[order]
    starts = jnp.searchsorted(sorted_slot, jnp.arange(n_slots + 1),
                              side="left")
    pos = jnp.arange(T * k) - starts[jnp.clip(sorted_slot, 0, n_slots)]
    ok = (sorted_slot < n_slots) & (pos < capacity)
    buf_sorted = jnp.where(ok, sorted_slot * capacity + pos,
                           n_slots * capacity)                  # dump row
    # invert the permutation to index by original (t, k)
    buf_flat = jnp.zeros(T * k, jnp.int32).at[order].set(
        buf_sorted.astype(jnp.int32))
    token_of_row = jnp.full(n_slots * capacity + 1, T, jnp.int32)
    token_of_row = token_of_row.at[buf_sorted].set(
        (order // k).astype(jnp.int32), mode="drop")
    xin = jnp.take(jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], 0),
                   jnp.minimum(token_of_row[:-1], T), axis=0)
    xin = jnp.where((token_of_row[:-1] < T)[:, None], xin, 0)
    xin = xin.reshape(n_slots, capacity, D)
    buf_of = jnp.where(buf_flat < n_slots * capacity, buf_flat, -1)
    return xin, buf_of.reshape(T, k)


def combine_from_buffers(yout_flat: jax.Array, buf_of: jax.Array,
                         w: jax.Array) -> jax.Array:
    """yout_flat: (rows, D); buf_of: (T,k) row ids (-1 dropped); w: (T,k)."""
    D = yout_flat.shape[-1]
    pad = jnp.concatenate([yout_flat, jnp.zeros((1, D), yout_flat.dtype)], 0)
    gathered = pad[jnp.where(buf_of >= 0, buf_of, pad.shape[0] - 1)]  # (T,k,D)
    gathered = jnp.where((buf_of >= 0)[..., None], gathered, 0)
    return jnp.einsum("tkd,tk->td", gathered, w)


def _expert_ffn(e_gate, e_up, e_down, xin: jax.Array) -> jax.Array:
    """xin: (n_slots, C, D) -> (n_slots, C, D) through per-slot SwiGLU."""
    g = jnp.einsum("scd,sdf->scf", xin, e_gate)
    u = jnp.einsum("scd,sdf->scf", xin, e_up)
    return jnp.einsum("scf,sfd->scd", jax.nn.silu(g) * u, e_down)


# ---------------------------------------------------------------- execution

def moe_dense_ref(p: dict, x: jax.Array, cfg: ModelConfig):
    """Single-device reference: dense top-k MoE (tests / tiny configs)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    w, idx, aux = router_topk(p["router"], xt, cfg)
    g = jnp.einsum("td,edf->tef", xt, p["e_gate"])
    u = jnp.einsum("td,edf->tef", xt, p["e_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, p["e_down"])
    oh = jax.nn.one_hot(idx, cfg.n_experts, dtype=x.dtype)
    gates = jnp.einsum("tk,tke->te", w, oh)
    out = jnp.einsum("ted,te->td", y, gates)
    if "w_gate" in p:
        from .layers import swiglu
        out = out + swiglu(p, x).reshape(-1, D)
    return out.reshape(B, S, D), aux


def moe_tp(p: dict, x: jax.Array, cfg: ModelConfig, plan: PlacementPlan):
    """Tokens replicated over the model axis; each shard computes its
    slots; psum combine.  Used for decode (tiny token counts)."""
    mesh = active_mesh()
    B, S, D = x.shape
    _, local_slot, _, _ = plan.arrays()
    dp = batch_axes()
    all_axes = tuple(dp) + ("model",)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    B_loc = B // dp_size
    T_loc = B_loc * S
    cap = max(1, int(np.ceil(T_loc * cfg.top_k / plan.total_slots
                             * plan.capacity_factor * plan.n_shards)))

    def per_shard(xl, e_gate, e_up, e_down, router):
        m = jax.lax.axis_index("model")
        xt = xl.reshape(-1, D)
        w, idx, aux = router_topk(router, xt, cfg)
        slots = jnp.asarray(local_slot)[m][idx]
        keep = slots >= 0
        xin, buf_of = sort_dispatch(xt, jnp.maximum(slots, 0), keep,
                                    plan.slots_per_shard, cap)
        yout = _expert_ffn(e_gate, e_up, e_down, xin)
        y = combine_from_buffers(yout.reshape(-1, D), buf_of, w)
        y = jax.lax.psum(y, "model")
        if dp:  # aux is invariant over 'model' here (tokens replicated)
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(B_loc, S, D), aux

    y, aux = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(dp or None, None, None), P("model"), P("model"),
                  P("model"), P()),
        out_specs=(P(dp or None, None, None), P()),
    )(x, p["e_gate_slots"], p["e_up_slots"], p["e_down_slots"], p["router"])
    if "w_gate" in p:
        from .layers import swiglu
        y = y + swiglu(p, x)
    return y, aux


def moe_a2a(p: dict, x: jax.Array, cfg: ModelConfig, plan: PlacementPlan):
    """Sequence-sharded tokens + static-capacity all_to_all dispatch.
    Local replicas bypass the all_to_all entirely: the plan's expected
    locality statically sizes (shrinks) the communication buffers."""
    mesh = active_mesh()
    B, S, D = x.shape
    _, local_slot, home_shard, home_slot = plan.arrays()
    n_sh = plan.n_shards
    dp = batch_axes()
    all_axes = tuple(dp) + ("model",)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    B_loc, S_loc = B // dp_size, S // n_sh
    T_loc = B_loc * S_loc
    cap_local, cap_send, cap_in = a2a_capacities(plan, T_loc, cfg.top_k)

    def per_shard(xl, e_gate, e_up, e_down, router):
        m = jax.lax.axis_index("model")
        xt = xl.reshape(-1, D)
        w, idx, aux = router_topk(router, xt, cfg)
        my_local = jnp.asarray(local_slot)[m][idx]        # (T,k)
        is_local = my_local >= 0
        # ---- local replicas: no communication (the replication win) ----
        xin_l, buf_l = sort_dispatch(xt, jnp.maximum(my_local, 0), is_local,
                                     plan.slots_per_shard, cap_local)
        # ---- remote dispatch through all_to_all ----
        dest = jnp.asarray(home_shard)[m][idx]
        dslot = jnp.asarray(home_slot)[m][idx]
        send_x, buf_r = sort_dispatch(xt, dest, ~is_local, n_sh, cap_send)
        # ship each row's target slot id alongside (int payload)
        slot_payload = jnp.full((n_sh * cap_send,), -1, jnp.int32)
        slot_payload = slot_payload.at[
            jnp.where(buf_r >= 0, buf_r, n_sh * cap_send).reshape(-1)
        ].set(dslot.reshape(-1).astype(jnp.int32), mode="drop")
        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0)
        recv_slot = jax.lax.all_to_all(
            slot_payload.reshape(n_sh, cap_send, 1), "model", 0, 0)[..., 0]
        rx = recv_x.reshape(-1, D)
        rslot = recv_slot.reshape(-1)
        xin_r, buf_in = sort_dispatch(rx, jnp.maximum(rslot, 0)[:, None],
                                      (rslot >= 0)[:, None],
                                      plan.slots_per_shard, cap_in)
        # ---- expert FFN ----
        yout_l = _expert_ffn(e_gate, e_up, e_down, xin_l)
        yout_r = _expert_ffn(e_gate, e_up, e_down, xin_r)
        # ---- combine: local directly, remote via return all_to_all ----
        y = combine_from_buffers(yout_l.reshape(-1, D), buf_l, w * is_local)
        ret = combine_from_buffers(
            yout_r.reshape(-1, D), buf_in,
            jnp.ones_like(buf_in, dtype=xt.dtype))          # (n_sh*cap_send, D)
        ret = jax.lax.all_to_all(ret.reshape(n_sh, cap_send, D), "model", 0, 0)
        y = y + combine_from_buffers(ret.reshape(-1, D), buf_r,
                                     w * (~is_local))
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(B_loc, S_loc, D), aux

    y, aux = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(dp or None, "model", None), P("model"), P("model"),
                  P("model"), P()),
        out_specs=(P(dp or None, "model", None), P()),
    )(x, p["e_gate_slots"], p["e_up_slots"], p["e_down_slots"], p["router"])
    if "w_gate" in p:
        from .layers import swiglu
        y = y + swiglu(p, x)
    return y, aux


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig, plan: PlacementPlan,
              mode: str):
    """mode: 'a2a' (train/prefill), 'tp' (decode), 'dense' (no mesh)."""
    if active_mesh() is None or mode == "dense":
        return moe_dense_ref(p, x, cfg)
    p = materialize_slots(p, plan)
    if mode == "tp":
        return moe_tp(p, x, cfg, plan)
    return moe_a2a(p, x, cfg, plan)


def materialize_slots(p: dict, plan: PlacementPlan) -> dict:
    """Gather logical expert weights (..., E, D, F) into the physical slot
    layout (..., n_shards*slots_per_shard, D, F).  Differentiable (training
    gradients of replicated slots sum back into the logical expert)."""
    if "e_gate_slots" in p:
        return p
    slot_expert = np.array(plan.slot_expert, np.int64).reshape(-1)
    gather = np.maximum(slot_expert, 0)

    def take(wname):
        return jnp.take(p[wname], jnp.asarray(gather), axis=-3)

    out = dict(p)
    out["e_gate_slots"] = take("e_gate")
    out["e_up_slots"] = take("e_up")
    out["e_down_slots"] = take("e_down")
    return out
