"""Unified model configuration covering all assigned architectures.

A model is a list of *segments*; each segment is a homogeneous stack of
layers executed with ``jax.lax.scan`` over stacked parameters (so HLO size
is independent of depth -- essential for 512-device dry-run compiles).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str          # 'dense' | 'moe' | 'mamba' | 'hybrid' | 'vision_group'
    n_layers: int      # number of (stacked, scanned) layers in this segment
    # attention flavour inside the segment
    attn: str = "gqa"  # 'gqa' | 'mla' | 'none'
    causal: bool = True
    sliding_window: int = 0      # 0 = full attention
    cross_attn: bool = False     # vision_group: 1 cross + (sub_layers-1) self
    sub_layers: int = 1          # for vision_group: layers per scanned block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: tuple[Segment, ...]
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.01

    # --- MLA (deepseek-v3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = True  # absorbed-weight decode (latent-space attention)

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    d_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)

    # --- multimodal stubs ---
    frame_input: bool = False    # audio: inputs are (B,S,d_model) embeddings
    n_image_tokens: int = 0      # vlm: stub patch embeddings (B,N,d_model)

    # --- multi-token prediction (deepseek-v3) ---
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.1

    # --- parallelism / perf knobs ---
    strategy: str = "tp"         # 'tp' | 'dp_seq' | 'tp+ep_data'
    n_heads_padded: int = 0      # pad q heads per kv group so H divides tp
    remat: str = "full"          # 'none' | 'full' | 'dots'
    zero_opt_state: bool = False # shard Adam moments over the data axis too
    seq_shard_activations: bool = False  # sequence parallelism on residual stream

    # expert placement plan (paper technique); set via with_placement()
    expert_placement: tuple | None = None  # tuple of tuples: replicas per expert

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers * s.sub_layers for s in self.segments)

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------ counting
    def param_count(self) -> int:
        """Exact parameter count (used for MODEL_FLOPS = 6*N*D)."""
        D, V = self.d_model, self.vocab
        total = V * D  # embed
        if not self.tie_embeddings:
            total += D * V  # head
        total += D  # final norm
        for seg in self.segments:
            total += seg.n_layers * self._layer_params(seg)
        if self.mtp_depth:
            total += self.mtp_depth * (2 * D * D + self._layer_params(
                Segment("dense", 1)) + D)
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k)."""
        if not self.n_experts:
            return self.param_count()
        D = self.d_model
        dead_per_layer = (self.n_experts - self.top_k) * 3 * D * self.moe_d_ff
        n_moe_layers = sum(s.n_layers for s in self.segments if s.kind == "moe")
        return self.param_count() - n_moe_layers * dead_per_layer

    def _attn_params(self, attn: str) -> int:
        D = self.d_model
        if attn == "none":
            return 0
        if attn == "mla":
            qk_hd = self.qk_nope_head_dim + self.qk_rope_head_dim
            p = D * self.q_lora_rank + self.q_lora_rank  # wq_a + norm
            p += self.q_lora_rank * self.n_heads * qk_hd  # wq_b
            p += D * (self.kv_lora_rank + self.qk_rope_head_dim)  # wkv_a
            p += self.kv_lora_rank  # norm
            p += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim
                                                     + self.v_head_dim)  # wkv_b
            p += self.n_heads * self.v_head_dim * D  # wo
            return p
        hd = self.hd
        return (D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd
                + self.n_heads * hd * D)

    def _mamba_params(self) -> int:
        D, di, st = self.d_model, self.d_inner, self.ssm_state
        r = self.dt_rank_
        return (D * 2 * di + di * self.d_conv + di * st + di  # in,conv,A,D
                + di * (r + 2 * st) + r * di + di * D)        # x_proj,dt,out

    def _layer_params(self, seg: Segment) -> int:
        D = self.d_model
        p = 2 * D  # two norms
        if seg.kind == "mamba":
            return D + self._mamba_params()  # single norm + mixer
        if seg.kind == "hybrid":
            p += self._attn_params(seg.attn) + self._mamba_params()
        elif seg.kind == "vision_group":
            # one cross-attn layer + (sub_layers-1) self-attn layers
            cross = (2 * D + self._attn_params("gqa") + 1  # gate
                     + 2 * D + 3 * D * self.d_ff)
            self_l = 2 * D + self._attn_params(seg.attn) + 3 * D * self.d_ff
            return cross + (seg.sub_layers - 1) * self_l
        else:
            p += self._attn_params(seg.attn)
        if seg.kind == "moe":
            p += D * self.n_experts  # router
            p += self.n_experts * 3 * D * self.moe_d_ff
            p += self.n_shared_experts * 3 * D * self.moe_d_ff
        elif seg.kind in ("dense", "hybrid"):
            p += 3 * D * self.d_ff if self.d_ff else 0
        return p
