"""Transformer / SSM layer implementations (pure functions over param trees).

Every block comes in two entry points:
  * ``*_block(params, x, cfg, seg)``            -- train / prefill (full seq)
  * ``*_block_decode(params, x, cfg, seg, cache, pos)`` -- one-token decode

Caches are functional (returned updated).  Attention math is routed through
``repro.kernels.ops`` (Pallas on TPU, jnp reference elsewhere).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..parallel.sharding import batch_axes, constrain
from .config import ModelConfig, Segment


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w).astype(x.dtype)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, hd); pos: (B, S) absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------- attention

def _positions(B: int, S: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def n_q_heads(cfg: ModelConfig) -> int:
    """Physical q-head count (optionally padded per-kv-group so the head
    dim divides the model axis; pad heads are masked to zero)."""
    return cfg.n_heads_padded or cfg.n_heads


def head_mask(cfg: ModelConfig, dtype) -> jax.Array | None:
    Hp = n_q_heads(cfg)
    if Hp == cfg.n_heads:
        return None
    g_pad = Hp // cfg.n_kv_heads
    g_real = cfg.n_heads // cfg.n_kv_heads
    mask = (jnp.arange(Hp) % g_pad) < g_real
    return mask.astype(dtype)[None, None, :, None]


def gqa_project(p: dict, x: jax.Array, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.hd
    Hp = n_q_heads(cfg)
    q = jnp.einsum("bsd,dn->bsn", x, p["wq"]).reshape(B, S, Hp, hd)
    k = jnp.einsum("bsd,dn->bsn", x, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dn->bsn", x, p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def gqa_attention(p: dict, x: jax.Array, cfg: ModelConfig, seg: Segment):
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = gqa_project(p, x, cfg)
    pos = _positions(B, S)
    q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
    out = ops.attention(q, k, v, causal=seg.causal,
                        window=seg.sliding_window)
    hm = head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm
    out = out.reshape(B, S, n_q_heads(cfg) * cfg.hd)
    return jnp.einsum("bsn,nd->bsd", out, p["wo"])


def gqa_init_cache(cfg: ModelConfig, seg: Segment, B: int, max_len: int,
                   dtype) -> dict:
    L = max_len if not seg.sliding_window else min(seg.sliding_window, max_len)
    return {
        "k": jnp.zeros((B, L, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((B, L, cfg.n_kv_heads, cfg.hd), dtype),
    }


def gqa_prefill_cache(p, x, cfg: ModelConfig, seg: Segment, max_len: int):
    """Build the decode cache from a prefilled sequence."""
    B, S, _ = x.shape
    _, k, v = gqa_project(p, x, cfg)
    pos = _positions(B, S)
    k = rope(k, pos, cfg.rope_theta)
    if seg.sliding_window:
        W = min(seg.sliding_window, max_len)
        pad = max(0, W - S)

        def fit(t):  # ring semantics: keep the last W, left-pad if short
            return (t[:, -W:] if S >= W
                    else jnp.pad(t, ((0, 0), (pad, 0), (0, 0), (0, 0))))
    else:
        def fit(t):  # linear cache: position i lives at index i
            return jnp.pad(t, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))
    return {"k": fit(k), "v": fit(v)}


def gqa_attention_decode(p: dict, x: jax.Array, cfg: ModelConfig, seg: Segment,
                         cache: dict, pos: jax.Array):
    """x: (B, 1, D); pos: scalar int32 -- index of the new token."""
    B = x.shape[0]
    q, k_new, v_new = gqa_project(p, x, cfg)
    pos_b = jnp.broadcast_to(pos[None, None], (B, 1))
    q = rope(q, pos_b, cfg.rope_theta)
    k_new = rope(k_new, pos_b, cfg.rope_theta)
    if seg.sliding_window:
        W = cache["k"].shape[1]
        k = jnp.concatenate([cache["k"][:, 1:], k_new], axis=1)
        v = jnp.concatenate([cache["v"][:, 1:], v_new], axis=1)
        k_pos = pos - W + 1 + jnp.arange(W)
        k_pos = jnp.broadcast_to(k_pos[None], (B, W))
        new_cache = {"k": k, "v": v}
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1)
        k_pos = jnp.broadcast_to(jnp.arange(k.shape[1])[None], (B, k.shape[1]))
        new_cache = {"k": k, "v": v}
    out = ops.attention(q, k, v, causal=True, window=0,
                        q_pos=pos_b, k_pos=k_pos)
    hm = head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm
    out = out.reshape(B, 1, n_q_heads(cfg) * cfg.hd)
    return jnp.einsum("bsn,nd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------- MLA

def _mla_dims(cfg: ModelConfig):
    return (cfg.q_lora_rank, cfg.kv_lora_rank, cfg.qk_nope_head_dim,
            cfg.qk_rope_head_dim, cfg.v_head_dim)


def mla_project_q(p, x, cfg):
    B, S, _ = x.shape
    qr, kvr, nope, rp, vh = _mla_dims(cfg)
    ql = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rn->bsn", ql, p["wq_b"])
    q = q.reshape(B, S, cfg.n_heads, nope + rp)
    return q[..., :nope], q[..., nope:]


def mla_latent(p, x, cfg):
    """Compressed kv latent + decoupled rope key (the cached quantities)."""
    kvr, rp = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv = rmsnorm(kv[..., :kvr], p["kv_ln"], cfg.norm_eps)
    k_rope = kv[..., kvr:]
    return ckv, k_rope


def mla_attention(p: dict, x: jax.Array, cfg: ModelConfig, seg: Segment):
    B, S, _ = x.shape
    qr, kvr, nope, rp, vh = _mla_dims(cfg)
    H = cfg.n_heads
    q_nope, q_rope = mla_project_q(p, x, cfg)
    ckv, k_rope = mla_latent(p, x, cfg)
    pos = _positions(B, S)
    q_rope = rope(q_rope, pos, cfg.rope_theta)
    k_rope = rope(k_rope[:, :, None, :], pos, cfg.rope_theta)  # single head
    kv = jnp.einsum("bsr,rn->bsn", ckv, p["wkv_b"]).reshape(B, S, H, nope + vh)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rp))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = (nope + rp) ** -0.5
    out = ops.attention(q, k, v, causal=seg.causal, scale=scale)
    out = out.reshape(B, S, H * vh)
    return jnp.einsum("bsn,nd->bsd", out, p["mla_wo"])


def mla_init_cache(cfg: ModelConfig, B: int, max_len: int, dtype) -> dict:
    return {
        "ckv": jnp.zeros((B, max_len, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((B, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_prefill_cache(p, x, cfg: ModelConfig, max_len: int):
    B, S, _ = x.shape
    ckv, k_rope = mla_latent(p, x, cfg)
    pos = _positions(B, S)
    k_rope = rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    pad = max_len - S
    return {
        "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
        "kr": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
    }


def mla_attention_decode(p, x, cfg: ModelConfig, cache: dict, pos: jax.Array,
                         absorb: bool = True):
    B = x.shape[0]
    qr, kvr, nope, rp, vh = _mla_dims(cfg)
    H = cfg.n_heads
    q_nope, q_rope = mla_project_q(p, x, cfg)  # (B,1,H,*)
    pos_b = jnp.broadcast_to(pos[None, None], (B, 1))
    q_rope = rope(q_rope, pos_b, cfg.rope_theta)
    ckv_new, kr_new = mla_latent(p, x, cfg)
    kr_new = rope(kr_new[:, :, None, :], pos_b, cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new, pos, axis=1)
    new_cache = {"ckv": ckv, "kr": kr}
    Sk = ckv.shape[1]
    mask = (jnp.arange(Sk)[None] <= pos)[:, None, None, :]  # (1,1,1,Sk)
    scale = (nope + rp) ** -0.5
    wkv_b = p["wkv_b"].reshape(kvr, H, nope + vh)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    if absorb:
        # fold W_UK into the query, attend in latent space (decode-optimal)
        q_eff = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)      # (B,1,H,kvr)
        s = jnp.einsum("bqhr,bsr->bhqs", q_eff, ckv,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhr,bsr->bhqs", q_rope, kr,
                        preferred_element_type=jnp.float32)
        s = jnp.where(mask, s * scale, -1e30)
        pattn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhqs,bsr->bqhr", pattn, ckv)          # latent ctx
        out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)
    else:
        # naive: expand every cached latent to full K/V each step
        kv = jnp.einsum("bsr,rn->bsn", ckv, p["wkv_b"]).reshape(B, Sk, H, nope + vh)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, Sk, H, rp))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        s = jnp.einsum("bqhn,bshn->bhqs", q, k,
                       preferred_element_type=jnp.float32)
        s = jnp.where(mask, s * scale, -1e30)
        pattn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshv->bqhv", pattn, v)
    out = out.reshape(B, 1, H * vh)
    return jnp.einsum("bsn,nd->bsd", out, p["mla_wo"]), new_cache


# ---------------------------------------------------------------- cross-attn

def cross_attention(p: dict, x: jax.Array, img: jax.Array, cfg: ModelConfig):
    """Text queries attend to (stub) image embeddings; tanh-gated residual."""
    B, S, _ = x.shape
    N = img.shape[1]
    hd = cfg.hd
    q = jnp.einsum("bsd,dn->bsn", x, p["cross_wq"]).reshape(B, S, cfg.n_heads, hd)
    k = jnp.einsum("bnd,dm->bnm", img, p["cross_wk"]).reshape(B, N, cfg.n_kv_heads, hd)
    v = jnp.einsum("bnd,dm->bnm", img, p["cross_wv"]).reshape(B, N, cfg.n_kv_heads, hd)
    out = ops.attention(q, k, v, causal=False)
    out = out.reshape(B, S, cfg.n_heads * hd)
    out = jnp.einsum("bsn,nd->bsd", out, p["cross_wo"])
    return jnp.tanh(p["gate"]).astype(out.dtype) * out


# --------------------------------------------------------------------- mamba

def mamba_mixer(p: dict, x: jax.Array, cfg: ModelConfig,
                state: dict | None = None):
    """Mamba1 mixer.  x: (B, S, D).  state: {'conv': (B, d_conv-1, di),
    'ssm': (B, di, N)} for stepwise decode (S==1)."""
    B, S, _ = x.shape
    di, N, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    xz = jnp.einsum("bsd,dn->bsn", x, p["in_proj"])
    u, z = xz[..., :di], xz[..., di:]
    # depthwise causal conv along S
    if state is None:
        u_pad = jnp.pad(u, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))
        new_conv = u_pad[:, -(cfg.d_conv - 1):, :] if cfg.d_conv > 1 else None
    else:
        u_pad = jnp.concatenate([state["conv"], u], axis=1)
        new_conv = u_pad[:, -(cfg.d_conv - 1):, :]
    idx = jnp.arange(S)[:, None] + jnp.arange(cfg.d_conv)[None, :]
    windows = u_pad[:, idx, :]                      # (B, S, d_conv, di)
    u_conv = jnp.einsum("bskn,kn->bsn", windows, p["conv_w"]) + p["conv_b"]
    u_conv = jax.nn.silu(u_conv)
    # input-dependent SSM parameters
    xproj = jnp.einsum("bsn,nm->bsm", u_conv, p["x_proj"])
    dt = jax.nn.softplus(jnp.einsum("bsr,rn->bsn", xproj[..., :r],
                                    p["dt_proj"]) + p["dt_bias"])
    Bc, Cc = xproj[..., r:r + N], xproj[..., r + N:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    init = state["ssm"] if state is not None else None
    y, last = ops.mamba_scan(u_conv, dt, A, Bc, Cc, p["ssm_D"], init_state=init)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsn,nd->bsd", y, p["out_proj"])
    new_state = {"conv": new_conv, "ssm": last}
    return out, new_state


def mamba_init_cache(cfg: ModelConfig, B: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((B, cfg.d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
