"""Unified model: segmented scan over homogeneous layer stacks.

Supports every assigned architecture family: dense GQA decoders, MLA+MoE
(deepseek-v3 incl. MTP), encoder-only (hubert), cross-attention VLM groups
(llama-3.2-vision), mamba1 (falcon-mamba) and parallel attention+SSM hybrid
(hymba).  Three entry points per model: ``loss`` (train), ``prefill`` and
``decode_step`` (serve).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import batch_axes, constrain
from . import layers as L
from .config import ModelConfig, Segment
from .moe import (PlacementPlan, moe_apply, round_robin_plan)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _init(rng, shape, scale_dim, dtype):
    return (jax.random.normal(rng, shape, jnp.float32)
            * (scale_dim ** -0.5)).astype(dtype)


class Model:
    def __init__(self, cfg: ModelConfig, n_ep_shards: int = 1,
                 plan: PlacementPlan | None = None):
        self.cfg = cfg
        self.plan = plan
        if cfg.n_experts and plan is None:
            self.plan = round_robin_plan(cfg.n_experts, n_ep_shards)

    # ------------------------------------------------------------- params
    def init(self, rng) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        D, V = cfg.d_model, cfg.vocab
        keys = jax.random.split(rng, 8 + len(cfg.segments))
        params: dict = {"embed": _init(keys[0], (V, D), D, dt),
                        "final_ln": jnp.ones((D,), jnp.float32)}
        if not cfg.tie_embeddings:
            params["lm_head"] = _init(keys[1], (D, V), D, dt)
        params["segments"] = [self._init_segment(keys[2 + i], seg)
                              for i, seg in enumerate(cfg.segments)]
        if cfg.mtp_depth:
            k = jax.random.split(keys[-1], cfg.mtp_depth)
            params["mtp"] = [self._init_mtp(k[i]) for i in range(cfg.mtp_depth)]
        return params

    def _init_attn(self, rng, seg_kind_attn: str, n: int) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        ks = jax.random.split(rng, 8)
        if seg_kind_attn == "mla":
            qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
            nope, rp, vh = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                            cfg.v_head_dim)
            return {
                "wq_a": _init(ks[0], (n, D, qr), D, dt),
                "q_ln": jnp.ones((n, qr), jnp.float32),
                "wq_b": _init(ks[1], (n, qr, H * (nope + rp)), qr, dt),
                "wkv_a": _init(ks[2], (n, D, kvr + rp), D, dt),
                "kv_ln": jnp.ones((n, kvr), jnp.float32),
                "wkv_b": _init(ks[3], (n, kvr, H * (nope + vh)), kvr, dt),
                "mla_wo": _init(ks[4], (n, H * vh, D), H * vh, dt),
            }
        Hp = cfg.n_heads_padded or H
        return {
            "wq": _init(ks[0], (n, D, Hp * hd), D, dt),
            "wk": _init(ks[1], (n, D, KV * hd), D, dt),
            "wv": _init(ks[2], (n, D, KV * hd), D, dt),
            "wo": _init(ks[3], (n, Hp * hd, D), Hp * hd, dt),
        }

    def _init_mlp(self, rng, n: int, d_ff: int) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        D = cfg.d_model
        ks = jax.random.split(rng, 3)
        return {
            "w_gate": _init(ks[0], (n, D, d_ff), D, dt),
            "w_up": _init(ks[1], (n, D, d_ff), D, dt),
            "w_down": _init(ks[2], (n, d_ff, D), d_ff, dt),
        }

    def _init_mamba(self, rng, n: int) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        D, di, N, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
        ks = jax.random.split(rng, 6)
        A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, None],
                     (n, di, 1))
        return {
            "in_proj": _init(ks[0], (n, D, 2 * di), D, dt),
            "conv_w": _init(ks[1], (n, cfg.d_conv, di), cfg.d_conv, dt),
            "conv_b": jnp.zeros((n, di), dt),
            "A_log": jnp.log(A),
            "ssm_D": jnp.ones((n, di), jnp.float32),
            "x_proj": _init(ks[2], (n, di, r + 2 * N), di, dt),
            "dt_proj": _init(ks[3], (n, r, di), r, dt),
            "dt_bias": jnp.zeros((n, di), dt),
            "out_proj": _init(ks[4], (n, di, D), di, dt),
        }

    def _init_moe(self, rng, n: int) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
        ks = jax.random.split(rng, 5)
        out = {
            "router": _init(ks[0], (n, D, E), D, jnp.float32),
            "e_gate": _init(ks[1], (n, E, D, F), D, dt),
            "e_up": _init(ks[2], (n, E, D, F), D, dt),
            "e_down": _init(ks[3], (n, E, F, D), F, dt),
        }
        if cfg.n_shared_experts:
            out.update(self._init_mlp(ks[4], n, cfg.n_shared_experts * F))
        return out

    def _init_segment(self, rng, seg: Segment) -> dict:
        cfg = self.cfg
        n = seg.n_layers
        ks = jax.random.split(rng, 6)
        D = cfg.d_model
        if seg.kind == "mamba":
            return {"ln1": jnp.ones((n, D), jnp.float32),
                    "mamba": self._init_mamba(ks[0], n)}
        p = {"ln1": jnp.ones((n, D), jnp.float32),
             "ln2": jnp.ones((n, D), jnp.float32)}
        if seg.kind == "vision_group":
            dt = _dtype(cfg)
            H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            sub = seg.sub_layers - 1
            cross = {
                "ln1": jnp.ones((n, D), jnp.float32),
                "ln2": jnp.ones((n, D), jnp.float32),
                "gate": jnp.zeros((n,), jnp.float32),
                "cross_wq": _init(ks[0], (n, D, H * hd), D, dt),
                "cross_wk": _init(ks[1], (n, D, KV * hd), D, dt),
                "cross_wv": _init(ks[2], (n, D, KV * hd), D, dt),
                "cross_wo": _init(ks[3], (n, H * hd, D), H * hd, dt),
                "mlp": self._init_mlp(ks[4], n, cfg.d_ff),
            }
            selfp = {
                "ln1": jnp.ones((n, sub, D), jnp.float32),
                "ln2": jnp.ones((n, sub, D), jnp.float32),
            }
            # stacked (n, sub, ...) self-attn + mlp params
            ks2 = jax.random.split(ks[5], 2)
            a = self._init_attn(ks2[0], "gqa", n * sub)
            m = self._init_mlp(ks2[1], n * sub, cfg.d_ff)
            selfp["attn"] = jax.tree.map(
                lambda w: w.reshape((n, sub) + w.shape[1:]), a)
            selfp["mlp"] = jax.tree.map(
                lambda w: w.reshape((n, sub) + w.shape[1:]), m)
            return {"cross": cross, "self": selfp}
        if seg.kind in ("dense", "moe", "hybrid"):
            p["attn"] = self._init_attn(ks[0], seg.attn, n)
        if seg.kind == "hybrid":
            p["mamba"] = self._init_mamba(ks[1], n)
        if seg.kind == "moe":
            p["moe"] = self._init_moe(ks[2], n)
        elif seg.kind in ("dense", "hybrid"):
            p["mlp"] = self._init_mlp(ks[3], n, cfg.d_ff)
        return p

    def _init_mtp(self, rng) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        D = cfg.d_model
        ks = jax.random.split(rng, 3)
        return {
            "proj": _init(ks[0], (2 * D, D), 2 * D, dt),
            "ln": jnp.ones((D,), jnp.float32),
            "block": self._init_segment(
                ks[1], Segment("dense", 1, attn=cfg.segments[-1].attn)),
        }

    # ------------------------------------------------------------ forward
    def _mixer(self, lp: dict, x, seg: Segment, img=None):
        """Attention and/or SSM part of one layer (full sequence)."""
        cfg = self.cfg
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        parts = []
        if seg.attn == "mla":
            parts.append(L.mla_attention(lp["attn"], h, cfg, seg))
        elif seg.attn == "gqa":
            parts.append(L.gqa_attention(lp["attn"], h, cfg, seg))
        if seg.kind in ("mamba", "hybrid"):
            key = "mamba"
            y, _ = L.mamba_mixer(lp[key], h, cfg)
            parts.append(y)
        out = parts[0]
        for extra in parts[1:]:
            out = out + extra
        return out

    def _ffn(self, lp: dict, x, seg: Segment, mode: str):
        cfg = self.cfg
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if seg.kind == "moe":
            y, aux = moe_apply(lp["moe"], h, cfg, self.plan, mode)
            return y, aux
        return L.swiglu(lp["mlp"], h), jnp.zeros((), jnp.float32)

    def _block(self, lp: dict, x, seg: Segment, mode: str, img=None):
        if seg.kind == "mamba":
            h = L.rmsnorm(x, lp["ln1"], self.cfg.norm_eps)
            y, _ = L.mamba_mixer(lp["mamba"], h, self.cfg)
            return x + y, jnp.zeros((), jnp.float32)
        if seg.kind == "vision_group":
            return self._vision_group(lp, x, seg, mode, img)
        x = x + self._mixer(lp, x, seg)
        x = self._constrain_residual(x)
        y, aux = self._ffn(lp, x, seg, mode)
        x = x + y
        x = self._constrain_residual(x)
        return x, aux

    def _constrain_residual(self, x):
        """Residual-stream sharding: batch over dp; with sequence
        parallelism also seq over 'model' (activation memory /tp)."""
        from ..parallel.sharding import active_mesh
        mesh = active_mesh()
        seq_axis = None
        if (self.cfg.seq_shard_activations and mesh is not None
                and "model" in mesh.axis_names and x.ndim == 3
                and x.shape[1] % mesh.shape["model"] == 0
                and x.shape[1] >= mesh.shape["model"]):
            seq_axis = "model"
        return constrain(x, batch_axes() or None, seq_axis, None)

    def _vision_group(self, lp, x, seg: Segment, mode: str, img):
        cfg = self.cfg
        cp = lp["cross"]
        h = L.rmsnorm(x, cp["ln1"], cfg.norm_eps)
        x = x + L.cross_attention(cp, h, img, cfg)
        x = x + L.swiglu(cp["mlp"], L.rmsnorm(x, cp["ln2"], cfg.norm_eps))

        def sub_block(carry, sp):
            xx = carry
            hh = L.rmsnorm(xx, sp["ln1"], cfg.norm_eps)
            xx = xx + L.gqa_attention(sp["attn"], hh, cfg, seg)
            xx = xx + L.swiglu(sp["mlp"],
                               L.rmsnorm(xx, sp["ln2"], cfg.norm_eps))
            return xx, None

        sub_params = {"ln1": lp["self"]["ln1"], "ln2": lp["self"]["ln2"],
                      "attn": lp["self"]["attn"], "mlp": lp["self"]["mlp"]}
        x, _ = jax.lax.scan(sub_block, x, sub_params)
        return x, jnp.zeros((), jnp.float32)

    def _run_segments(self, params, x, mode: str, img=None):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        for seg, sp in zip(cfg.segments, params["segments"]):
            def body(carry, lp, seg=seg):
                xx, aux = carry
                xx, a = self._block(lp, xx, seg, mode, img=img)
                return (xx, aux + a), None
            if cfg.remat != "none":
                policy = (jax.checkpoint_policies.nothing_saveable
                          if cfg.remat == "full"
                          else jax.checkpoint_policies.checkpoint_dots)
                body = jax.checkpoint(body, policy=policy,
                                      prevent_cse=False, static_argnums=())
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), sp)
        return x, aux_total

    def _embed_inputs(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.frame_input:
            return batch["frames"].astype(_dtype(cfg))
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        return x

    def logits_fn(self, params, x):
        cfg = self.cfg
        x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        return jnp.einsum("bsd,dv->bsv", x, head,
                          preferred_element_type=jnp.float32)

    def forward(self, params, batch, mode: str = "a2a"):
        x = self._embed_inputs(params, batch)
        x = constrain(x, batch_axes() or None, None, None)
        img = batch.get("image_embeds")
        if img is not None:
            img = img.astype(_dtype(self.cfg))
        x, aux = self._run_segments(params, x, mode, img=img)
        return x, aux

    # ---------------------------------------------------------- profiling
    def route_trace(self, params, batch):
        """Replay the forward pass collecting per-MoE-layer router choices:
        returns a list (one per moe segment) of (L, T, top_k) expert ids.
        Feeds the replication-aware placement planner (paper §B.1)."""
        from .moe import router_topk
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        traces = []
        for seg, sp in zip(cfg.segments, params["segments"]):
            if seg.kind != "moe":
                def body(carry, lp, seg=seg):
                    xx, _ = self._block(lp, carry, seg, "dense")
                    return xx, None
                x, _ = jax.lax.scan(body, x, sp)
                continue

            def body(carry, lp, seg=seg):
                xx = carry
                h = L.rmsnorm(xx, lp["ln2"], cfg.norm_eps)
                # router sees the post-mixer hidden state
                xx2, _ = self._block(lp, xx, seg, "dense")
                hh = L.rmsnorm(xx + self._mixer(lp, xx, seg), lp["ln2"],
                               cfg.norm_eps)
                _, idx, _ = router_topk(lp["moe"]["router"],
                                        hh.reshape(-1, cfg.d_model), cfg)
                return xx2, idx
            x, idx = jax.lax.scan(body, x, sp)
            traces.append(idx)
        return traces

    # --------------------------------------------------------------- loss
    def loss(self, params, batch):
        cfg = self.cfg
        x, aux = self.forward(params, batch, mode="a2a")
        logits = self.logits_fn(params, x)
        labels = batch["labels"]
        if cfg.frame_input or not self._is_causal():
            tgt, lg = labels, logits          # frame classification
        else:
            tgt, lg = labels[:, 1:], logits[:, :-1]
        ce = _xent(lg, tgt)
        total = ce + cfg.router_aux_coef * aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp_depth:
            mtp_ce = self._mtp_loss(params, x, batch)
            total = total + cfg.mtp_loss_weight * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = total
        return total, metrics

    def _is_causal(self) -> bool:
        return all(s.causal for s in self.cfg.segments)

    def _mtp_loss(self, params, x, batch):
        """DeepSeek-V3 multi-token prediction: one extra depth predicting
        token t+2 from (h_t, emb(token_{t+1}))."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        total = jnp.zeros((), jnp.float32)
        h = x
        for d, mp in enumerate(params["mtp"]):
            nxt = jnp.take(params["embed"], tokens[:, d + 1:], axis=0)
            hcat = jnp.concatenate(
                [L.rmsnorm(h[:, :nxt.shape[1]], mp["ln"], cfg.norm_eps), nxt],
                axis=-1)
            hm = jnp.einsum("bsd,dn->bsn", hcat, mp["proj"])
            seg = Segment("dense", 1, attn=cfg.segments[-1].attn)
            lp = jax.tree.map(lambda w: w[0], mp["block"])
            hm, _ = self._block(lp, hm, seg, mode="a2a")
            lg = self.logits_fn(params, hm)
            tgt = labels[:, d + 1:]
            total = total + _xent(lg[:, :-1], tgt[:, 1:])
            h = hm
        return total / cfg.mtp_depth

    # -------------------------------------------------------------- serve
    def init_cache(self, B: int, max_len: int) -> list:
        cfg = self.cfg
        dt = _dtype(cfg)
        caches = []
        for seg in cfg.segments:
            n = seg.n_layers
            def stack(tree):
                return jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree)
            c: dict = {}
            if seg.attn == "gqa" and seg.kind not in ("mamba", "vision_group"):
                c.update(stack(L.gqa_init_cache(cfg, seg, B, max_len, dt)))
            elif seg.attn == "mla":
                c.update(stack(L.mla_init_cache(cfg, B, max_len, dt)))
            if seg.kind in ("mamba", "hybrid"):
                c["mamba"] = stack(L.mamba_init_cache(cfg, B, dt))
            if seg.kind == "vision_group":
                sub = seg.sub_layers - 1
                kv = L.gqa_init_cache(cfg, seg, B, max_len, dt)
                c["self"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None, None],
                                               (n, sub) + a.shape), kv)
                N = cfg.n_image_tokens
                c["cross"] = {
                    "ck": jnp.zeros((n, B, N, cfg.n_kv_heads, cfg.hd), dt),
                    "cv": jnp.zeros((n, B, N, cfg.n_kv_heads, cfg.hd), dt),
                }
            caches.append(c)
        return caches

    def prefill(self, params, batch, max_len: int):
        """Run the full prompt, return (last-token logits, caches)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        img = batch.get("image_embeds")
        if img is not None:
            img = img.astype(_dtype(cfg))
        caches = []
        for seg, sp in zip(cfg.segments, params["segments"]):
            def body(xx, lp, seg=seg):
                y, aux = self._block(lp, xx, seg, mode="a2a", img=img)
                cache = self._prefill_layer_cache(lp, xx, seg, max_len, img)
                return y, cache
            x, cache = jax.lax.scan(body, x, sp)
            caches.append(cache)
        logits = self.logits_fn(params, x[:, -1:])
        return logits, caches

    def _prefill_layer_cache(self, lp, x_in, seg: Segment, max_len, img):
        cfg = self.cfg
        c: dict = {}
        if seg.kind == "vision_group":
            h = L.rmsnorm(x_in, lp["cross"]["ln1"], cfg.norm_eps)
            B, N = img.shape[0], img.shape[1]
            ck = jnp.einsum("bnd,dm->bnm", img, lp["cross"]["cross_wk"])
            cv = jnp.einsum("bnd,dm->bnm", img, lp["cross"]["cross_wv"])
            c["cross"] = {
                "ck": ck.reshape(B, N, cfg.n_kv_heads, cfg.hd),
                "cv": cv.reshape(B, N, cfg.n_kv_heads, cfg.hd)}
            # NOTE: self-attn caches inside the group are rebuilt by
            # replaying sub-blocks; handled in prefill for simplicity by
            # full recompute (vision decode is exercised via decode_32k).
            h = x_in
            sub_caches = []
            xx = x_in
            cp = lp["cross"]
            hh = L.rmsnorm(xx, cp["ln1"], cfg.norm_eps)
            xx = xx + L.cross_attention(cp, hh, img, cfg)
            xx = xx + L.swiglu(cp["mlp"], L.rmsnorm(xx, cp["ln2"], cfg.norm_eps))
            for j in range(seg.sub_layers - 1):
                sp = jax.tree.map(lambda w: w[j], {
                    "ln1": lp["self"]["ln1"], "ln2": lp["self"]["ln2"],
                    "attn": lp["self"]["attn"], "mlp": lp["self"]["mlp"]})
                h2 = L.rmsnorm(xx, sp["ln1"], cfg.norm_eps)
                sub_caches.append(L.gqa_prefill_cache(sp["attn"], h2, cfg,
                                                      seg, max_len))
                xx = xx + L.gqa_attention(sp["attn"], h2, cfg, seg)
                xx = xx + L.swiglu(sp["mlp"],
                                   L.rmsnorm(xx, sp["ln2"], cfg.norm_eps))
            c["self"] = jax.tree.map(lambda *a: jnp.stack(a), *sub_caches)
            return c
        h = L.rmsnorm(x_in, lp["ln1"], cfg.norm_eps)
        if seg.attn == "gqa" and seg.kind != "mamba":
            c.update(L.gqa_prefill_cache(lp["attn"], h, cfg, seg, max_len))
        elif seg.attn == "mla":
            c.update(L.mla_prefill_cache(lp["attn"], h, cfg, max_len))
        if seg.kind in ("mamba", "hybrid"):
            _, st = L.mamba_mixer(lp["mamba"] if seg.kind == "hybrid"
                                  else lp["mamba"], h, cfg)
            c["mamba"] = st
        return c

    def decode_step(self, params, token_or_frame, caches, pos):
        """One token for the whole batch.  pos: scalar int32."""
        cfg = self.cfg
        if cfg.frame_input:
            x = token_or_frame.astype(_dtype(cfg))
        else:
            x = jnp.take(params["embed"], token_or_frame, axis=0)
        new_caches = []
        for seg, sp, cache in zip(cfg.segments, params["segments"], caches):
            def body(xx, lp_cache, seg=seg):
                lp, c = lp_cache
                y, nc = self._decode_block(lp, xx, seg, c, pos)
                return y, nc
            x, nc = jax.lax.scan(body, x, (sp, cache))
            new_caches.append(nc)
        logits = self.logits_fn(params, x)
        return logits, new_caches

    def _decode_block(self, lp, x, seg: Segment, cache, pos):
        cfg = self.cfg
        if seg.kind == "mamba":
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, st = L.mamba_mixer(lp["mamba"], h, cfg, state=cache["mamba"])
            return x + y, {"mamba": st}
        if seg.kind == "vision_group":
            return self._decode_vision_group(lp, x, seg, cache, pos)
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        new_cache = dict(cache)
        parts = []
        if seg.attn == "mla":
            y, nc = L.mla_attention_decode(lp["attn"], h, cfg, cache, pos,
                                           absorb=cfg.mla_absorb)
            new_cache.update(nc)
            parts.append(y)
        elif seg.attn == "gqa":
            y, nc = L.gqa_attention_decode(lp["attn"], h, cfg, seg,
                                           cache, pos)
            new_cache.update(nc)
            parts.append(y)
        if seg.kind in ("mamba", "hybrid"):
            y, st = L.mamba_mixer(lp["mamba"], h, cfg, state=cache["mamba"])
            new_cache["mamba"] = st
            parts.append(y)
        out = parts[0]
        for extra in parts[1:]:
            out = out + extra
        x = x + out
        hf = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if seg.kind == "moe":
            y, _ = moe_apply(lp["moe"], hf, cfg, self.plan, mode="tp")
        else:
            y = L.swiglu(lp["mlp"], hf)
        return x + y, new_cache

    def _decode_vision_group(self, lp, x, seg: Segment, cache, pos):
        cfg = self.cfg
        cp = lp["cross"]
        B = x.shape[0]
        h = L.rmsnorm(x, cp["ln1"], cfg.norm_eps)
        hd = cfg.hd
        q = jnp.einsum("bsd,dn->bsn", h, cp["cross_wq"]).reshape(
            B, 1, cfg.n_heads, hd)
        from ..kernels import ops
        out = ops.attention(q, cache["cross"]["ck"], cache["cross"]["cv"],
                            causal=False)
        out = out.reshape(B, 1, cfg.n_heads * hd)
        x = x + jnp.tanh(cp["gate"]).astype(x.dtype) * jnp.einsum(
            "bsn,nd->bsd", out, cp["cross_wo"])
        x = x + L.swiglu(cp["mlp"], L.rmsnorm(x, cp["ln2"], cfg.norm_eps))
        new_cache = {"cross": cache["cross"]}

        def sub(carry, lp_cache, seg=seg):
            xx = carry
            sp, c = lp_cache
            hh = L.rmsnorm(xx, sp["ln1"], cfg.norm_eps)
            y, nc = L.gqa_attention_decode(sp["attn"], hh, cfg, seg, c, pos)
            xx = xx + y
            xx = xx + L.swiglu(sp["mlp"],
                               L.rmsnorm(xx, sp["ln2"], cfg.norm_eps))
            return xx, nc

        sub_params = {"ln1": lp["self"]["ln1"], "ln2": lp["self"]["ln2"],
                      "attn": lp["self"]["attn"], "mlp": lp["self"]["mlp"]}
        x, nc = jax.lax.scan(sub, x, (sub_params, cache["self"]))
        new_cache["self"] = nc
        return x, new_cache


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
