"""Builders for train / prefill / decode steps with explicit shardings.

``build_train_step``  -- loss + grads + AdamW update (donated state).
``build_serve_steps`` -- prefill and single-token decode.

Sharding policy (DESIGN.md §6):
  * params / optimizer state: path-based rules (`repro.parallel.sharding`),
  * batch dims over ('pod','data'); dp_seq strategy additionally shards the
    sequence dim over 'model' (sequence parallelism for small models),
  * KV caches: batch over data; sequence over 'model' when the batch does
    not cover the data axis (long-context decode).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..models.model import Model
from ..optim import adamw
from ..parallel import sharding as shd


def _nd(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def batch_specs(cfg: ModelConfig, mesh: Mesh, abstract_batch: dict) -> dict:
    """Shardings for the input batch dict."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    seq_axis = "model" if cfg.strategy == "dp_seq" else None
    out = {}
    for k, v in abstract_batch.items():
        if k in ("tokens", "labels", "frames"):
            spec = [dp or None, seq_axis] + [None] * (len(v.shape) - 2)
            if v.shape[1] == 1 or (seq_axis and v.shape[1] % mesh.shape["model"]):
                spec[1] = None
            out[k] = _nd(mesh, *spec)
        else:  # image_embeds etc: batch-sharded only
            out[k] = _nd(mesh, dp or None, *([None] * (len(v.shape) - 1)))
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, abstract_caches) -> object:
    """KV/latent/SSM cache shardings: batch over data when divisible, and
    the long sequence dim over 'model' when that still divides."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    model_size = mesh.shape.get("model", 1)

    def spec_for(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        # batch: first non-leading dim divisible by the dp extent (caches
        # are (layers[, sub], batch, ...))
        batch_dim = None
        for i in range(1, len(shape)):
            if shape[i] % dp_size == 0 and shape[i] >= dp_size:
                spec[i] = dp
                batch_dim = i
                break
        # cache sequence: largest remaining long dim over 'model'
        order = sorted((i for i in range(1, len(shape)) if i != batch_dim),
                       key=lambda i: -shape[i])
        for i in order:
            if shape[i] >= model_size and shape[i] % model_size == 0 \
                    and shape[i] >= 1024:
                spec[i] = "model"
                break
        return _nd(mesh, *spec)

    return jax.tree.map(spec_for, abstract_caches)


@dataclasses.dataclass
class TrainStep:
    step_fn: object          # jit'd (state, batch) -> (state, metrics)
    state_shardings: object
    batch_shardings: object
    abstract_state: object


def make_train_state_abstract(model: Model, opt_cfg: adamw.AdamWConfig):
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = jax.eval_shape(partial(adamw.init_state, opt_cfg), params)
    return {"params": params, "opt": opt}


def state_shardings(cfg: ModelConfig, mesh: Mesh, abstract_state):
    pspecs = shd.tree_param_specs(abstract_state["params"], cfg.strategy)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    def opt_leaf(path_spec, leaf):
        spec = list(path_spec) + [None] * (len(leaf.shape) - len(path_spec))
        if "data" in spec:  # already data-sharded (e.g. ep_data experts)
            return NamedSharding(mesh, P(*spec))
        if cfg.zero_opt_state and "data" in mesh.axis_names:
            # ZeRO: add the data axis on the largest unsharded dim
            dims = sorted(range(len(leaf.shape)),
                          key=lambda i: -leaf.shape[i])
            for i in dims:
                if spec[i] is None and leaf.shape[i] % mesh.shape["data"] == 0 \
                        and leaf.shape[i] >= mesh.shape["data"]:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    def opt_tree(tree):
        return jax.tree.map(lambda s, l: opt_leaf(tuple(s), l), pspecs, tree,
                            is_leaf=lambda x: isinstance(x, P))

    opt_sh = {
        "step": NamedSharding(mesh, P()),
        "master": opt_tree(abstract_state["opt"]["master"]),
        "m": opt_tree(abstract_state["opt"]["m"]),
        "v": opt_tree(abstract_state["opt"]["v"]),
    }
    return {"params": param_sh, "opt": opt_sh}


def build_train_step(cfg: ModelConfig, mesh: Mesh,
                     opt_cfg: adamw.AdamWConfig | None = None,
                     plan=None) -> TrainStep:
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    n_ep = mesh.shape.get("model", 1)
    model = Model(cfg, n_ep_shards=n_ep, plan=plan)

    def step(state, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt, opt_metrics = adamw.apply_updates(
            opt_cfg, state["opt"], grads, state["params"])
        metrics = dict(metrics, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    abstract_state = make_train_state_abstract(model, opt_cfg)
    st_sh = state_shardings(cfg, mesh, abstract_state)
    fn = jax.jit(step, donate_argnums=(0,),
                 in_shardings=(st_sh, None),
                 out_shardings=(st_sh, None))
    return TrainStep(step_fn=fn, state_shardings=st_sh,
                     batch_shardings=None, abstract_state=abstract_state)


@dataclasses.dataclass
class ServeSteps:
    prefill_fn: object
    decode_fn: object
    param_shardings: object
    abstract_params: object
    abstract_caches: object
    cache_shardings: object


def build_serve_steps(cfg: ModelConfig, mesh: Mesh, B: int, max_len: int,
                      plan=None) -> ServeSteps:
    n_ep = mesh.shape.get("model", 1)
    model = Model(cfg, n_ep_shards=n_ep, plan=plan)
    abstract_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = shd.tree_param_specs(abstract_params, cfg.strategy)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    abstract_caches = jax.eval_shape(partial(model.init_cache, B, max_len))
    cache_sh = cache_specs(cfg, mesh, abstract_caches)

    def prefill(params, batch):
        return model.prefill(params, batch, max_len)

    def decode(params, tok, caches, pos):
        return model.decode_step(params, tok, caches, pos)

    prefill_fn = jax.jit(prefill,
                         in_shardings=(param_sh, None),
                         out_shardings=(None, cache_sh))
    decode_fn = jax.jit(decode,
                        in_shardings=(param_sh, None, cache_sh, None),
                        out_shardings=(None, cache_sh),
                        donate_argnums=(2,))
    return ServeSteps(prefill_fn=prefill_fn, decode_fn=decode_fn,
                      param_shardings=param_sh,
                      abstract_params=abstract_params,
                      abstract_caches=abstract_caches,
                      cache_shardings=cache_sh)
