"""AdamW with cosine schedule, global-norm clipping and optional tricks:

  * ``zero_partition``: Adam moments sharded over the *data* axis on their
    largest dimension (ZeRO-1 style) -- required to fit deepseek-v3 on 512
    v5e chips (DESIGN.md §6);
  * ``compress_moments``: bf16 first moment (halves optimizer HBM and the
    bytes the memory-bound update step moves).

Parameters stay in the model dtype (bf16); a float32 master copy lives in
the optimizer state.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_moments: bool = False


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def init_state(cfg: AdamWConfig, params):
    mdt = jnp.bfloat16 if cfg.compress_moments else jnp.float32
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def apply_updates(cfg: AdamWConfig, state, grads, params):
    step = state["step"] + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        master_new = master - lr * delta
        return m_new.astype(m.dtype), v_new, master_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ma = jax.tree.leaves(state["master"])
    outs = [upd(g, m, v, ma) for g, m, v, ma
            in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in outs])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, params)
    new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
