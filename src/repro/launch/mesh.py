"""Production meshes.

Single pod: 16x16 = 256 chips ('data', 'model').
Multi-pod:  2x16x16 = 512 chips ('pod', 'data', 'model'); the 'pod' axis
carries only data parallelism (gradient all-reduce over DCI), matching how
multi-pod TPU training is deployed.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
