"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 50 --batch 8 --seq 256 [--reduced] [--mesh dxm] \
        [--ckpt-dir DIR] [--resume]

On this CPU container use ``--reduced`` (tiny same-family config) or small
dims; on a pod the same entry point drives the production mesh.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, list_archs, reduce_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg, layers_per_segment=args.layers)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh(model_axis=1 if len(jax.devices()) < 2 else 2)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                             total_steps=args.steps)
    trainer = Trainer(cfg, mesh, DataConfig(args.batch, args.seq), tcfg, ocfg)
    _, hist = trainer.run()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[train] done: loss {first:.4f} -> {last:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
