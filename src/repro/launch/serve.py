"""Batched serving launcher: prefill + decode over a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --requests 8 --prompt-len 32 --gen 16 [--replicated-placement]

Serving is where the paper's replication technique applies (its MoE traces
come from the decode phase): with ``--replicated-placement`` the engine
profiles router co-activation on warmup traffic, plans a replicated expert
placement (hypergraph partitioning with replication), rebuilds the decode
step with the plan and reports the (lambda_e - 1) communication cost next
to the round-robin baseline.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduce_config
from repro.core.placement.expert_placement import (evaluate_plan,
                                                   plan_expert_placement)
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.parallel import sharding as shd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--replicated-placement", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg, layers_per_segment=args.layers)
    rng = np.random.default_rng(0)
    B, S, G = args.requests, args.prompt_len, args.gen
    max_len = S + G

    mesh = make_host_mesh()
    shd.set_active_mesh(mesh)
    plan = None
    model = Model(cfg, n_ep_shards=mesh.shape.get("model", 1))
    params = model.init(jax.random.PRNGKey(0))

    prompts = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.n_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_image_tokens, cfg.d_model)), jnp.float32)

    if args.replicated_placement and cfg.n_experts:
        # --- profile router on warmup traffic, plan replicated placement ---
        traces = model.route_trace(params, {"tokens": jnp.asarray(prompts)})
        trace = np.asarray(traces[0]).reshape(-1, cfg.top_k)
        n_sh = mesh.shape.get("model", 1)
        res = plan_expert_placement(np.sort(trace, axis=1), cfg.n_experts,
                                    max(n_sh, 2), kappa0=min(1000, 8 * len(trace)))
        print(f"[serve] placement: lambda-cost {res.lambda_cost_no_repl:.1f} "
              f"-> {res.lambda_cost_repl:.1f} with replication; "
              f"local fraction {res.local_fraction_no_repl:.2f} -> "
              f"{res.local_fraction_repl:.2f}")
        if n_sh >= 2:
            plan = res.plan
            model = Model(cfg, plan=plan)

    with shd.use_mesh(mesh):
        t0 = time.time()
        logits, caches = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))(params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens = [np.asarray(tok)]
        decode = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos))
        for i in range(G - 1):
            logits, caches = decode(params, tok, caches, jnp.int32(S + i))
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out_tokens.append(np.asarray(tok))
        dt = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] {B} requests, prompt {S}, generated {G} tokens each "
          f"in {dt:.2f}s ({B*G/dt:.1f} tok/s)")
    print(f"[serve] sample continuation ids: {gen[0][:12].tolist()}")


if __name__ == "__main__":
    main()
