import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real train/prefill/decode step with
ShapeDtypeStruct inputs (no allocation), compiles it, and records
``memory_analysis`` / ``cost_analysis`` / HLO-parsed collective bytes into
``benchmarks/results/dryrun/<cell>.json`` for the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
        --shape train_4k [--multi-pod] [--all] [--placement plan.json]
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (SHAPES, cell_is_applicable, get_config, input_specs,
                           list_archs)
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.parallel import sharding as shd
from repro.roofline.hlo import collective_bytes_from_text, summarize_cost
from repro.train import step as step_lib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def shard_batch_abstract(cfg, mesh, abstract_batch):
    sh = step_lib.batch_specs(cfg, mesh, abstract_batch)
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=sh[k])
            for k, v in abstract_batch.items()}


def with_shardings(abstract_tree, shardings_tree):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_tree, shardings_tree)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             plan=None, tag: str = "", overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    ok, why = cell_is_applicable(cfg, shape_name)
    cell = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}{tag}"
    if not ok:
        return {"cell": cell, "status": "skipped", "reason": why}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    shd.set_active_mesh(mesh)
    shape = SHAPES[shape_name]
    try:
        with shd.use_mesh(mesh):
            if shape.kind == "train":
                ts = step_lib.build_train_step(cfg, mesh, plan=plan)
                ab = input_specs(cfg, shape_name)
                batch = shard_batch_abstract(cfg, mesh, ab)
                state = with_shardings(ts.abstract_state, ts.state_shardings)
                lowered = ts.step_fn.lower(state, batch)
            elif shape.kind == "prefill":
                sv = step_lib.build_serve_steps(cfg, mesh, shape.global_batch,
                                                shape.seq_len, plan=plan)
                ab = input_specs(cfg, shape_name)
                batch = shard_batch_abstract(cfg, mesh, ab)
                params = with_shardings(sv.abstract_params, sv.param_shardings)
                lowered = sv.prefill_fn.lower(params, batch)
            else:  # decode
                sv = step_lib.build_serve_steps(cfg, mesh, shape.global_batch,
                                                shape.seq_len, plan=plan)
                params = with_shardings(sv.abstract_params, sv.param_shardings)
                caches = with_shardings(sv.abstract_caches, sv.cache_shardings)
                tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
                if cfg.frame_input:
                    tok = jax.ShapeDtypeStruct(
                        (shape.global_batch, 1, cfg.d_model), jnp.dtype(cfg.dtype))
                pos = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = sv.decode_fn.lower(params, tok, caches, pos)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            coll = collective_bytes_from_text(compiled.as_text())
    finally:
        shd.set_active_mesh(None)
    n_chips = int(np.prod(list(mesh.shape.values())))
    out = {
        "cell": cell,
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "chips": n_chips,
        "seconds": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": summarize_cost(cost),
        "collectives": coll,
        "params": get_config(arch).param_count(),
        "active_params": get_config(arch).active_param_count(),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cell = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}{args.tag}"
                path = RESULTS / f"{cell}.json"
                if args.skip_existing and path.exists():
                    print(f"[dryrun] {cell}: cached", flush=True)
                    continue
                try:
                    out = run_cell(arch, shape, mp, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    out = {"cell": cell, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                path.write_text(json.dumps(out, indent=1))
                status = out["status"]
                extra = (f" flops={out['cost'].get('flops', 0):.3g}"
                         f" coll={out['collectives'].get('total_bytes', 0):.3g}B"
                         f" peak={out['memory']['peak_bytes']}"
                         if status == "ok" else
                         out.get("reason", out.get("error", "")))
                print(f"[dryrun] {cell}: {status} {extra}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
