"""Sharded, atomic, resharding-tolerant checkpointing.

Layout:
    <dir>/step_000123.tmp/   -> written, fsynced, then renamed to
    <dir>/step_000123/
        manifest.json        -- treedef paths, shapes, dtypes
        <leaf-hash>.npy      -- one file per pytree leaf (full array)

Restart semantics:
  * rename() makes a checkpoint visible atomically -- a preempted writer
    never leaves a readable-but-corrupt step;
  * `restore` accepts target shardings for a *different* mesh than the one
    that wrote the checkpoint (elastic re-scaling): arrays are loaded on
    host and re-placed with jax.device_put under the new sharding;
  * `keep` most-recent checkpoints are retained.

On a multi-host deployment each host writes only the shards it owns
(`addressable_shards`); in this single-process container every array is
fully addressable so files hold full arrays -- the manifest format carries
per-shard metadata either way.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import re
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize ml_dtypes natively: store as same-width uint views
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8, "float16": None}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    u = _EXOTIC.get(str(arr.dtype))
    return arr.view(u) if u is not None else arr


def _from_storable(arr: np.ndarray, dtype: str) -> np.ndarray:
    u = _EXOTIC.get(dtype)
    return arr.view(getattr(ml_dtypes, dtype)) if u is not None else arr


def _leaf_name(path: str) -> str:
    h = hashlib.sha1(path.encode()).hexdigest()[:16]
    safe = re.sub(r"[^A-Za-z0-9_.-]", "_", path)[-80:]
    return f"{safe}__{h}.npy"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: threading.Thread | None = None

    # ------------------------------------------------------------------ io
    def save(self, step: int, tree, extra: dict | None = None) -> pathlib.Path:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, _ = _flatten_with_names(tree)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for name, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            fn = _leaf_name(name)
            np.save(tmp / fn, _to_storable(arr))
            manifest["leaves"].append(
                {"path": name, "file": fn, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        tmp.rename(final)  # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, tree, extra: dict | None = None) -> None:
        """Overlap checkpoint IO with the next steps (device_get happens
        synchronously; file IO on a worker thread)."""
        leaves, _ = _flatten_with_names(tree)
        host = [(n, np.asarray(jax.device_get(l))) for n, l in leaves]

        def work():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "extra": extra or {}, "leaves": []}
            for name, arr in host:
                fn = _leaf_name(name)
                np.save(tmp / fn, _to_storable(arr))
                manifest["leaves"].append(
                    {"path": name, "file": fn, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            tmp.rename(final)
            self._gc()

        self.wait()
        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def latest_step(self) -> int | None:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, step: int, abstract_tree, shardings=None):
        """Load into the structure of ``abstract_tree``; if ``shardings``
        (same structure) is given, place each leaf accordingly -- works
        across mesh shapes (elastic restart)."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_path = {l["path"]: l for l in manifest["leaves"]}
        leaves, treedef = _flatten_with_names(abstract_tree)
        sh_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(leaves))
        out = []
        for (name, ab), sh in zip(leaves, sh_leaves):
            meta = by_path[name]
            arr = _from_storable(np.load(d / meta["file"]), meta["dtype"])
            assert tuple(arr.shape) == tuple(ab.shape), \
                f"{name}: {arr.shape} vs {ab.shape}"
            arr = arr.astype(ab.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest["extra"]

    def _gc(self) -> None:
        steps = sorted((int(p.name.split("_")[1]), p)
                       for p in self.dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for _, p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
