"""SpMV hypergraphs: fine-grained and row-net models (paper §3.2, §B.1).

The paper samples application matrices from SuiteSparse; offline we generate
sparse matrices with application-like structure (banded diagonals + random
off-band fill + a few dense rows/columns, the patterns partitioners care
about) and apply the two standard hypergraph constructions:

  * fine-grained [24, 27]: one node per non-zero; one hyperedge per row and
    one per column, connecting the non-zeros it contains;
  * row-net [10]: one node per column (weight = its non-zero count); one
    hyperedge per row, connecting the columns with a non-zero in that row.
"""
from __future__ import annotations

import numpy as np

from ..core.hypergraph import Hypergraph


def synthetic_sparse_matrix(n_rows: int, n_cols: int, seed: int = 0,
                            band: int = 3, fill: float = 0.01,
                            n_dense: int = 2) -> list[tuple[int, int]]:
    """Return the non-zero coordinate list of an application-like matrix."""
    rng = np.random.default_rng(seed)
    nz: set[tuple[int, int]] = set()
    # banded structure (stencil-like applications)
    for i in range(n_rows):
        for off in range(-band, band + 1):
            j = i + off
            if 0 <= j < n_cols and rng.random() < 0.7:
                nz.add((i, j))
    # random fill (irregular coupling)
    n_fill = int(fill * n_rows * n_cols)
    rows = rng.integers(0, n_rows, size=n_fill)
    cols = rng.integers(0, n_cols, size=n_fill)
    nz.update(zip(rows.tolist(), cols.tolist()))
    # a few dense rows/columns (constraints, hubs)
    for _ in range(n_dense):
        r = int(rng.integers(0, n_rows))
        for j in rng.choice(n_cols, size=max(2, n_cols // 6), replace=False):
            nz.add((r, int(j)))
        c = int(rng.integers(0, n_cols))
        for i in rng.choice(n_rows, size=max(2, n_rows // 6), replace=False):
            nz.add((int(i), c))
    return sorted(nz)


def fine_grained_hypergraph(nz: list[tuple[int, int]], name: str = "spmv_fg") -> Hypergraph:
    n = len(nz)
    rows: dict[int, list[int]] = {}
    cols: dict[int, list[int]] = {}
    for idx, (i, j) in enumerate(nz):
        rows.setdefault(i, []).append(idx)
        cols.setdefault(j, []).append(idx)
    edges = [tuple(v) for v in rows.values() if len(v) >= 2]
    edges += [tuple(v) for v in cols.values() if len(v) >= 2]
    return Hypergraph(n=n, edges=edges, name=name).remove_isolated()


def row_net_hypergraph(nz: list[tuple[int, int]], n_cols: int,
                       name: str = "spmv_rn") -> Hypergraph:
    rows: dict[int, list[int]] = {}
    col_nnz = np.zeros(n_cols, dtype=np.float64)
    for (i, j) in nz:
        rows.setdefault(i, []).append(j)
        col_nnz[j] += 1
    edges = [tuple(sorted(set(v))) for v in rows.values() if len(set(v)) >= 2]
    omega = np.maximum(col_nnz, 1.0)  # node weight = nnz in the column [10]
    return Hypergraph(n=n_cols, edges=edges, omega=omega, name=name).remove_isolated()


def spmv_dataset(kind: str = "fg", count: int = 10, seed: int = 0,
                 sizes: tuple[int, int] = (30, 90)) -> list[Hypergraph]:
    """A dataset of `count` instances with paper-like size spread."""
    rng = np.random.default_rng(seed)
    out = []
    for k in range(count):
        m = int(rng.integers(sizes[0], sizes[1]))
        nz = synthetic_sparse_matrix(m, m, seed=seed * 1000 + k)
        if kind == "fg":
            out.append(fine_grained_hypergraph(nz, name=f"spmv_fg_{k}"))
        else:
            out.append(row_net_hypergraph(nz, m, name=f"spmv_rn_{k}"))
    return out
