"""SpMV hypergraphs: fine-grained and row-net models (paper §3.2, §B.1).

The paper samples application matrices from SuiteSparse; offline we generate
sparse matrices with application-like structure (banded diagonals + random
off-band fill + a few dense rows/columns, the patterns partitioners care
about) and apply the two standard hypergraph constructions:

  * fine-grained [24, 27]: one node per non-zero; one hyperedge per row and
    one per column, connecting the non-zeros it contains;
  * row-net [10]: one node per column (weight = its non-zero count); one
    hyperedge per row, connecting the columns with a non-zero in that row.
"""
from __future__ import annotations

import numpy as np

from ..core.hypergraph import Hypergraph


def synthetic_sparse_matrix(n_rows: int, n_cols: int, seed: int = 0,
                            band: int = 3, fill: float = 0.01,
                            n_dense: int = 2) -> list[tuple[int, int]]:
    """Return the non-zero coordinate list of an application-like matrix."""
    rng = np.random.default_rng(seed)
    nz: set[tuple[int, int]] = set()
    # banded structure (stencil-like applications)
    for i in range(n_rows):
        for off in range(-band, band + 1):
            j = i + off
            if 0 <= j < n_cols and rng.random() < 0.7:
                nz.add((i, j))
    # random fill (irregular coupling)
    n_fill = int(fill * n_rows * n_cols)
    rows = rng.integers(0, n_rows, size=n_fill)
    cols = rng.integers(0, n_cols, size=n_fill)
    nz.update(zip(rows.tolist(), cols.tolist()))
    # a few dense rows/columns (constraints, hubs)
    for _ in range(n_dense):
        r = int(rng.integers(0, n_rows))
        for j in rng.choice(n_cols, size=max(2, n_cols // 6), replace=False):
            nz.add((r, int(j)))
        c = int(rng.integers(0, n_cols))
        for i in rng.choice(n_rows, size=max(2, n_rows // 6), replace=False):
            nz.add((int(i), c))
    return sorted(nz)


def fine_grained_hypergraph(nz: list[tuple[int, int]], name: str = "spmv_fg") -> Hypergraph:
    n = len(nz)
    rows: dict[int, list[int]] = {}
    cols: dict[int, list[int]] = {}
    for idx, (i, j) in enumerate(nz):
        rows.setdefault(i, []).append(idx)
        cols.setdefault(j, []).append(idx)
    edges = [tuple(v) for v in rows.values() if len(v) >= 2]
    edges += [tuple(v) for v in cols.values() if len(v) >= 2]
    return Hypergraph(n=n, edges=edges, name=name).remove_isolated()


def row_net_hypergraph(nz: list[tuple[int, int]], n_cols: int,
                       name: str = "spmv_rn") -> Hypergraph:
    rows: dict[int, list[int]] = {}
    col_nnz = np.zeros(n_cols, dtype=np.float64)
    for (i, j) in nz:
        rows.setdefault(i, []).append(j)
        col_nnz[j] += 1
    edges = [tuple(sorted(set(v))) for v in rows.values() if len(set(v)) >= 2]
    omega = np.maximum(col_nnz, 1.0)  # node weight = nnz in the column [10]
    return Hypergraph(n=n_cols, edges=edges, omega=omega, name=name).remove_isolated()


def large_row_net(n: int, seed: int = 0, band: int = 3,
                  fill_per_row: float = 2.0, n_dense: int = 2,
                  dense_len: int = 256,
                  name: str | None = None,
                  chunk_rows: int | None = None,
                  alloc=None) -> Hypergraph:
    """Streaming row-net generator for multilevel-scale instances.

    ``synthetic_sparse_matrix`` materializes a python set of (i, j) pairs
    and its ``fill`` fraction scales with n^2 -- at n = 65536 that is tens
    of millions of python tuples before the hypergraph even exists.  This
    generator keeps the same structural mix (band + random fill + a few
    dense rows/columns) but parameterized *per row* (``fill_per_row``
    non-zeros of random fill per row, dense rows/columns capped at
    ``dense_len``), and builds everything as flat numpy coordinate arrays
    emitted straight as a CSR ``Hypergraph`` (no per-edge tuples at all).
    n = 65536 builds in a couple of seconds; n and seed are the knobs the
    scale benchmarks sweep.

    ``chunk_rows`` bounds the dedup working set: the i*n + j key space is
    partitioned by row ranges, each range deduped/sorted on its own, and
    the per-range results concatenated -- bit-identical to the one-shot
    ``np.unique`` (row-major key order is preserved across ranges), so the
    default (one shot) and chunked paths produce the same hypergraph.

    ``alloc(shape, dtype)``, when given, allocates the output CSR arrays
    (``xpins``/``pins``/``omega``) -- pass ``ShmRegistry.alloc`` and a
    ~10^7-pin instance lands directly in shared memory, never copied again
    for the worker pool.
    """
    if alloc is None:
        alloc = np.zeros
    rng = np.random.default_rng(seed)
    coords = []
    # banded structure, each diagonal kept with prob 0.7 (as the seed gen)
    for off in range(-band, band + 1):
        i = np.arange(max(0, -off), min(n, n - off), dtype=np.int64)
        i = i[rng.random(len(i)) < 0.7]
        coords.append(np.stack([i, i + off]))
    # random fill (irregular coupling), ~fill_per_row nz per row
    n_fill = int(fill_per_row * n)
    coords.append(np.stack([rng.integers(0, n, size=n_fill, dtype=np.int64),
                            rng.integers(0, n, size=n_fill, dtype=np.int64)]))
    # a few dense rows/columns (constraints, hubs), capped length
    k = max(2, min(dense_len, n // 6))
    for _ in range(n_dense):
        r = int(rng.integers(0, n))
        cols = rng.choice(n, size=k, replace=False).astype(np.int64)
        coords.append(np.stack([np.full(k, r, dtype=np.int64), cols]))
        c = int(rng.integers(0, n))
        rows_d = rng.choice(n, size=k, replace=False).astype(np.int64)
        coords.append(np.stack([rows_d, np.full(k, c, dtype=np.int64)]))
    ij = np.concatenate(coords, axis=1)
    keys = ij[0] * np.int64(n) + ij[1]
    if chunk_rows is None or chunk_rows >= n:
        flat = np.unique(keys)          # dedup + row-major sort, one shot
    else:
        # partitioned key space: rows [lo, hi) own keys [lo*n, hi*n), so
        # per-range uniques concatenate into exactly the global unique
        parts = []
        for lo in range(0, n, int(chunk_rows)):
            hi = min(lo + int(chunk_rows), n)
            sel = (ij[0] >= lo) & (ij[0] < hi)
            if sel.any():
                parts.append(np.unique(keys[sel]))
        flat = np.concatenate(parts)
    i_arr, j_arr = flat // n, flat % n
    # row-net model: nodes = columns (weight = nnz), edges = rows with >= 2
    # distinct columns; isolated columns dropped (cf. row_net_hypergraph)
    col_nnz = np.bincount(j_arr, minlength=n)
    row_len = np.bincount(i_arr, minlength=n)
    keep = row_len[i_arr] >= 2
    i_arr, j_arr = i_arr[keep], j_arr[keep]
    used = np.unique(j_arr)   # columns appearing in some kept edge
    remap = np.zeros(n, dtype=np.int64)
    remap[used] = np.arange(len(used), dtype=np.int64)
    # CSR straight out: i_arr is sorted, runs of equal i are the edges (and
    # j ascends within a run, so ``presorted`` pin order holds); the output
    # arrays come from ``alloc`` so they can live in shared memory
    first = np.ones(len(i_arr), dtype=bool)
    first[1:] = i_arr[1:] != i_arr[:-1]
    starts = np.flatnonzero(first)
    lens = np.diff(np.append(starts, len(i_arr)))
    xpins = alloc(len(starts) + 1, np.int64)
    np.cumsum(lens, out=xpins[1:])
    pins = alloc(len(j_arr), np.int64)
    pins[:] = remap[j_arr]
    omega = alloc(len(used), np.float64)
    omega[:] = np.maximum(col_nnz[used], 1.0)
    return Hypergraph.from_csr(len(used), xpins, pins, omega=omega,
                               name=name or f"spmv_rn_large_{n}")


def spmv_dataset(kind: str = "fg", count: int = 10, seed: int = 0,
                 sizes: tuple[int, int] = (30, 90)) -> list[Hypergraph]:
    """A dataset of `count` instances with paper-like size spread."""
    rng = np.random.default_rng(seed)
    out = []
    for k in range(count):
        m = int(rng.integers(sizes[0], sizes[1]))
        nz = synthetic_sparse_matrix(m, m, seed=seed * 1000 + k)
        if kind == "fg":
            out.append(fine_grained_hypergraph(nz, name=f"spmv_fg_{k}"))
        else:
            out.append(row_net_hypergraph(nz, m, name=f"spmv_rn_{k}"))
    return out
