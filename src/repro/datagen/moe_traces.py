"""MoE expert co-activation traces and the moe-8 / moe-2 hypergraphs.

The paper (§B.1) builds hypergraphs from profiled expert usage of MoE LLMs:
for every token, the 8-tuple of experts invoked on a layer is recorded; the
most frequent 8-tuples become hyperedges (weight = frequency normalized to
[1,10]) until the pin count reaches kappa_0 ~ 1000; isolated experts are
dropped.  moe-2 does the same with all C(8,2) expert pairs.

The published traces (Qwen3-235B / DeepSeek-R1 on MMLU) are not available
offline, so ``synthetic_trace`` generates token->8-tuple traces with the
salient statistics of real MoE routing: a Zipf-like expert popularity skew
plus topic clustering (tokens from a topic prefer a correlated expert
subset), which is what makes co-activation partitioning non-trivial.

``trace_to_moe8`` / ``trace_to_moe2`` then follow the paper's construction
verbatim.  The same code path is used by the *runtime* profiler
(`repro.core.placement`): there the trace comes from the actual router of a
running model instead of the synthetic generator.
"""
from __future__ import annotations

from collections import Counter

import numpy as np

from ..core.hypergraph import Hypergraph


def synthetic_trace(
    n_experts: int = 128,
    n_tokens: int = 50_000,
    top_k: int = 8,
    n_topics: int = 16,
    zipf_a: float = 1.1,
    topic_strength: float = 12.0,
    gumbel_scale: float = 1.5,
    seed: int = 0,
) -> np.ndarray:
    """Token -> top-k expert tuples, shape (n_tokens, top_k).

    Co-activation in real MoE traces is highly concentrated: tokens of one
    topic invoke near-identical expert tuples (that is what makes the
    paper's moe-8 hyperedges heavy).  Each topic has a small favorite set
    barely larger than top_k, so its tokens mostly produce the same tuple
    with occasional swaps; a mild global Zipf makes some experts hubs
    across topics.
    """
    rng = np.random.default_rng(seed)
    pop = 1.0 / np.arange(1, n_experts + 1) ** zipf_a
    pop = pop[rng.permutation(n_experts)]
    pop /= pop.sum()
    topic_boost = np.full((n_topics, n_experts), 1e-3)
    # universal hub experts: co-activated by every topic (the analogue of
    # always-hot experts in real routers; these are what replication wins on)
    hubs = rng.choice(n_experts, size=max(2, top_k // 4), replace=False)
    for t in range(n_topics):
        fav = rng.choice(n_experts, size=top_k + 3, replace=False)
        topic_boost[t, fav] += topic_strength
        topic_boost[t, hubs] += topic_strength * 1.5
    topic_of_token = rng.integers(0, n_topics, size=n_tokens)
    logits = np.log(pop)[None, :] + np.log(topic_boost[topic_of_token])
    gumbel = rng.gumbel(size=(n_tokens, n_experts)) * gumbel_scale
    out = np.argpartition(-(logits + gumbel), top_k, axis=1)[:, :top_k]
    return np.sort(out.astype(np.int32), axis=1)


def _tuples_to_hypergraph(counter: Counter, kappa0: int, tuple_size: int,
                          name: str) -> Hypergraph:
    """Select the most frequent tuples until >= kappa0 pins (paper §B.1)."""
    items = counter.most_common()
    edges, freqs, pins = [], [], 0
    for tup, f in items:
        edges.append(tuple(tup))
        freqs.append(f)
        pins += tuple_size
        if pins >= kappa0:
            break
    freqs = np.asarray(freqs, dtype=np.float64)
    # normalize frequency to [1, 10]
    if freqs.max() > freqs.min():
        mu = 1.0 + 9.0 * (freqs - freqs.min()) / (freqs.max() - freqs.min())
    else:
        mu = np.ones_like(freqs)
    mu = np.maximum(mu, 1.0)
    n = int(max(v for e in edges for v in e)) + 1
    hg = Hypergraph(n=n, edges=edges, mu=mu, name=name)
    return hg.remove_isolated()


def trace_to_moe8(trace: np.ndarray, kappa0: int = 1000,
                  name: str = "moe8") -> Hypergraph:
    uniq, counts = np.unique(trace, axis=0, return_counts=True)
    counter = Counter({tuple(int(x) for x in row): int(c)
                       for row, c in zip(uniq, counts)})
    return _tuples_to_hypergraph(counter, kappa0, trace.shape[1], name)


def trace_to_moe2(trace: np.ndarray, kappa0: int = 1000,
                  name: str = "moe2") -> Hypergraph:
    k = trace.shape[1]
    n_exp = int(trace.max()) + 1
    ii, jj = np.triu_indices(k, k=1)
    codes = (trace[:, ii].astype(np.int64) * n_exp
             + trace[:, jj].astype(np.int64)).ravel()
    uniq, counts = np.unique(codes, return_counts=True)
    counter = Counter({(int(c // n_exp), int(c % n_exp)): int(f)
                       for c, f in zip(uniq, counts)})
    return _tuples_to_hypergraph(counter, kappa0, 2, name)


def moe_dataset(kind: str = "moe8", n_layers: int = 5, kappa0: int = 1000,
                n_experts: int = 128, seed: int = 0) -> list[Hypergraph]:
    """One hypergraph per 'layer' (independent trace), like Qwen_l0..l4."""
    out = []
    for layer in range(n_layers):
        trace = synthetic_trace(n_experts=n_experts, seed=seed * 100 + layer)
        fn = trace_to_moe8 if kind == "moe8" else trace_to_moe2
        hg = fn(trace, kappa0=kappa0, name=f"{kind}_l{layer}")
        out.append(hg)
    return out
