from .dags import (cg_dag, hdb_dataset, iterated_matmul_dag, knn_dag,
                   large_psdd_dag, large_sptrsv_dag, psdd_dag, psdd_dataset,
                   spmv_dag, sptrsv_dag, sptrsv_dataset, tiny_dataset)
from .moe_traces import (moe_dataset, synthetic_trace, trace_to_moe2,
                         trace_to_moe8)
from .spmv import (fine_grained_hypergraph, large_row_net,
                   row_net_hypergraph, spmv_dataset, synthetic_sparse_matrix)
