"""Computational-DAG generators mirroring the paper's scheduling datasets.

  * hdb-like (§B.2): fine-grained DAGs of SpMV, conjugate gradient, k-NN and
    iterated matrix multiplication on random sparse structures -- the same
    four computations the HyperDAG database is built from;
  * sptrsv-like: the dependency DAG of a sparse lower-triangular solve
    (node = row, edge = sub-diagonal non-zero) on synthetic banded+fill
    triangular matrices;
  * psdd-like: irregular arithmetic-circuit DAGs (alternating sum/product
    units with random fan-in, as in PSDD evaluation graphs).

Sizes are scaled to the single-core CPU budget of this container (the paper
uses up to 175k nodes on a 128-thread EPYC; we default to 400-4000 and the
generators accept any size).
"""
from __future__ import annotations

import numpy as np

from ..core.hypergraph import Dag


def _rand_sparse_rows(n: int, nnz_per_row: int, rng) -> list[list[int]]:
    rows = []
    for i in range(n):
        deg = max(1, int(rng.poisson(nnz_per_row)))
        rows.append(sorted(set(rng.integers(0, n, size=deg).tolist())))
    return rows


def spmv_dag(n_rows: int = 60, nnz_per_row: int = 3, seed: int = 0) -> Dag:
    """Fine-grained y = A x: input nodes x_j -> multiply nodes -> row sums."""
    rng = np.random.default_rng(seed)
    rows = _rand_sparse_rows(n_rows, nnz_per_row, rng)
    edges = []
    x_nodes = list(range(n_rows))  # x_j
    nid = n_rows
    mul_nodes_of_row = []
    for i, cols in enumerate(rows):
        muls = []
        for j in cols:
            edges.append((x_nodes[j], nid))
            muls.append(nid)
            nid += 1
        mul_nodes_of_row.append(muls)
    for i, muls in enumerate(mul_nodes_of_row):  # reduction node per row
        for m in muls:
            edges.append((m, nid))
        nid += 1
    return Dag(n=nid, edge_list=edges, name=f"spmv_N{n_rows}")


def iterated_matmul_dag(n: int = 20, iters: int = 4, nnz_per_row: int = 3,
                        seed: int = 0) -> Dag:
    """x <- A x repeated: exp_N*_K* graphs of the HyperDAG DB."""
    rng = np.random.default_rng(seed)
    rows = _rand_sparse_rows(n, nnz_per_row, rng)
    edges = []
    cur = list(range(n))
    nid = n
    for _ in range(iters):
        nxt = []
        for i, cols in enumerate(rows):
            for j in cols:
                edges.append((cur[j], nid))
            nxt.append(nid)
            nid += 1
        cur = nxt
    return Dag(n=nid, edge_list=edges, name=f"exp_N{n}_K{iters}")


def cg_dag(n: int = 20, iters: int = 4, nnz_per_row: int = 3, seed: int = 0) -> Dag:
    """Conjugate-gradient-like iteration: SpMV + two reductions + axpy."""
    rng = np.random.default_rng(seed)
    rows = _rand_sparse_rows(n, nnz_per_row, rng)
    edges = []
    x = list(range(n))
    nid = n
    for _ in range(iters):
        # SpMV
        y = []
        for i, cols in enumerate(rows):
            for j in cols:
                edges.append((x[j], nid))
            y.append(nid)
            nid += 1
        # global reduction (dot product) as a binary tree
        layer = y
        while len(layer) > 1:
            nxt = []
            for a in range(0, len(layer) - 1, 2):
                edges.append((layer[a], nid))
                edges.append((layer[a + 1], nid))
                nxt.append(nid)
                nid += 1
            if len(layer) % 2:
                nxt.append(layer[-1])
            layer = nxt
        dot = layer[0]
        # axpy: new x depends on old x, y and the scalar
        x2 = []
        for i in range(n):
            edges.append((x[i], nid))
            edges.append((y[i], nid))
            edges.append((dot, nid))
            x2.append(nid)
            nid += 1
        x = x2
    return Dag(n=nid, edge_list=edges, name=f"CG_N{n}_K{iters}")


def knn_dag(n: int = 30, k: int = 4, iters: int = 3, seed: int = 0) -> Dag:
    """k-NN style: each new value depends on k nearest previous values."""
    rng = np.random.default_rng(seed)
    edges = []
    cur = list(range(n))
    nid = n
    for _ in range(iters):
        nxt = []
        for i in range(n):
            window = np.clip(np.arange(i - k - 2, i + k + 3), 0, n - 1)
            window = np.unique(window)
            nbrs = set(rng.choice(window, size=min(k, len(window)),
                                  replace=False).tolist())
            nbrs.add(i)
            for j in nbrs:
                edges.append((cur[j], nid))
            nxt.append(nid)
            nid += 1
        cur = nxt
    return Dag(n=nid, edge_list=edges, name=f"kNN_N{n}_K{iters}")


def sptrsv_dag(n: int = 800, band: int = 32, fill: float = 0.0,
               seed: int = 0, p_cross: float = 0.06) -> Dag:
    """Lower-triangular solve dependencies with supernodal structure: rows
    form ``band`` interleaved strands (the paper's application matrices
    come from elimination trees with many independent subtrees).  A row
    depends on the previous 1-2 rows of its own strand plus occasional
    cross-strand couplings -- wavefront depth ~ n/band, ancestor cones stay
    sparse, so both parallelism and communication pressure are realistic."""
    rng = np.random.default_rng(seed)
    strands = band
    edges = set()
    for i in range(strands, n):
        edges.add((i - strands, i))            # own strand
        if rng.random() < 0.35 and i - 2 * strands >= 0:
            edges.add((i - 2 * strands, i))
        if rng.random() < p_cross:             # cross-strand coupling
            off = int(rng.integers(1, strands))
            j = i - off
            if j >= 0:
                edges.add((j, i))
        if fill and rng.random() < fill:
            j = int(rng.integers(0, i))
            edges.add((j, i))
    return Dag(n=n, edge_list=sorted(edges), name=f"sptrsv_{n}")


def psdd_dag(n_leaves: int = 200, depth: int = 14, seed: int = 0) -> Dag:
    """Irregular arithmetic circuit: random sum/product units over earlier
    units, fan-in 2 (products) or 2-4 (sums), single root-ish top layer."""
    rng = np.random.default_rng(seed)
    edges = []
    nodes = list(range(n_leaves))
    nid = n_leaves
    per_layer = max(8, n_leaves // 2)
    for d in range(depth):
        layer_size = max(4, int(per_layer * (0.85 ** d)))
        new = []
        lo = max(0, len(nodes) - 3 * per_layer)
        for _ in range(layer_size):
            fanin = 2 if rng.random() < 0.6 else int(rng.integers(2, 5))
            srcs = rng.choice(np.arange(lo, len(nodes)), size=min(fanin, len(nodes) - lo),
                              replace=False)
            for s in srcs:
                edges.append((int(nodes[s]), nid))
            new.append(nid)
            nid += 1
        nodes.extend(new)
    return Dag(n=nid, edge_list=edges, name=f"psdd_{nid}")


# ------------------------------------------------------ streaming generators
# Flat-numpy builders for multilevel-scale instances (mirroring
# ``spmv.large_row_net``): the per-row python loops of ``sptrsv_dag`` /
# ``psdd_dag`` spend seconds in rng calls and tuple churn at n = 100k;
# these draw every random decision as one vectorized batch and construct
# the Dag through ``Dag.from_arrays`` -- n = 100k builds in well under a
# second.  Same structural mix as the loop generators, parameterized the
# same way; n and seed are the knobs the scale benchmarks sweep.

def large_sptrsv_dag(n: int = 100_000, band: int = 48, fill: float = 0.0,
                     seed: int = 0, p_cross: float = 0.06) -> Dag:
    """Streaming ``sptrsv_dag``: banded strands + probabilistic second
    in-strand edge + cross-strand couplings + optional random fill, all as
    flat coordinate arrays."""
    rng = np.random.default_rng(seed)
    strands = band
    i = np.arange(strands, n, dtype=np.int64)
    srcs = [i - strands]
    dsts = [i]
    sel = i[(rng.random(len(i)) < 0.35) & (i >= 2 * strands)]
    srcs.append(sel - 2 * strands)
    dsts.append(sel)
    sel = i[rng.random(len(i)) < p_cross]
    off = rng.integers(1, strands, size=len(sel))
    keep = sel - off >= 0
    srcs.append(sel[keep] - off[keep])
    dsts.append(sel[keep])
    if fill:
        sel = i[rng.random(len(i)) < fill]
        j = np.floor(rng.random(len(sel)) * sel).astype(np.int64)
        srcs.append(j)
        dsts.append(sel)
    return Dag.from_arrays(n, np.concatenate(srcs), np.concatenate(dsts),
                           name=f"sptrsv_large_{n}")


def large_psdd_dag(n_leaves: int = 25_000, depth: int = 16,
                   seed: int = 0) -> Dag:
    """Streaming ``psdd_dag``: the same layered sum/product circuit shape
    (decaying layer sizes, fan-in 2 or 2-4, sources drawn from a recency
    window), one vectorized draw per layer; duplicate (child, source)
    picks collapse in the ``from_arrays`` dedup (slightly shrinking the
    occasional fan-in, as ``rng.choice(replace=False)`` would avoid)."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    nid = n_leaves
    per_layer = max(8, n_leaves // 2)
    for d in range(depth):
        layer_size = max(4, int(per_layer * (0.85 ** d)))
        lo = max(0, nid - 3 * per_layer)
        fanin = np.where(rng.random(layer_size) < 0.6, 2,
                         rng.integers(2, 5, size=layer_size))
        fanin = np.minimum(fanin, nid - lo)
        new = np.arange(nid, nid + layer_size, dtype=np.int64)
        dsts.append(np.repeat(new, fanin))
        srcs.append(rng.integers(lo, nid, size=int(fanin.sum()),
                                 dtype=np.int64))
        nid += layer_size
    return Dag.from_arrays(nid, np.concatenate(srcs), np.concatenate(dsts),
                           name=f"psdd_large_{nid}")


def hdb_dataset(scale: int = 1, seed: int = 0) -> list[Dag]:
    """Mixed hdb-like set (SpMV / CG / kNN / iterated matmul)."""
    out = [
        spmv_dag(n_rows=60 * scale, seed=seed),
        spmv_dag(n_rows=90 * scale, seed=seed + 1),
        iterated_matmul_dag(n=30 * scale, iters=4, seed=seed + 2),
        iterated_matmul_dag(n=40 * scale, iters=5, seed=seed + 3),
        cg_dag(n=16 * scale, iters=4, seed=seed + 4),
        cg_dag(n=24 * scale, iters=5, seed=seed + 5),
        knn_dag(n=40 * scale, k=4, iters=4, seed=seed + 6),
        knn_dag(n=50 * scale, k=5, iters=5, seed=seed + 7),
    ]
    return out


def sptrsv_dataset(scale: int = 1, seed: int = 0) -> list[Dag]:
    return [sptrsv_dag(n=n * scale, band=b, seed=seed + i)
            for i, (n, b) in enumerate([(600, 24), (800, 32), (1000, 32),
                                        (1200, 40), (1500, 48)])]


def psdd_dataset(scale: int = 1, seed: int = 0) -> list[Dag]:
    return [psdd_dag(n_leaves=nl * scale, depth=d, seed=seed + i)
            for i, (nl, d) in enumerate([(150, 10), (200, 12), (250, 14),
                                         (300, 12), (350, 16)])]


def tiny_dataset(seed: int = 0) -> list[Dag]:
    """40-80-node DAGs for the exact-vs-heuristic comparison (§C.2.2)."""
    out = []
    rng = np.random.default_rng(seed)
    for i in range(8):
        kind = i % 4
        if kind == 0:
            d = spmv_dag(n_rows=int(rng.integers(8, 14)), seed=seed + i)
        elif kind == 1:
            d = iterated_matmul_dag(n=int(rng.integers(8, 12)), iters=3, seed=seed + i)
        elif kind == 2:
            d = knn_dag(n=int(rng.integers(8, 12)), k=3, iters=2, seed=seed + i)
        else:
            d = psdd_dag(n_leaves=16, depth=4, seed=seed + i)
        out.append(d)
    return out
