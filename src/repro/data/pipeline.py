"""Deterministic, shard-aware synthetic data pipeline.

Every (step, shard) pair maps to the same tokens regardless of topology --
restarts and elastic re-sharding resume byte-identically (the fault-
tolerance tests rely on this).  Tokens come from a splitmix64 hash, with a
Zipf-flavored mapping into the vocab so MoE routers see non-uniform data.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..models.config import ModelConfig


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0


class SyntheticTokenStream:
    """Iterator over global batches; `state` is just the step counter, so
    checkpointing the pipeline is trivial."""

    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.dc = data_cfg
        self.step = step

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def next_batch(self) -> dict:
        B, S = self.dc.global_batch, self.dc.seq_len
        base = (np.uint64(self.dc.seed) << np.uint64(40)) \
            + (np.uint64(self.step) << np.uint64(20))
        idx = np.arange(B * (S + 1), dtype=np.uint64) + base * np.uint64(1_000_003)
        h = _splitmix64(idx).astype(np.float64) / 2.0 ** 64
        # Zipf-ish skew: u^3 concentrates mass on low token ids
        toks = (np.minimum(h ** 2.5, 0.999999) * self.cfg.vocab).astype(np.int32)
        toks = toks.reshape(B, S + 1)
        self.step += 1
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if self.cfg.frame_input:
            f = _splitmix64(idx[: B * S * 4]).astype(np.float64) / 2 ** 64
            frames = (f.reshape(B, S, 4) - 0.5).repeat(
                self.cfg.d_model // 4, axis=-1).astype(np.float32)
            out = {"frames": frames, "labels": out["labels"] % self.cfg.vocab}
        if self.cfg.n_image_tokens:
            g = _splitmix64(idx[: B * self.cfg.n_image_tokens]) \
                .astype(np.float64) / 2 ** 64
            out["image_embeds"] = np.tile(
                (g.reshape(B, self.cfg.n_image_tokens, 1) - 0.5),
                (1, 1, self.cfg.d_model)).astype(np.float32)
        out["labels"] = out["labels"] % self.cfg.vocab
        if "tokens" in out:
            out["tokens"] = out["tokens"] % self.cfg.vocab
        return out
