"""Benchmark harness: one function per paper table (deliverable d).

Prints ``name,us_per_call,derived`` CSV -- `derived` is the table's key
quantity (mean cost-reduction %, exact-gap %, roofline fraction ...).
Full-size runs: REPRO_BENCH_FULL=1.  JSON details land in
benchmarks/results/.
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

RESULTS = pathlib.Path(__file__).parent / "results"


def _emit(name: str, seconds: float, derived) -> None:
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)


def main() -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    from benchmarks import ilp_vs_heuristic, partitioning, scheduling
    from benchmarks import roofline as roof

    print("name,us_per_call,derived", flush=True)

    # ---- partitioning (paper Fig. 4 / Tables 1, 10-12) -------------------
    t0 = time.time()
    part = partitioning.run_all()
    (RESULTS / "partitioning.json").write_text(json.dumps(part, indent=1))
    for key in ("fig4_P2", "fig4_P4"):
        for ds, row in part[key].items():
            _emit(f"partition_{key}_{ds}", part["seconds"],
                  f"reduction={row['reduction_pct']:.1f}%;zeros={row['zeros']}")
    for eps, row in part["table1"].items():
        mean = sum(r["reduction_pct"] for r in row.values()) / len(row)
        _emit(f"partition_table1_{eps}", part["seconds"],
              f"mean_reduction={mean:.1f}%")
    _emit("partition_forms_DvsR", part["seconds"],
          f"wins={part['forms']['wins']}")

    # ---- partition-engine perf trajectory (machine-readable) -------------
    # BENCH_partition.json at the repo root: instances/sec and best cost per
    # dataset, plus old-vs-new engine throughput -- future PRs diff this.
    bench = {
        "engine_scale": part["engine"]["scale"],
        "replication_large": part["engine"]["replication_large"],
        "frontier_scale": part["frontier"]["scale"],
        "frontier_replication": part["frontier"]["replication"],
        "multilevel_scale": part["multilevel"]["scale"],
        "device_resident": part["device"],
        "parallel_scale": part["parallel"]["scale"],
        "datasets": {
            ds: {"instances_per_sec": row["instances_per_sec"],
                 "best_cost": min((r for _, r in row["pairs"]), default=0.0)}
            for ds, row in part["fig4_P4"].items()
        },
    }
    (pathlib.Path(__file__).resolve().parents[1]
     / "BENCH_partition.json").write_text(json.dumps(bench, indent=1))
    for row in part["engine"]["scale"]:
        spd = (f";speedup_vs_seed={row['speedup']:.1f}x"
               if "speedup" in row else "")
        _emit(f"partition_engine_n{row['n']}", row["engine_seconds"],
              f"inst_per_sec={row['engine_instances_per_sec']:.2f};"
              f"cost={row['engine_cost']:.0f}" + spd)
    for row in part["frontier"]["scale"]:
        jx = (f"speedup_jax={row['speedup_jax']:.2f}x;"
              if "speedup_jax" in row else "")
        _emit(f"partition_frontier_n{row['n']}", row["seconds_numpy"],
              f"speedup_numpy={row['speedup_numpy']:.2f}x;" + jx
              + f"cost={row['cost']:.0f}")
    frep = part["frontier"]["replication"]
    _emit(f"partition_frontier_rep_n{frep['n']}", frep["seconds_numpy"],
          f"speedup_numpy={frep['speedup_numpy']:.2f}x;"
          f"rep_cost={frep['rep_cost']:.0f}")
    for row in part["device"].get("scale", []):
        pi = (f";pallas_interpret={row['seconds_device_pallas_interpret']:.2f}s"
              if "seconds_device_pallas_interpret" in row else "")
        _emit(f"partition_device_n{row['n']}", row["seconds_device"],
              f"speedup_vs_numpy={row['speedup_vs_numpy']:.2f}x;"
              f"speedup_vs_perfront={row['speedup_vs_perfront']:.2f}x;"
              f"syncs={row['syncs']};commits={row['commits']}" + pi)
    for row in part["parallel"].get("scale", []):
        rel = (f"speedup_vs_w1={row['speedup_vs_w1']:.2f}x;"
               f"cost_vs_w1={row['cost_vs_w1_pct']:+.2f}%;"
               f"not_worse={row['cost_not_worse']};"
               if "speedup_vs_w1" in row else "")
        _emit(f"partition_parallel_n{row['n']}_w{row['workers']}",
              row["seconds"],
              rel + f"cpus={row['cpu_count']};rep_cost={row['rep_cost']:.0f}")
    for row in part["multilevel"]["scale"]:
        flat = (f"flat={row['flat_seconds']:.1f}s;"
                f"speedup={row['speedup']:.1f}x;"
                f"not_worse={row['cost_not_worse']};"
                if "flat_seconds" in row else "")
        _emit(f"partition_multilevel_n{row['n']}", row["ml_seconds"],
              flat + f"rep_cost={row['ml_rep_cost']:.0f};"
              f"reduction={row['ml_reduction_pct']:.1f}%")

    # ---- scheduling (paper Tables 2, 3, 4) -------------------------------
    sched = scheduling.run_all()
    (RESULTS / "scheduling.json").write_text(json.dumps(sched, indent=1))
    for ds, row in sched["table2"].items():
        for p, v in row.items():
            _emit(f"schedule_table2_{ds}_{p}", sched["seconds"],
                  f"basic={v['basic_pct']:.2f}%;advanced={v['advanced_pct']:.2f}%")
    for ds, row in sched["table3"].items():
        for gl, v in row.items():
            _emit(f"schedule_table3_{ds}_{gl}", sched["seconds"],
                  f"advanced={v['advanced_pct']:.2f}%")
    for ds, row in sched["table4"].items():
        _emit(f"schedule_table4_{ds}", sched["seconds"],
              ";".join(f"{k}={v:.2f}%" for k, v in row.items()))
    for sc, v in sched.get("table13", {}).items():
        _emit(f"schedule_table13_{sc}", sched["seconds"],
              f"n={v['n_range']};advanced={v['advanced_pct']:.2f}%")

    # ---- schedule-engine perf trajectory (machine-readable) --------------
    # BENCH_schedule.json at the repo root: old-vs-new heuristic throughput
    # at scale plus the cost-reduction trajectory -- future PRs diff this.
    sched_bench = {
        "engine_scale": sched["engine"],
        "frontier_scale": sched["frontier"],
        "multilevel_scale": sched["multilevel"],
        "split_scale": sched["split"],
        "device_resident": sched["device"],
        "cost_reduction": sched["table2"],
    }
    (pathlib.Path(__file__).resolve().parents[1]
     / "BENCH_schedule.json").write_text(json.dumps(sched_bench, indent=1))
    for row in sched["engine"]:
        _emit(f"schedule_engine_{row['name']}",
              row["engine_advanced_seconds"],
              f"speedup_advanced={row['speedup_advanced']:.1f}x;"
              f"speedup_baseline={row['speedup_baseline']:.1f}x;"
              f"cost={row['advanced_cost']:.0f};"
              f"costs_match={row['costs_match']}")
    for row in sched["frontier"]:
        _emit(f"schedule_frontier_{row['name']}",
              row["advanced_seconds_front"],
              f"hc_speedup={row['hill_climb_speedup']:.2f}x;"
              f"adv_speedup={row['advanced_speedup']:.2f}x;"
              f"adv_cost={row['advanced_cost_front']:.0f}")
    for row in sched["device"]:
        _emit(f"schedule_device_{row['name']}", row["seconds_device"],
              f"speedup_vs_numpy={row['speedup_vs_numpy']:.2f}x;"
              f"cost={row['cost']:.0f};probe_syncs={row['probe_syncs']}")
    for row in sched["multilevel"]:
        flat = (f"flat={row['flat_seconds']:.1f}s;"
                f"speedup={row['speedup']:.1f}x;"
                f"not_worse={row['cost_not_worse']};"
                f"vcycle_not_worse={row['vcycle_not_worse']};"
                if "flat_seconds" in row else "")
        _emit(f"schedule_multilevel_{row['name']}", row["ml_seconds"],
              flat + f"ml_cost={row['ml_cost']:.0f};"
              f"S={row['ml_supersteps']};replicas={row['ml_replicas']}")
    for row in sched["split"]:
        guarded = (f"guarded={row['guarded_seconds']:.1f}s;"
                   f"retired={row['guard_retired_seconds']:.1f}s;"
                   f"not_worse={row['split_not_worse_than_guarded']};"
                   if "guarded_seconds" in row else "")
        _emit(f"schedule_split_{row['name']}", row["split_seconds"],
              guarded + f"split_cost={row['split_cost']:.0f};"
              f"S={row['split_supersteps']}")

    # ---- exact vs heuristic (paper §C.2.2) -------------------------------
    ex = ilp_vs_heuristic.run_all()
    (RESULTS / "ilp_vs_heuristic.json").write_text(json.dumps(ex, indent=1))
    for p in ("P=2", "P=4"):
        _emit(f"schedule_exact_{p}", ex["seconds"],
              f"reduction={ex[p]['mean_reduction_pct']:.2f}%;"
              f"heuristic_gap={ex[p]['heuristic_gap_pct']:.2f}%")

    # ---- roofline table (from dry-run artifacts) -------------------------
    t0 = time.time()
    rows = roof.table()
    (RESULTS / "roofline.json").write_text(json.dumps(rows, indent=1))
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        _emit("roofline_cells", time.time() - t0,
              f"n={len(rows)};best={best['cell']}:"
              f"{best['roofline_fraction']*100:.1f}%;"
              f"worst={worst['cell']}:{worst['roofline_fraction']*100:.1f}%")
    else:
        _emit("roofline_cells", time.time() - t0,
              "no dry-run artifacts (run repro.launch.dryrun --all)")


def device_smoke() -> None:
    """``run.py --device-smoke``: CI-sized proof that the device-resident
    pass reproduces the numpy path bit-exactly (partition and schedule)."""
    from benchmarks import partitioning, scheduling
    out = {"partition": partitioning.device_smoke(),
           "schedule": scheduling.device_smoke()}
    print(json.dumps(out, indent=1))


def parallel_smoke() -> None:
    """``run.py --parallel-smoke``: CI-sized proof of the process-parallel
    V-cycle -- sharded matching bit-identity and a valid W=2 end-to-end
    run (skips cleanly where POSIX shared memory is unavailable)."""
    from benchmarks import partitioning
    print(json.dumps({"partition": partitioning.parallel_smoke()}, indent=1))


def schedule_split_smoke() -> None:
    """``run.py --schedule-split-smoke``: CI-sized proof of the guard
    retirement -- the guard-off split-enabled V-cycle must not cost more
    than the old guarded driver on replication-hungry psdd instances."""
    from benchmarks import scheduling
    print(json.dumps({"schedule": scheduling.split_smoke()}, indent=1))


if __name__ == "__main__":
    if "--device-smoke" in sys.argv:
        device_smoke()
    elif "--parallel-smoke" in sys.argv:
        parallel_smoke()
    elif "--schedule-split-smoke" in sys.argv:
        schedule_split_smoke()
    else:
        main()
