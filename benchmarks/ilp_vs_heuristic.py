"""Exact-vs-heuristic on tiny DAGs (paper §7.3 last part / §C.2.2).

The paper solves 40-80-node DAGs with a scheduling ILP (COPT, hours).  Our
near-exact solver enumerates compute assignments exhaustively (comm phases
by local search, see repro.core.schedule.exact), viable to ~30-45 nodes
here; we report (a) how close the heuristic baseline is to exact, and
(b) the exact-baseline -> replicated-heuristic reduction, the analogue of
the paper's 12.99% / 21.08% numbers for P=2 / P=4.

Each row additionally carries ``milp_lb``: the LP relaxation of an
S-superstep BSP scheduling ILP in the spirit of the paper's §C.1.1
formulation, solved by scipy's HiGHS backend (``optimize.milp`` with all
integrality relaxed -- always a valid lower bound on any replicated
schedule using at most S supersteps, the same cap the exact solver
searches under).  Import-guarded: scipy is an optional benchmark-only
dependency; tier-1 never touches it, and rows degrade to ``None`` when it
is absent.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.schedule import (BspInstance, advanced_heuristic,
                                 baseline_schedule, best_replicated_schedule,
                                 exact_schedule)
from repro.datagen import tiny_dataset

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def bsp_schedule_lb(inst: BspInstance, S: int = 3) -> float | None:
    """LP lower bound on any (replicated) BSP schedule with at most S
    supersteps.

    Variables (all relaxed to [0, 1]): ``x[v,p,s]`` -- v computed on p in
    superstep s; ``c[v,p,s]`` -- v's value received by p in superstep s;
    ``z[s]`` -- superstep s has a communication phase; plus continuous
    ``w[s]`` (work max) and ``h[s]`` (h-relation).  Constraints: every
    value computed somewhere; precedence (a compute needs each parent
    computed on the same processor by s or received before s); comm
    sources (a received value was computed somewhere else by s); per-
    processor work and recv loads under ``w``/``h``; total sent volume
    under ``P * h`` (the sender identity is relaxed away); any comm forces
    ``z``.  Every valid schedule induces a feasible 0/1 point of this
    system with objective equal to its true cost except that ``h`` under-
    approximates max(sent, recv) -- so the LP optimum is a lower bound.
    Returns ``None`` when scipy is unavailable or HiGHS fails.
    """
    try:
        from scipy import sparse
        from scipy.optimize import Bounds, LinearConstraint, milp
    except ImportError:
        return None
    dag, P = inst.dag, inst.P
    n = dag.n
    nx = n * P * S          # x block
    nzv = nx + n * P * S    # c block ends here
    # variable layout: x | c | z(S) | w(S) | h(S)
    def xi(v, p, s):
        return (v * P + p) * S + s

    def ci(v, p, s):
        return nx + (v * P + p) * S + s

    zi0, wi0, hi0 = nzv, nzv + S, nzv + 2 * S
    nvar = nzv + 3 * S
    rows, cols, vals, lb, ub = [], [], [], [], []
    r = 0

    def add(entries, lo, hi):
        nonlocal r
        for j, a in entries:
            rows.append(r)
            cols.append(j)
            vals.append(a)
        lb.append(lo)
        ub.append(hi)
        r += 1

    inf = np.inf
    for v in range(n):      # computed somewhere (replication: >= 1)
        add([(xi(v, p, s), 1.0) for p in range(P) for s in range(S)],
            1.0, inf)
    for v in range(n):      # precedence + comm source + latency link
        for p in range(P):
            for s in range(S):
                for u in dag.parents[v]:
                    ent = [(xi(v, p, s), 1.0)]
                    ent += [(xi(u, p, t), -1.0) for t in range(s + 1)]
                    ent += [(ci(u, p, t), -1.0) for t in range(s)]
                    add(ent, -inf, 0.0)
                ent = [(ci(v, p, s), 1.0)]
                ent += [(xi(v, q, t), -1.0) for q in range(P) if q != p
                        for t in range(s + 1)]
                add(ent, -inf, 0.0)
                add([(ci(v, p, s), 1.0), (zi0 + s, -1.0)], -inf, 0.0)
    for s in range(S):
        for p in range(P):  # loads
            add([(xi(v, p, s), float(dag.omega[v])) for v in range(n)]
                + [(wi0 + s, -1.0)], -inf, 0.0)
            add([(ci(v, p, s), float(dag.mu[v])) for v in range(n)]
                + [(hi0 + s, -1.0)], -inf, 0.0)
        add([(ci(v, p, s), float(dag.mu[v])) for v in range(n)
             for p in range(P)] + [(hi0 + s, -float(P))], -inf, 0.0)
    A = sparse.csr_matrix((vals, (rows, cols)), shape=(r, nvar))
    obj = np.zeros(nvar)
    obj[zi0:zi0 + S] = inst.L
    obj[wi0:wi0 + S] = 1.0
    obj[hi0:hi0 + S] = inst.g
    var_ub = np.ones(nvar)
    var_ub[wi0:] = np.inf
    res = milp(c=obj,
               constraints=LinearConstraint(A, np.asarray(lb), np.asarray(ub)),
               bounds=Bounds(np.zeros(nvar), var_ub),
               integrality=np.zeros(nvar))
    if not res.success:
        return None
    return float(res.fun)


def run_all(ps=(2, 4), g=4.0, L=5.0):
    dags = tiny_dataset()
    if not FULL:
        dags = [d for d in dags if d.n <= 45][:5]
    t0 = time.time()
    out = {}
    for P in ps:
        rows = []
        for dag in dags:
            inst = BspInstance(dag, P=P, g=g, L=L)
            heur = baseline_schedule(inst)
            ex = exact_schedule(inst, max_supersteps=3, time_limit=20.0,
                                ub_sched=heur)
            rep = best_replicated_schedule(inst, baseline=ex.schedule)
            lb = bsp_schedule_lb(inst, S=3)
            rows.append({
                "dag": dag.name, "n": dag.n,
                "exact_base": ex.cost,
                "heuristic_base": heur.current_cost(),
                "replicated": rep.current_cost(),
                "assignments_optimal": ex.assignments_optimal,
                # HiGHS LP bound over the same <= 3-superstep space the
                # exact solver searches; None when scipy is absent
                "milp_lb": lb,
                "lb_consistent": None if lb is None
                else bool(lb <= ex.cost + 1e-6),
            })
        ratios = [r["replicated"] / r["exact_base"] for r in rows
                  if r["exact_base"] > 0]
        gap = [r["heuristic_base"] / r["exact_base"] for r in rows
               if r["exact_base"] > 0]
        lb_gaps = [r["exact_base"] / r["milp_lb"] for r in rows
                   if r["milp_lb"] and r["exact_base"] > 0]
        out[f"P={P}"] = {
            "mean_reduction_pct":
                (1 - float(np.exp(np.mean(np.log(np.minimum(ratios, 1.0))))))
                * 100,
            "heuristic_gap_pct":
                (float(np.exp(np.mean(np.log(gap)))) - 1) * 100,
            "optimal_count": sum(r["assignments_optimal"] for r in rows),
            "lb_consistent_all": all(r["lb_consistent"] is not False
                                     for r in rows),
            "milp_lb_gap_pct": (float(np.exp(np.mean(np.log(lb_gaps)))) - 1)
            * 100 if lb_gaps else None,
            "rows": rows,
        }
    out["seconds"] = time.time() - t0
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run_all(), indent=1))
