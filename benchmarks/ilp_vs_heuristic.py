"""Exact-vs-heuristic on tiny DAGs (paper §7.3 last part / §C.2.2).

The paper solves 40-80-node DAGs with a scheduling ILP (COPT, hours).  Our
near-exact solver enumerates compute assignments exhaustively (comm phases
by local search, see repro.core.schedule.exact), viable to ~30-45 nodes
here; we report (a) how close the heuristic baseline is to exact, and
(b) the exact-baseline -> replicated-heuristic reduction, the analogue of
the paper's 12.99% / 21.08% numbers for P=2 / P=4.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.schedule import (BspInstance, advanced_heuristic,
                                 baseline_schedule, best_replicated_schedule,
                                 exact_schedule)
from repro.datagen import tiny_dataset

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def run_all(ps=(2, 4), g=4.0, L=5.0):
    dags = tiny_dataset()
    if not FULL:
        dags = [d for d in dags if d.n <= 45][:5]
    t0 = time.time()
    out = {}
    for P in ps:
        rows = []
        for dag in dags:
            inst = BspInstance(dag, P=P, g=g, L=L)
            heur = baseline_schedule(inst)
            ex = exact_schedule(inst, max_supersteps=3, time_limit=20.0,
                                ub_sched=heur)
            rep = best_replicated_schedule(inst, baseline=ex.schedule)
            rows.append({
                "dag": dag.name, "n": dag.n,
                "exact_base": ex.cost,
                "heuristic_base": heur.current_cost(),
                "replicated": rep.current_cost(),
                "assignments_optimal": ex.assignments_optimal,
            })
        ratios = [r["replicated"] / r["exact_base"] for r in rows
                  if r["exact_base"] > 0]
        gap = [r["heuristic_base"] / r["exact_base"] for r in rows
               if r["exact_base"] > 0]
        out[f"P={P}"] = {
            "mean_reduction_pct":
                (1 - float(np.exp(np.mean(np.log(np.minimum(ratios, 1.0))))))
                * 100,
            "heuristic_gap_pct":
                (float(np.exp(np.mean(np.log(gap)))) - 1) * 100,
            "optimal_count": sum(r["assignments_optimal"] for r in rows),
            "rows": rows,
        }
    out["seconds"] = time.time() - t0
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run_all(), indent=1))
