"""Targeted BENCH_schedule.json refresh for the guard-retirement sections.

Re-runs ``multilevel_scale`` (whose guard-free default now includes the
split front) and the new ``split_scale`` section, then the single
million-node sptrsv gate -- recorded in both sections from one run (at
that size both sections measure the identical default driver, so a second
multi-hour run would duplicate, not verify).  Checkpoints the JSON after
each section so a partial run still lands its finished rows.
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from benchmarks import scheduling as S  # noqa: E402

PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_schedule.json"


def main() -> None:
    bench = json.loads(PATH.read_text())

    if "--skip-multilevel" not in sys.argv:
        ml = S.multilevel_scale(sizes=[
            ("sptrsv", 3000), ("sptrsv", 6000), ("psdd", 4000),
            ("sptrsv", 50_000), ("psdd", 50_000), ("sptrsv", 100_000)])
        bench["multilevel_scale"] = ml
        PATH.write_text(json.dumps(bench, indent=1))
        print("multilevel_scale done", flush=True)
    ml = bench["multilevel_scale"]

    sp = S.split_scale(sizes=[
        ("sptrsv", 2000), ("sptrsv", 6000), ("sptrsv", 8192),
        ("psdd", 4000), ("sptrsv", 50_000), ("sptrsv", 100_000)])
    bench["split_scale"] = sp
    PATH.write_text(json.dumps(bench, indent=1))
    print("split_scale (<= 100k) done", flush=True)

    big = S.split_scale(sizes=[("sptrsv", 1_000_000)])
    row = big[0]
    bench["split_scale"] = sp + big
    bench["multilevel_scale"] = ml + [{
        "name": row["name"], "n": row["n"], "edges": row["edges"],
        "P": row["P"], "g": row["g"], "L": row["L"],
        "ml_seconds": row["split_seconds"],
        "vcycle_cost": row["split_cost"], "ml_cost": row["split_cost"],
        "ml_supersteps": row["split_supersteps"],
        "ml_replicas": row["split_replicas"],
    }]
    PATH.write_text(json.dumps(bench, indent=1))
    print("n=1e6 done", flush=True)


if __name__ == "__main__":
    main()
