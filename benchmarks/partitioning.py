"""Partitioning experiments (paper §7.2: Fig. 4, Tables 1, 10-12).

Replicates the paper's protocol on the synthetic dataset analogues:
non-replicating optimum (exact B&B on small instances, heuristic beyond)
vs replication (ILP/D and ILP/R semantics: capped / unlimited replicas),
cost-reduction ratio = 1 - geomean(repl/base), zero-cost cases counted
separately -- exactly the paper's metric (§7.1).

``bench_engine`` additionally tracks the incremental-gain engine's
throughput against the preserved seed implementation
(``core.partition.reference``) at instance sizes the seed could not touch;
its output lands in ``BENCH_partition.json`` via ``run.py``.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.partition import (exact_partition, is_valid, partition_cost,
                                  partition_heuristic,
                                  partition_with_replication,
                                  replicate_local_search)
from repro.core.partition.reference import partition_heuristic_reference
from repro.datagen import large_row_net, moe_dataset, spmv_dataset
from repro.datagen.spmv import row_net_hypergraph, synthetic_sparse_matrix

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def _datasets(count: int):
    return {
        "spmv-fg": spmv_dataset("fg", count=count, sizes=(14, 26)),
        "spmv-rn": spmv_dataset("rn", count=count, sizes=(28, 60)),
        # paper parameters: kappa_0 = 1000, DeepSeek-like 256 experts
        "moe-8": moe_dataset("moe8", n_layers=count, kappa0=1000,
                             n_experts=256),
        "moe-2": moe_dataset("moe2", n_layers=count, kappa0=1000,
                             n_experts=256),
    }


def solve_pair(hg, P, eps, mode, exact_limit=18, time_limit=8.0, seed=0):
    """(base_cost, repl_cost, optimal?) for one instance."""
    from repro.core.partition import partition_with_replication
    if hg.n <= exact_limit:
        base = exact_partition(hg, P, eps, mode="none", time_limit=time_limit)
        ub = replicate_local_search(hg, base.masks.copy(), P, eps,
                                    max_replicas=2 if mode == "dup" else None,
                                    seed=seed)
        rep = exact_partition(hg, P, eps, mode=mode, time_limit=time_limit,
                              ub_masks=ub.masks)
        return base.cost, min(rep.cost, ub.cost), base.optimal and rep.optimal
    base, rep = partition_with_replication(hg, P, eps, mode=mode,
                                           exact_node_limit=0, seed=seed)
    return base.cost, rep.cost, False


def mean_reduction(pairs):
    """Paper metric: 1 - geomean(ratio) over instances with base > 0 and
    repl > 0; returns (reduction_pct, zero_count)."""
    ratios, zeros = [], 0
    for b, r in pairs:
        if b <= 0:
            continue
        if r <= 0:
            zeros += 1
            continue
        ratios.append(min(r / b, 1.0))
    red = (1.0 - float(np.exp(np.mean(np.log(ratios))))) * 100 if ratios else 0.0
    return red, zeros


def fig4_reductions(P=2, eps=0.025, count=None):
    """Fig. 4 analogue: per-dataset mean cost reduction."""
    count = count or (5 if FULL else 3)
    out = {}
    for name, ds in _datasets(count).items():
        pairs = []
        t0 = time.perf_counter()
        for hg in ds:
            b, r, _ = solve_pair(hg, P, eps, mode="rep")
            pairs.append((b, r))
        dt = time.perf_counter() - t0
        red, zeros = mean_reduction(pairs)
        out[name] = {"reduction_pct": red, "zeros": zeros,
                     "pairs": [(float(b), float(r)) for b, r in pairs],
                     "seconds": dt,
                     "instances_per_sec": len(ds) / dt if dt > 0 else 0.0}
    return out


def table1_eps_sweep(P=2, count=None):
    """Table 1: reductions grow with eps (P=2)."""
    count = count or (3 if FULL else 2)
    ds = _datasets(count)
    out = {}
    for eps in (0.0125, 0.025, 0.05):
        row = {}
        for name, insts in ds.items():
            pairs = [solve_pair(hg, P, eps, "rep")[:2] for hg in insts]
            red, zeros = mean_reduction(pairs)
            row[name] = {"reduction_pct": red, "zeros": zeros}
        out[f"eps={eps}"] = row
    return out


def table_forms(P=4, eps=0.05, count=None):
    """Tables 10/5-style: ILP/D vs ILP/R comparison."""
    count = count or (4 if FULL else 3)
    wins = {"same": 0, "D": 0, "R": 0}
    reductions = {"dup": [], "rep": []}
    for name, ds in _datasets(count).items():
        for hg in ds:
            b, rd, _ = solve_pair(hg, P, eps, mode="dup")
            _, rr, _ = solve_pair(hg, P, eps, mode="rep")
            if abs(rd - rr) < 1e-9:
                wins["same"] += 1
            elif rd < rr:
                wins["D"] += 1
            else:
                wins["R"] += 1
            reductions["dup"].append((b, rd))
            reductions["rep"].append((b, rr))
    out = {"wins": wins}
    for m in ("dup", "rep"):
        red, zeros = mean_reduction(reductions[m])
        out[m] = {"reduction_pct": red, "zeros": zeros}
    return out


def bench_engine(P=4, eps=0.05, seed=0):
    """Old-vs-new engine throughput at growing instance sizes.

    The seed implementation re-ran exact set cover per candidate move; the
    engine prices moves in O(degree).  The reference is only timed up to
    ``ref_limit`` nodes (beyond that a single run takes minutes -- exactly
    the scaling wall this PR removes); engine-only rows keep growing.
    Returns rows with instances/sec and best cost, plus replication results
    at the largest size.
    """
    sizes = (128, 256, 512, 1024, 2048) if FULL else (128, 256, 512, 1024)
    ref_limit = 512
    rows = []
    for n in sizes:
        nz = synthetic_sparse_matrix(n, n, seed=seed + n)
        hg = row_net_hypergraph(nz, n, name=f"spmv_rn_{n}")
        t0 = time.perf_counter()
        new = partition_heuristic(hg, P, eps, seed=seed)
        t_new = time.perf_counter() - t0
        assert is_valid(hg, new.masks, P, eps)
        row = {
            "n": hg.n, "edges": len(hg.edges), "pins": int(hg.num_pins),
            "P": P, "eps": eps,
            "engine_seconds": t_new,
            "engine_instances_per_sec": 1.0 / t_new,
            "engine_cost": float(new.cost),
        }
        if hg.n <= ref_limit:
            t0 = time.perf_counter()
            _, ref_cost = partition_heuristic_reference(hg, P, eps, seed=seed)
            t_ref = time.perf_counter() - t0
            row.update(ref_seconds=t_ref, ref_cost=float(ref_cost),
                       speedup=t_ref / t_new,
                       cost_not_worse=bool(new.cost <= ref_cost + 1e-9))
        rows.append(row)
    # replication on the largest instance: the end-to-end path at a size
    # the seed search could not finish in reasonable time
    nz = synthetic_sparse_matrix(sizes[-1], sizes[-1], seed=seed)
    hg = row_net_hypergraph(nz, sizes[-1], name="spmv_rn_large")
    t0 = time.perf_counter()
    base, rep = partition_with_replication(hg, P, eps, mode="rep",
                                           exact_node_limit=0, seed=seed)
    t_rep = time.perf_counter() - t0
    large = {"n": hg.n, "base_cost": float(base.cost),
             "rep_cost": float(rep.cost), "seconds": t_rep,
             "reduction_pct": (100.0 * (1 - rep.cost / base.cost)
                               if base.cost > 0 else 0.0)}
    return {"scale": rows, "replication_large": large}


def bench_frontier(P=4, eps=0.05, seed=0):
    """Frontier layer old-vs-new at scale (PR 3 tentpole).

    Times ``partition_heuristic`` with the pre-frontier per-node rescan
    (``frontier="off"``), the batched NumPy front path (default) and the
    JAX backend (Pallas gain kernel on TPU, jnp fallback elsewhere --
    included for the record; on CPU device dispatch costs more than the
    batched reduction saves).  All three are decision-identical, so the
    only deliverable difference is wall-clock; a cost mismatch is a bug.
    Also times the end-to-end replication pipeline old-vs-new at the
    smallest size.
    """
    sizes = (2048, 4096, 6000)
    try:  # the jax rows are optional: the rest of the repo runs numpy-only
        import jax  # noqa: F401
        modes = ("off", "numpy", "jax")
    except ImportError:
        modes = ("off", "numpy")
    rows = []
    for n in sizes:
        nz = synthetic_sparse_matrix(n, n, seed=seed + n)
        hg = row_net_hypergraph(nz, n, name=f"spmv_rn_{n}")
        timings, costs = {}, {}
        for mode in modes:
            if mode == "jax":
                # untimed run first: front sizes are padded per instance
                # size, so this compiles exactly the jit shapes the timed
                # run uses (steady-state, not compilation)
                partition_heuristic(hg, P, eps, seed=seed, frontier="jax")
            t0 = time.perf_counter()
            res = partition_heuristic(hg, P, eps, seed=seed, frontier=mode)
            timings[mode] = time.perf_counter() - t0
            costs[mode] = float(res.cost)
        assert len(set(costs.values())) == 1, costs
        row = {
            "n": hg.n, "edges": len(hg.edges), "pins": int(hg.num_pins),
            "P": P, "eps": eps, "cost": costs["numpy"],
            "seconds_off": timings["off"],
            "seconds_numpy": timings["numpy"],
            "speedup_numpy": timings["off"] / timings["numpy"],
        }
        if "jax" in timings:
            row["seconds_jax"] = timings["jax"]
            row["speedup_jax"] = timings["off"] / timings["jax"]
        rows.append(row)
    # end-to-end replication pipeline, old vs new front pricing
    n = sizes[0]
    nz = synthetic_sparse_matrix(n, n, seed=seed)
    hg = row_net_hypergraph(nz, n, name="spmv_rn_rep")
    t0 = time.perf_counter()
    base_off, rep_off = partition_with_replication(
        hg, P, eps, mode="rep", exact_node_limit=0, seed=seed, frontier="off")
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    base_on, rep_on = partition_with_replication(
        hg, P, eps, mode="rep", exact_node_limit=0, seed=seed)
    t_on = time.perf_counter() - t0
    assert rep_off.cost == rep_on.cost and base_off.cost == base_on.cost
    replication = {"n": n, "base_cost": float(base_on.cost),
                   "rep_cost": float(rep_on.cost),
                   "seconds_off": t_off, "seconds_numpy": t_on,
                   "speedup_numpy": t_off / t_on}
    return {"scale": rows, "replication": replication}


def bench_multilevel(P=8, eps=0.05, seed=0, sizes=None, flat_limit=None):
    """Flat vs multilevel V-cycle at scale (PR 4 tentpole).

    End-to-end ``partition_with_replication`` on streaming row-net
    instances: the V-cycle path (``multilevel=True``) at every size, the
    flat path up to ``flat_limit`` (beyond it a single flat run takes
    minutes -- the scaling wall the V-cycle removes).  Wherever both run,
    the V-cycle's final cost must be at or below the flat cost
    (``cost_not_worse``); rows land in ``BENCH_partition.json`` as
    ``multilevel_scale`` via ``run.py``.
    """
    sizes = sizes or ((4096, 8192, 16384, 32768, 65536) if FULL
                      else (4096, 8192, 16384, 65536))
    flat_limit = flat_limit if flat_limit is not None else \
        (16384 if FULL else 8192)
    rows = []
    for n in sizes:
        hg = large_row_net(n, seed=seed + n)
        t0 = time.perf_counter()
        base, rep = partition_with_replication(hg, P, eps, seed=seed,
                                               multilevel=True)
        t_ml = time.perf_counter() - t0
        assert is_valid(hg, rep.masks, P, eps)
        row = {
            "n": hg.n, "edges": len(hg.edges), "pins": int(hg.num_pins),
            "P": P, "eps": eps,
            "ml_seconds": t_ml,
            "ml_base_cost": float(base.cost),
            "ml_rep_cost": float(rep.cost),
            "ml_reduction_pct": (100.0 * (1 - rep.cost / base.cost)
                                 if base.cost > 0 else 0.0),
        }
        if hg.n <= flat_limit:
            t0 = time.perf_counter()
            fbase, frep = partition_with_replication(
                hg, P, eps, exact_node_limit=0, seed=seed)
            t_flat = time.perf_counter() - t0
            row.update(flat_seconds=t_flat,
                       flat_base_cost=float(fbase.cost),
                       flat_rep_cost=float(frep.cost),
                       speedup=t_flat / t_ml,
                       cost_not_worse=bool(rep.cost <= frep.cost + 1e-9))
        rows.append(row)
    return {"scale": rows}


def bench_device_resident(P=4, eps=0.05, seed=0, sizes=None,
                          interpret_row=True):
    """Device-resident FM pass vs per-front dispatch vs numpy (PR 6).

    Times one ``fm_refine`` call per variant on integer-weight row-net
    instances: the numpy frontier (PR 3 host path), the per-front jax
    dispatch (PR 3 jax path, forced by raising the device floor above n),
    the whole-pass device-resident program (one host sync per committed
    move), and -- at the smallest size only, interpret mode is slow -- the
    Pallas find-pricing path.  All variants are decision-identical, so a
    cost mismatch is a bug; host-sync counters come from an instrumented
    ``run_fm`` on the same instance and land in ``BENCH_partition.json``
    as ``device_resident`` via ``run.py``.

    The ``price_*`` fields isolate the pricing deliverable: one fused
    device scan over every candidate row of a pass vs the PR 3 per-front
    dispatch (host row gather + one ``min_cover_lambdas`` call per
    front) -- the fused path wins on CPU (~2.3x at n=8192, 262k rows).
    End-to-end ``seconds_device`` still trails numpy on CPU because each
    committed move costs a find dispatch plus an apply dispatch (the
    one-sync contract); the commit-batching follow-up and the compiled
    TPU path are ROADMAP open item 3.
    """
    try:
        import jax  # noqa: F401
    except ImportError:
        return {"scale": [], "available": False}
    from repro.kernels import front_pass, gain

    sizes = sizes or ((8192, 16384, 32768) if FULL else (4096, 8192))
    # generators trim empty rows, so instances land slightly under the
    # nominal size -- pin the attach floor below the smallest instance for
    # the duration of the bench (the per-front variant force-raises it
    # per size anyway)
    floor_saved = front_pass.DEVICE_MIN_NODES
    front_pass.DEVICE_MIN_NODES = min(min(sizes) // 2, floor_saved)
    try:
        rows = _device_resident_rows(sizes, P, eps, seed, interpret_row)
    finally:
        front_pass.DEVICE_MIN_NODES = floor_saved
    return {"scale": rows, "available": True,
            "kernel_cache": gain.kernel_cache_stats()}


def _device_resident_rows(sizes, P, eps, seed, interpret_row):
    from repro.core.partition import PartitionState
    from repro.core.partition.cost import capacity
    from repro.core.partition.heuristic import fm_refine, greedy_initial
    from repro.kernels import front_pass, gain, ops
    from repro.core.frontier import device_pass

    rows = []
    for n in sizes:
        hg = large_row_net(n, seed=seed + n)
        m0 = greedy_initial(hg, P, eps, np.random.default_rng(seed))

        def timed(frontier, warm=False):
            if warm:  # compile the jit shape family before the timed run
                st = PartitionState(hg, P, masks=m0.copy())
                fm_refine(hg, m0.copy(), P, eps, np.random.default_rng(seed),
                          state=st, frontier=frontier)
            st = PartitionState(hg, P, masks=m0.copy())
            t0 = time.perf_counter()
            fm_refine(hg, m0.copy(), P, eps, np.random.default_rng(seed),
                      state=st, frontier=frontier)
            return time.perf_counter() - t0, float(st.cost)

        t_np, c_np = timed("numpy")
        saved = front_pass.DEVICE_MIN_NODES
        front_pass.DEVICE_MIN_NODES = n + 1      # force per-front dispatch
        try:
            t_pf, c_pf = timed("jax", warm=True)
        finally:
            front_pass.DEVICE_MIN_NODES = saved
        t_dev, c_dev = timed("jax", warm=True)
        assert c_np == c_pf == c_dev, (n, c_np, c_pf, c_dev)

        # instrumented run: host syncs per committed move
        st = PartitionState(hg, P, masks=m0.copy())
        dev = device_pass(st, capacity(hg, P, eps) + 1e-9, backend="jax")
        try:
            dev.run_fm(np.random.default_rng(seed), 6)
            # counter snapshot BEFORE the pricing microbench below -- its
            # extra find dispatches are timing probes, not sweep syncs
            counters = {"syncs": dev.syncs, "commits": dev.commits,
                        "pass_scans": dev.pass_scans,
                        "apply_dispatches": dev.apply_dispatches}
            # pricing microbench (the acceptance row): every candidate row
            # of a full pass, priced by one fused device scan (what each
            # find dispatches) vs the PR 3 per-front path (host row gather
            # + one min_cover_lambdas call per front) over the same rows
            all_bnd = np.ones(hg.n, dtype=bool)
            reps = 5
            t0 = time.perf_counter()
            for _ in range(reps):
                dev._call_find(dev._find_fm, 0, 0, -1, 0, all_bnd)
            t_fused = (time.perf_counter() - t0) / reps
            edges_np = np.asarray(dev._blk_edge).ravel()
            n_rows = edges_np.size
            t0 = time.perf_counter()
            for _ in range(reps):
                for lo in range(0, n_rows, dev.R_blk):
                    rows_h = st.uncov[np.minimum(edges_np[lo:lo + dev.R_blk],
                                                 len(hg.edges) - 1)]
                    lam = gain.min_cover_lambdas(rows_h, st._order,
                                                 st._order_pc)
                    np.argmin(np.maximum(lam - 1, 0))
            t_perfront = (time.perf_counter() - t0) / reps
        finally:
            dev.detach()
        row = {
            "n": hg.n, "edges": len(hg.edges), "pins": int(hg.num_pins),
            "P": P, "eps": eps, "cost": c_np,
            "seconds_numpy": t_np,
            "seconds_perfront_jax": t_pf,
            "seconds_device": t_dev,
            "speedup_vs_numpy": t_np / t_dev,
            "speedup_vs_perfront": t_pf / t_dev,
            **counters,
            "front_rows": int(n_rows),
            "price_seconds_fused": t_fused,
            "price_seconds_perfront": t_perfront,
            "price_speedup": t_perfront / max(t_fused, 1e-9),
        }
        if interpret_row and n == sizes[0]:
            ops.force("pallas")
            try:
                t_pi, c_pi = timed("jax", warm=True)
            finally:
                ops.force(None)
            assert c_pi == c_np, (n, c_pi, c_np)
            row["seconds_device_pallas_interpret"] = t_pi
        rows.append(row)
    return rows


def bench_parallel(P=8, eps=0.05, seed=0, sizes=None, workers=(1, 2, 4, 8)):
    """Worker-count sweep of the process-parallel V-cycle (PR 7 tentpole).

    End-to-end ``partition_with_replication(..., multilevel=True,
    workers=W)`` on the same streaming row-net instances as
    ``bench_multilevel``, W swept over ``workers``.  Wall-clock speedup is
    reported against the W=1 run *on this box* together with
    ``cpu_count`` -- on a single-core container every W>1 row is pure
    overhead (fork + shared-memory setup + reconciliation replay) and the
    honest speedup is < 1; the sweep still proves the sharded path end to
    end, and ``cost_vs_w1_pct``/``cost_not_worse`` disclose how the
    reconciled cost compares to serial at every size.  Rows land in
    ``BENCH_partition.json`` as ``parallel_scale`` via ``run.py``.
    """
    from repro.core.partition import parallel as par
    if not par.shm_available():
        return {"scale": [], "available": False}
    sizes = sizes or ((16384, 65536) if FULL else (16384,))
    rows = []
    for n in sizes:
        hg = large_row_net(n, seed=seed + n)
        w1 = None
        for W in workers:
            t0 = time.perf_counter()
            base, rep = partition_with_replication(
                hg, P, eps, seed=seed, multilevel=True, workers=W)
            t = time.perf_counter() - t0
            assert is_valid(hg, rep.masks, P, eps)
            row = {
                "n": hg.n, "edges": len(hg.edges), "pins": int(hg.num_pins),
                "P": P, "eps": eps, "workers": W,
                "cpu_count": os.cpu_count(),
                "seconds": t,
                "base_cost": float(base.cost), "rep_cost": float(rep.cost),
            }
            if W == 1:
                w1 = (t, float(rep.cost))
            else:
                row["speedup_vs_w1"] = w1[0] / t
                row["cost_vs_w1_pct"] = (100.0 * (rep.cost - w1[1]) / w1[1]
                                         if w1[1] > 0 else 0.0)
                row["cost_not_worse"] = bool(rep.cost <= w1[1] + 1e-9)
            rows.append(row)
    return {"scale": rows, "available": True}


def parallel_smoke(P=4, eps=0.1, seed=0):
    """CI-sized proof of the parallel layer (``run.py --parallel-smoke``):
    sharded matching must be bit-identical to serial, and the W=2
    end-to-end V-cycle must produce a valid, rep-not-worse partition."""
    from repro.core.partition import parallel as par
    from repro.core.partition.multilevel import heavy_pin_matching
    out = {"available": par.shm_available(), "cpu_count": os.cpu_count()}
    if not out["available"]:
        return out
    hg = large_row_net(2048, seed=seed)
    cm_s, nc_s = heavy_pin_matching(hg, 50.0, np.random.default_rng(seed))
    with par.ParallelContext(2, min_nodes=64) as ctx:
        cm_p, nc_p = heavy_pin_matching(hg, 50.0,
                                        np.random.default_rng(seed), ctx=ctx)
        assert not ctx.failed, "pool failed; smoke must run the real path"
    assert nc_p == nc_s and np.array_equal(cm_p, cm_s)
    saved = par.PARALLEL_MIN_NODES
    par.PARALLEL_MIN_NODES = 256     # engage workers at smoke size
    try:
        t0 = time.perf_counter()
        base, rep = partition_with_replication(hg, P, eps, seed=seed,
                                               multilevel=True, workers=2)
        t = time.perf_counter() - t0
    finally:
        par.PARALLEL_MIN_NODES = saved
    assert is_valid(hg, rep.masks, P, eps)
    assert rep.cost <= base.cost + 1e-9
    out.update(n=hg.n, workers=2, seconds=t, cmap_bit_identical=True,
               base_cost=float(base.cost), rep_cost=float(rep.cost))
    return out


def device_smoke(P=4, eps=0.1, seed=0):
    """Small-n CI smoke (``run.py --device-smoke``): the device-resident
    pass must reproduce the numpy path bit-exactly on every push."""
    from repro.kernels import front_pass
    saved = front_pass.DEVICE_MIN_NODES
    front_pass.DEVICE_MIN_NODES = 1
    try:
        out = bench_device_resident(P=P, eps=eps, seed=seed, sizes=(1024,),
                                    interpret_row=True)
    finally:
        front_pass.DEVICE_MIN_NODES = saved
    for row in out["scale"]:    # cost equality is asserted inside; re-check
        # fused dispatch (PR 7): every committed move's apply rides in the
        # next find program, so a pure FM sweep is one sync per find --
        # one per commit plus at most one pass-ending scan per pass (a
        # pass whose last find commits at the final position ends without
        # another find) -- and dispatches zero standalone apply programs
        assert row["commits"] <= row["syncs"] <= (row["commits"]
                                                  + row["pass_scans"]), row
        assert row["apply_dispatches"] == 0, row
    return out


def multilevel_smoke(P=4, eps=0.1, seed=0):
    """Small-n CI smoke: exercise the whole V-cycle path on every push.

    Asserts validity, base >= rep, and final-cost parity (<=) against the
    flat path at a size where both run in seconds.
    """
    out = bench_multilevel(P=P, eps=eps, seed=seed, sizes=(1024, 2048),
                           flat_limit=2048)
    for row in out["scale"]:
        assert row["ml_rep_cost"] <= row["ml_base_cost"] + 1e-9
        assert row.get("cost_not_worse", True), row
    return out


def run_all():
    t0 = time.time()
    results = {}
    results["fig4_P2"] = fig4_reductions(P=2, eps=0.025)
    results["fig4_P4"] = fig4_reductions(P=4, eps=0.05)
    results["table1"] = table1_eps_sweep()
    results["forms"] = table_forms()
    results["engine"] = bench_engine()
    results["frontier"] = bench_frontier()
    results["multilevel"] = bench_multilevel()
    results["device"] = bench_device_resident()
    results["parallel"] = bench_parallel()
    results["seconds"] = time.time() - t0
    return results


if __name__ == "__main__":
    import json
    import sys
    if "--multilevel-smoke" in sys.argv:
        print(json.dumps(multilevel_smoke(), indent=1))
    elif "--parallel-smoke" in sys.argv:
        print(json.dumps(parallel_smoke(), indent=1))
    elif "--device-smoke" in sys.argv:
        print(json.dumps(device_smoke(), indent=1))
    else:
        print(json.dumps(run_all(), indent=1))
