import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing on the three selected cells (EXPERIMENTS.md SS-Perf).

Each iteration: hypothesis (napkin math from the analytic cost model) ->
change -> re-lower/re-compile (memory + compile validity) -> re-analyze ->
confirm/refute.  Artifacts land in benchmarks/results/dryrun/*__optN.json;
the before/after table prints here and is transcribed into EXPERIMENTS.md.

Cells (chosen per the assignment brief):
  A. yi-34b / train_4k / pod1        -- worst roofline fraction among the
     large dense models (unsharded 56-head attention; 28 GB/dev peak)
  B. deepseek-v3-671b / train_4k / pod2 -- most collective-bound cell
  C. olmoe-1b-7b / train_4k / pod1   -- the paper's own technique:
     replication-aware expert placement
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from benchmarks import roofline as roof                       # noqa: E402
from repro.launch.dryrun import RESULTS, run_cell             # noqa: E402


def show(tagline, r):
    print(f"  {tagline:34s} comp={r['compute_s']*1e3:9.1f}ms "
          f"mem={r['memory_s']*1e3:8.1f}ms coll={r['collective_s']*1e3:8.1f}ms "
          f"bound={r['bottleneck']:10s} roof={r['roofline_fraction']*100:5.1f}% "
          f"peak={r['peak_gb']:.1f}GB", flush=True)


def run_variant(arch, shape, multi_pod, tag, overrides=None, plan=None):
    cell = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}{tag}"
    path = RESULTS / f"{cell}.json"
    if path.exists():
        out = json.loads(path.read_text())
    else:
        out = run_cell(arch, shape, multi_pod, tag=tag, overrides=overrides,
                       plan=plan)
        path.write_text(json.dumps(out, indent=1))
    if out["status"] != "ok":
        print(f"  !! {cell}: {out['status']} {out.get('error','')[:300]}")
        return None
    return roof.analyze(out, overrides)


def cell_a():
    print("\n=== Cell A: yi-34b train_4k pod1 (worst-fraction dense) ===")
    base = run_variant("yi-34b", "train_4k", False, "")
    show("baseline", base)
    # Iter 1 -- hypothesis: 56 q-heads % 16 != 0 leaves every attention
    # projection replicated over the model axis (16x compute);
    # per-kv-group padding to 64 heads shards them at 14% pad waste.
    ov1 = {"n_heads_padded": 64}
    r1 = run_variant("yi-34b", "train_4k", False, "__opt1", overrides=ov1)
    if r1:
        show("opt1: pad heads 56->64", r1)
    # Iter 2 -- hypothesis: saved residuals (60 layers x B/dp x 4k x 7168)
    # dominate the 28 GB peak; sequence-parallel sharding divides them by
    # the model-axis extent.
    ov2 = {"n_heads_padded": 64, "seq_shard_activations": True}
    r2 = run_variant("yi-34b", "train_4k", False, "__opt2", overrides=ov2)
    if r2:
        show("opt2: + sequence-parallel acts", r2)
    # Iter 3 -- hypothesis: with compute fixed, the grad all-reduce and
    # optimizer traffic remain; ZeRO moments sharding cuts the optimizer
    # read/write bytes by dp.
    ov3 = dict(ov2, zero_opt_state=True)
    r3 = run_variant("yi-34b", "train_4k", False, "__opt3", overrides=ov3)
    if r3:
        show("opt3: + ZeRO optimizer state", r3)
    return [("baseline", base), ("opt1", r1), ("opt2", r2), ("opt3", r3)]


def cell_b():
    print("\n=== Cell B: deepseek-v3-671b train_4k pod2 (most collective-bound) ===")
    base = run_variant("deepseek-v3-671b", "train_4k", True, "")
    show("baseline", base)
    # Iter 1 -- hypothesis: the 313 GB/step f32-equivalent gradient ring
    # all-reduce dominates; ZeRO turns it into a bf16 reduce-scatter
    # (4x fewer bytes) and divides optimizer traffic by dp.
    ov1 = {"zero_opt_state": True}
    r1 = run_variant("deepseek-v3-671b", "train_4k", True, "__opt1",
                     overrides=ov1)
    if r1:
        show("opt1: ZeRO bf16 reduce-scatter", r1)
    # Iter 2 -- hypothesis: expert weights (656B of 671B params) replicated
    # over the data axis are the remaining memory+collective driver;
    # 'tp+ep_data' shards their d_model dim over data (persistent storage
    # /32, per-layer streamed gather).
    ov2 = {"zero_opt_state": True, "strategy": "tp+ep_data"}
    r2 = run_variant("deepseek-v3-671b", "train_4k", True, "__opt2",
                     overrides=ov2)
    if r2:
        show("opt2: + expert ep_data sharding", r2)
    return [("baseline", base), ("opt1", r1), ("opt2", r2)]


def cell_c():
    print("\n=== Cell C: olmoe-1b-7b train_4k pod1 (paper technique) ===")
    from repro.core.placement.expert_placement import plan_expert_placement
    from repro.datagen import synthetic_trace

    base = run_variant("olmoe-1b-7b", "train_4k", False, "")
    show("baseline (round-robin placement)", base)
    # Paper-faithful step: profile co-activation, partition WITH replication
    # (eps = spare expert-slot memory), route local-first.  The plan's
    # local fraction statically shrinks the MoE all_to_all buffers.
    trace = synthetic_trace(n_experts=64, n_tokens=50_000, top_k=8, seed=7)
    res = plan_expert_placement(trace, 64, 16, eps=1.0, kappa0=1000)
    print(f"  placement: lambda-cost {res.lambda_cost_no_repl:.0f} -> "
          f"{res.lambda_cost_repl:.0f} "
          f"(-{(1 - res.lambda_cost_repl / max(res.lambda_cost_no_repl, 1e-9)) * 100:.1f}%), "
          f"local fraction {res.local_fraction_no_repl:.3f} -> "
          f"{res.local_fraction_repl:.3f}")
    r1 = run_variant("olmoe-1b-7b", "train_4k", False, "__opt1",
                     overrides={"expert_placement":
                                (res.plan.local_fraction, 1.25)},
                     plan=res.plan)
    if r1:
        show("opt1: replicated placement", r1)
    # Beyond-paper: the calibration showed capacity padding costs ~2x the
    # useful expert FLOPs; the replicated plan's locality allows a tighter
    # capacity factor at equal drop rate.
    import dataclasses
    tight = dataclasses.replace(res.plan, capacity_factor=1.0)
    r2 = run_variant("olmoe-1b-7b", "train_4k", False, "__opt2",
                     overrides={"expert_placement":
                                (tight.local_fraction, 1.0)},
                     plan=tight)
    if r2:
        show("opt2: + capacity factor 1.25->1.0", r2)
    return [("baseline", base), ("opt1", r1), ("opt2", r2)]


def cell_d():
    """Extra (beyond the required three): hymba-1.5b train_4k."""
    print("\n=== Cell D: hymba-1.5b train_4k pod1 (hybrid, compute-bound) ===")
    base = run_variant("hymba-1.5b", "train_4k", False, "")
    show("baseline", base)
    # Hypothesis: 25 heads % 16 != 0 leaves the attention half of every
    # hybrid mixer replicated 16x; per-kv-group padding needs G_pad s.t.
    # 5*G_pad % 16 == 0 -> 80 physical heads (3.2x pad waste but /16
    # sharding; cost model predicts 2.26x total FLOP reduction).
    r1 = run_variant("hymba-1.5b", "train_4k", False, "__opt1",
                     overrides={"n_heads_padded": 80})
    if r1:
        show("opt1: pad heads 25->80", r1)
    return [("baseline", base), ("opt1", r1)]


def cell_e():
    """Extra: deepseek-v3-671b decode_32k (memory-bound class).

    Hypothesis: naive MLA decode re-expands every cached latent to full
    K/V each step (34 TFLOP + 250 GB/step/dev); absorbing W_UK/W_UV into
    the query/output keeps attention in the 576-dim latent space --
    cost model predicts 47x flops, 2.4x HBM reduction."""
    print("\n=== Cell E: deepseek-v3-671b decode_32k pod1 (memory-bound) ===")
    naive = run_variant("deepseek-v3-671b", "decode_32k", False, "__naive",
                        overrides={"mla_absorb": False,
                                   "strategy": "tp+ep_data"})
    if naive:
        show("naive latent re-expansion", naive)
    absorbed = run_variant("deepseek-v3-671b", "decode_32k", False, "__opt1",
                           overrides={"mla_absorb": True,
                                      "strategy": "tp+ep_data"})
    if absorbed:
        show("opt1: absorbed-weight MLA", absorbed)
    return [("naive", naive), ("opt1", absorbed)]


def main():
    out = {"A_yi34b": cell_a(), "B_dsv3": cell_b(), "C_olmoe": cell_c(),
           "D_hymba": cell_d(), "E_v3_decode": cell_e()}
    serializable = {
        k: [(tag, r) for tag, r in v if r is not None]
        for k, v in out.items()
    }
    (pathlib.Path(__file__).parent / "results" / "hillclimb.json").write_text(
        json.dumps(serializable, indent=1, default=float))
    print("\n[hillclimb] results saved")


if __name__ == "__main__":
    main()
