"""Validate the analytic roofline cost model against XLA cost analysis.

HloCostAnalysis counts while-loop (scan) bodies once, so validation uses
*unrolled* builds: for a given arch family we compile a 1-layer and a
2-layer python-loop (no scan) variant of the forward pass at moderate
shapes and check that the analytic per-layer FLOP increment matches the
XLA-measured increment.  Attention/MLP/MoE families validate directly;
SSM mixers are excluded from the FLOP check (their XLA reference path
still contains the sequential time scan -- the analytic model uses the
Pallas kernel's cost by design; the kernel itself is validated vs the
oracle in tests/test_kernels.py).

Run:  PYTHONPATH=src python -m benchmarks.calibration
"""
from __future__ import annotations

import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import Segment
from repro.models.model import Model
from repro.roofline.model import step_cost


def _unrolled_forward(cfg, n_layers: int):
    """Forward pass with python-loop layers (no scan -> XLA counts all)."""
    segs = tuple(dataclasses.replace(s, n_layers=n_layers)
                 for s in cfg.segments[:1])
    cfg1 = cfg.with_(segments=segs, remat="none", mtp_depth=0)
    model = Model(cfg1)

    def fwd(params, batch):
        x = model._embed_inputs(params, batch)
        img = batch.get("image_embeds")
        seg = cfg1.segments[0]
        sp = params["segments"][0]
        for i in range(n_layers):
            lp = jax.tree.map(lambda w: w[i], sp)
            x, _ = model._block(lp, x, seg, "dense", img=img)
        return model.logits_fn(params, x)

    return cfg1, model, fwd


def measured_layer_flops(arch: str, B: int, S: int,
                         mesh=None) -> float:
    from repro.parallel import sharding as shd
    cfg = get_config(arch)
    out = {}
    for n in (1, 2):
        cfg1, model, fwd = _unrolled_forward(cfg, n)
        if mesh is not None:
            model.plan = __import__(
                "repro.models.moe", fromlist=["round_robin_plan"]
            ).round_robin_plan(cfg.n_experts, mesh.shape["model"])
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        batch = {("frames" if cfg.frame_input else "tokens"):
                 jax.ShapeDtypeStruct(
                     (B, S, cfg.d_model) if cfg.frame_input else (B, S),
                     jnp.dtype(cfg.dtype) if cfg.frame_input else jnp.int32)}
        if cfg.n_image_tokens:
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if mesh is not None:
            shd.set_active_mesh(mesh)
            try:
                with shd.use_mesh(mesh):
                    def fwd_moe(params, batch, model=model, cfg1=cfg1, n=n):
                        x = model._embed_inputs(params, batch)
                        seg = cfg1.segments[0]
                        sp = params["segments"][0]
                        for i in range(n):
                            lp = jax.tree.map(lambda w: w[i], sp)
                            x, _ = model._block(lp, x, seg, "a2a")
                        return model.logits_fn(params, x)
                    psh = shd.tree_shardings(params, mesh, cfg1.strategy)
                    params_sh = jax.tree.map(
                        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                                          sharding=s),
                        params, psh)
                    from jax.sharding import NamedSharding, PartitionSpec as P
                    batch_sh = {k: jax.ShapeDtypeStruct(
                        v.shape, v.dtype,
                        sharding=NamedSharding(mesh, P(
                            "data", *([None] * (len(v.shape) - 1)))))
                        for k, v in batch.items()}
                    lowered = jax.jit(fwd_moe).lower(params_sh, batch_sh)
                    cost = lowered.compile().cost_analysis()
            finally:
                shd.set_active_mesh(None)
        else:
            lowered = jax.jit(fwd).lower(params, batch)
            cost = lowered.compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        out[n] = float(cost["flops"])
    return out[2] - out[1]


def analytic_layer_flops(arch: str, B: int, S: int, dp: int = 1,
                         tp: int = 1) -> float:
    cfg = get_config(arch)
    seg = cfg.segments[0]
    one = cfg.with_(segments=(dataclasses.replace(seg, n_layers=1),),
                    mtp_depth=0, remat="none")
    two = cfg.with_(segments=(dataclasses.replace(seg, n_layers=2),),
                    mtp_depth=0, remat="none")
    c1 = step_cost(one, B, S, S, dp, tp, "prefill")
    c2 = step_cost(two, B, S, S, dp, tp, "prefill")
    return c2["flops"] - c1["flops"]


# vision excluded: its 4 self sub-layers sit inside an inner scan XLA
# can't count; the per-sublayer formulas are the dense-family ones, which
# validate at <2% (yi, deepseek-7b).  SSM archs excluded by design (the
# analytic model costs the Pallas kernel path; see module docstring).
ARCHS = ["smollm-135m", "deepseek-7b", "yi-34b", "olmoe-1b-7b",
         "deepseek-v3-671b", "hubert-xlarge"]


def run(verbose: bool = True) -> dict:
    B, S = 1, 512
    results = {}
    for arch in ARCHS:
        is_moe = get_config(arch).n_experts > 0
        mesh = None
        if is_moe:
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
        want = measured_layer_flops(arch, B if not is_moe else 8,
                                    S, mesh=mesh)
        dp, tp = (2, 4) if is_moe else (1, 1)
        have = analytic_layer_flops(arch, B if not is_moe else 8, S,
                                    dp=dp, tp=tp)
        rel = abs(have - want) / want
        results[arch] = {"xla": want, "analytic": have, "rel_err": rel}
        if verbose:
            print(f"[calibration] {arch:24s} xla={want:.4g} "
                  f"analytic={have:.4g} rel_err={rel*100:.1f}%", flush=True)
    return results


if __name__ == "__main__":
    res = run()
    worst = max(r["rel_err"] for r in res.values())
    print(f"[calibration] worst relative error: {worst*100:.1f}%")
