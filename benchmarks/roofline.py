"""Roofline assembly (deliverable g).

Terms per (arch x shape x mesh) cell:
    compute    = FLOPs_per_chip / 197e12        (bf16 peak, TPU v5e)
    memory     = HBM_bytes_per_chip / 819e9
    collective = collective_bytes_per_chip / 50e9 (ICI link)

FLOPs / HBM bytes / collective bytes come from the analytic per-layer cost
model (src/repro/roofline/model.py) -- XLA's HloCostAnalysis does not scale
while-loop (scan) bodies by trip count, so the compiled numbers undercount
by ~n_layers; the analytic model is validated against unrolled calibration
compiles (benchmarks/calibration.py, <=9% err).  Peak HBM per device comes
from the real 512-device compile (buffer assignment is loop-aware).

MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (serve); useful-compute ratio =
MODEL_FLOPS / (analytic FLOPs x chips); roofline fraction = useful model
FLOP rate at the bottleneck-implied step time vs chip peak.
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import SHAPES, get_config          # noqa: E402
from repro.roofline.model import step_cost            # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = pathlib.Path(__file__).parent / "results" / "dryrun"


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    for f in sorted(RESULTS.glob("*.json")):
        d = json.loads(f.read_text())
        name = d.get("cell", f.stem)
        if tag and not name.endswith(tag):
            continue
        if not tag and not name.endswith(("__pod1", "__pod2")):
            continue  # tagged variants (__optN/__naive) are SS-Perf artifacts
        cells.append(d)
    return cells


def analyze(cell: dict, overrides: dict | None = None) -> dict | None:
    if cell.get("status") != "ok":
        return None
    cfg = get_config(cell["arch"])
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[cell["shape"]]
    mesh = cell["mesh"]
    dp = mesh.get("data", 1) * mesh.get("pod", 1)
    tp = mesh.get("model", 1)
    if shape.kind == "decode":
        B, S, K = shape.global_batch, 1, shape.seq_len
    else:
        B, S, K = shape.global_batch, shape.seq_len, shape.seq_len
    c = step_cost(cfg, B, S, K, dp, tp, shape.kind)
    terms = {"compute": c["flops"] / PEAK_FLOPS,
             "memory": c["hbm_bytes"] / HBM_BW,
             "collective": c["coll_bytes"] / LINK_BW}
    bottleneck = max(terms, key=terms.get)
    tokens = B * S
    n_act = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_act * tokens
    chips = cell["chips"]
    useful = model_flops / (c["flops"] * chips) if c["flops"] else 0.0
    t_step = max(terms.values())
    frac = (model_flops / chips / PEAK_FLOPS) / t_step if t_step else 0.0
    return {
        "cell": cell["cell"],
        "arch": cell["arch"],
        "shape": cell["shape"],
        "mesh": "x".join(str(v) for v in mesh.values()),
        "chips": chips,
        "compute_s": terms["compute"],
        "memory_s": terms["memory"],
        "collective_s": terms["collective"],
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "peak_gb": (cell["memory"]["peak_bytes"] or 0) / 2**30,
    }


def improvement_hint(r: dict) -> str:
    if r["bottleneck"] == "memory":
        if r["shape"] in ("decode_32k", "long_500k"):
            return ("decode is weight/KV-bandwidth bound: quantize weights/"
                    "KV or raise batch to amortize weight reads")
        return ("shard saved activations over the model axis (sequence "
                "parallelism) / cut optimizer-state traffic")
    if r["bottleneck"] == "collective":
        return ("reduce TP all-reduce volume: sequence-parallel boundaries, "
                "bf16 grad reduce, or (MoE) replication-aware placement to "
                "shrink all_to_all buffers")
    return "compute-bound: close to the right regime; tune tiling/fusion"


def table(tag: str = "", overrides: dict | None = None) -> list[dict]:
    out = []
    for c in load_cells(tag):
        a = analyze(c, overrides)
        if a:
            out.append(a)
    return out


def main() -> None:
    rows = table()
    hdr = (f"{'cell':52s} {'comp(ms)':>9s} {'mem(ms)':>9s} {'coll(ms)':>9s} "
           f"{'bound':>6s} {'useful':>7s} {'roof%':>6s} {'peakGB':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(f"{r['cell']:52s} {r['compute_s']*1e3:9.2f} "
              f"{r['memory_s']*1e3:9.2f} {r['collective_s']*1e3:9.2f} "
              f"{r['bottleneck'][:6]:>6s} {r['useful_ratio']:7.2f} "
              f"{r['roofline_fraction']*100:6.1f} {r['peak_gb']:7.2f}")
    over = [r for r in rows if r["peak_gb"] > 16]
    if over:
        print(f"\n{len(over)} cells exceed 16 GB v5e HBM "
              f"(see EXPERIMENTS.md SS-Dry-run for the mitigation notes):")
        for r in over:
            print(f"  {r['cell']}: {r['peak_gb']:.1f} GB")


if __name__ == "__main__":
    main()
