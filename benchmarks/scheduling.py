"""Scheduling experiments (paper §7.3: Tables 2, 3, 4/14).

Protocol mirrors the paper: for each DAG, build the strong non-replicating
baseline (BSPg list scheduling + hill climbing, best-of incl. sequential),
then apply the basic and advanced replication heuristics; report mean cost
reduction = 1 - geomean(repl/base).  Dataset sizes are scaled to this
container's single CPU core (paper: 1k-175k nodes on a 128-thread EPYC);
the generators accept any scale.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.schedule import (AdvancedOptions, BspInstance,
                                 MultilevelScheduleOptions,
                                 advanced_heuristic, baseline_schedule,
                                 basic_heuristic, best_replicated_schedule,
                                 bspg_schedule, hill_climb)
from repro.core.schedule import reference as ref
from repro.datagen import (hdb_dataset, large_psdd_dag, large_sptrsv_dag,
                           psdd_dag, psdd_dataset, spmv_dag, sptrsv_dag,
                           sptrsv_dataset)

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def _datasets():
    # scale=2/3 keeps enough work per processor that parallel schedules
    # beat sequential even at g=16 / L=400 (the paper's DAGs are 1k-175k
    # nodes; too-small instances degenerate the comparison)
    if FULL:
        return {"hdb": hdb_dataset(scale=3), "psdd": psdd_dataset(),
                "sptrsv": sptrsv_dataset(scale=2)}
    return {
        "hdb": hdb_dataset(scale=3)[:4],
        "psdd": psdd_dataset()[:3],
        "sptrsv": sptrsv_dataset(scale=2)[:2],
    }


def _geo_reduction(ratios):
    ratios = [min(max(r, 1e-9), 1.0) for r in ratios]
    return (1.0 - float(np.exp(np.mean(np.log(ratios))))) * 100


def reductions_for(dag, P, g, L, opts=None, seed=0):
    """Paper protocol (§6.1): the comparison baseline is the BSPg +
    hill-climbing PARALLEL schedule; replication is introduced into it.
    (Our framework also keeps a sequential candidate -- often better for
    tiny graphs at huge g/L, cf. §C.2.2 -- but the paper's ratios are
    parallel-baseline vs parallel+replication.)"""
    inst = BspInstance(dag, P=P, g=float(g), L=float(L))
    base = hill_climb(bspg_schedule(inst, seed=seed), seed=seed)
    c0 = base.current_cost()
    basic = basic_heuristic(base.copy())
    adv = advanced_heuristic(base.copy(), opts)
    return c0, basic.current_cost(), adv.current_cost()


def table2_p_sweep(ps=None, g=4, L=20):
    ps = ps or ((2, 4, 8, 16) if FULL else (4, 8))
    out = {}
    for name, ds in _datasets().items():
        row = {}
        for P in ps:
            basics, advs = [], []
            for dag in ds:
                c0, cb, ca = reductions_for(dag, P, g, L)
                basics.append(cb / c0)
                advs.append(ca / c0)
            row[f"P={P}"] = {"basic_pct": _geo_reduction(basics),
                             "advanced_pct": _geo_reduction(advs)}
        out[name] = row
    return out


def table3_gl_sweep(P=8):
    combos = ((4, 20), (1, 20), (16, 20), (4, 1), (4, 400)) if FULL \
        else ((4, 20), (16, 20), (4, 400))
    out = {}
    for name, ds in _datasets().items():
        row = {}
        for g, L in combos:
            basics, advs = [], []
            for dag in ds:
                c0, cb, ca = reductions_for(dag, P, g, L)
                basics.append(cb / c0)
                advs.append(ca / c0)
            row[f"g={g},L={L}"] = {"basic_pct": _geo_reduction(basics),
                                   "advanced_pct": _geo_reduction(advs)}
        out[name] = row
    return out


def table4_ablation(P=8, g=4, L=20):
    """Activate single components of the advanced heuristic (B, B+BR,
    B+SM, B+SR) -- paper Table 4."""
    variants = {
        "B": AdvancedOptions(False, False, False),
        "B+BR": AdvancedOptions(True, False, False),
        "B+SM": AdvancedOptions(False, True, False),
        "B+SR": AdvancedOptions(False, False, True),
        "B+BR+SM+SR": AdvancedOptions(True, True, True),
    }
    out = {}
    for name, ds in _datasets().items():
        row = {}
        bases = table4_bases(ds, P, g, L)
        for vname, opts in variants.items():
            ratios = []
            for base in bases:
                c0 = base.current_cost()
                c = advanced_heuristic(base.copy(), opts).current_cost()
                ratios.append(c / c0)
            row[vname] = _geo_reduction(ratios)
        out[name] = row
    return out


def table4_bases(ds, P, g, L):
    return [hill_climb(bspg_schedule(BspInstance(d, P=P, g=float(g),
                                                 L=float(L)), seed=0), seed=0)
            for d in ds]


def table13_size_consistency(P=8, g=4, L=20):
    """Paper Table 13: improvements are consistent across instance sizes."""
    out = {}
    scales = (2, 3, 4) if FULL else (2, 4)
    for scale in scales:
        ds = hdb_dataset(scale=scale)[:3]
        advs = []
        for dag in ds:
            c0, _, ca = reductions_for(dag, P, g, L)
            advs.append(ca / c0)
        out[f"scale={scale}"] = {
            "n_range": [min(d.n for d in ds), max(d.n for d in ds)],
            "advanced_pct": _geo_reduction(advs),
        }
    return out


def engine_scale(P=8, g=4, L=20):
    """Old-vs-new throughput of the scheduling stack at scale.

    Runs the engine-backed pipeline and the preserved seed implementation
    (``reference.py``) on the same instances; final costs must be identical
    (the engine changes mechanics, not decisions), so the only deliverable
    difference is wall-clock.  Always measured at full scale -- DAG sizes
    where the seed's copy-per-trial pricing dominates (the paper's DAGs are
    1k-175k nodes) -- since the whole comparison fits in well under a
    minute; the seed side is the slow one and it runs exactly once per
    instance.
    """
    instances = [
        ("sptrsv_6000", sptrsv_dag(n=6000, band=48, seed=0)),
        ("sptrsv_3000", sptrsv_dag(n=3000, band=32, seed=0)),
        ("psdd_2035", psdd_dag(n_leaves=500, depth=16, seed=0)),
        ("hdb_spmv_2061", spmv_dag(n_rows=400, seed=0)),
    ]
    rows = []
    for name, dag in instances:
        inst = BspInstance(dag, P=P, g=float(g), L=float(L))
        t0 = time.time()
        new_hc = hill_climb(bspg_schedule(inst, seed=0), seed=0)
        t1 = time.time()
        new_adv = advanced_heuristic(new_hc.copy())
        t2 = time.time()
        ref_hc = ref.hill_climb(ref.bspg_schedule(inst, seed=0), seed=0)
        t3 = time.time()
        ref_adv = ref.advanced_heuristic(ref_hc.copy())
        t4 = time.time()
        rows.append({
            "name": name, "n": dag.n, "P": P,
            "engine_baseline_seconds": t1 - t0,
            "engine_advanced_seconds": t2 - t1,
            "seed_baseline_seconds": t3 - t2,
            "seed_advanced_seconds": t4 - t3,
            "speedup_baseline": (t3 - t2) / max(t1 - t0, 1e-9),
            "speedup_advanced": (t4 - t3) / max(t2 - t1, 1e-9),
            "baseline_cost": float(new_hc.current_cost()),
            "advanced_cost": float(new_adv.current_cost()),
            "costs_match": bool(
                float(new_hc.current_cost()) == float(ref_hc.current_cost())
                and float(new_adv.current_cost()) == float(ref_adv.current_cost())),
        })
    return rows


def frontier_scale(P=8, g=4, L=20):
    """Frontier layer old-vs-new on the scheduling stack (PR 3 tentpole).

    Per instance: the hill climber with node moves priced per-target
    (``use_fronts=False``, the pre-frontier loop) vs one batched front per
    node, and the advanced heuristic with the first-improvement SR sweep
    vs the frontier SR pass (whole ``(p1, p2)`` front priced purely, only
    the winner committed through a transaction).  The hill-climb pair is
    decision-identical (costs must match); the SR pair deliberately
    differs in decision rule, so both costs are recorded.
    """
    instances = [
        ("sptrsv_6000", sptrsv_dag(n=6000, band=48, seed=0)),
        ("sptrsv_3000", sptrsv_dag(n=3000, band=32, seed=0)),
        ("psdd_2035", psdd_dag(n_leaves=500, depth=16, seed=0)),
    ]
    rows = []
    for name, dag in instances:
        inst = BspInstance(dag, P=P, g=float(g), L=float(L))
        base = bspg_schedule(inst, seed=0)
        t0 = time.perf_counter()
        hc_on = hill_climb(base.copy(), seed=0)
        t1 = time.perf_counter()
        hc_off = hill_climb(base.copy(), seed=0, use_fronts=False)
        t2 = time.perf_counter()
        adv_on = advanced_heuristic(hc_on.copy())
        t3 = time.perf_counter()
        adv_off = advanced_heuristic(hc_on.copy(),
                                     AdvancedOptions(use_fronts=False))
        t4 = time.perf_counter()
        assert hc_on.current_cost() == hc_off.current_cost()
        rows.append({
            "name": name, "n": dag.n, "P": P,
            "hill_climb_seconds_front": t1 - t0,
            "hill_climb_seconds_off": t2 - t1,
            "hill_climb_speedup": (t2 - t1) / max(t1 - t0, 1e-9),
            "advanced_seconds_front": t3 - t2,
            "advanced_seconds_off": t4 - t3,
            "advanced_speedup": (t4 - t3) / max(t3 - t2, 1e-9),
            "hill_climb_cost": float(hc_on.current_cost()),
            "advanced_cost_front": float(adv_on.current_cost()),
            "advanced_cost_off": float(adv_off.current_cost()),
        })
    return rows


def multilevel_scale(P=8, g=4, L=20, sizes=None, flat_limit=None, seed=0):
    """Flat vs multilevel V-cycle scheduling at scale (PR 5 tentpole).

    End-to-end ``best_replicated_schedule`` on sptrsv/psdd instances: the
    *pure* V-cycle (``flat_guard_n=0``, so ``ml_seconds``/``vcycle_cost``
    measure the V-cycle itself, not a hidden flat run) at every size, the
    flat path up to ``flat_limit`` nodes (beyond it a single flat run
    takes minutes to hours -- the scaling wall the V-cycle removes; the
    paper schedules up to 175k-node DAGs in exactly this coarse-grained
    regime).  ``ml_cost`` is what the default guarded driver returns --
    ``min(vcycle, flat)`` wherever both ran, the V-cycle alone beyond the
    guard -- so ``cost_not_worse`` holds by construction and
    ``vcycle_not_worse`` reports whether the V-cycle won organically.
    Rows land in ``BENCH_schedule.json`` as ``multilevel_scale`` via
    ``run.py``.
    """
    if sizes is None:
        sizes = ([("sptrsv", 3000), ("sptrsv", 6000), ("psdd", 4000),
                  ("sptrsv", 50_000), ("psdd", 50_000),
                  ("sptrsv", 100_000), ("sptrsv", 1_000_000)] if FULL else
                 [("sptrsv", 3000), ("sptrsv", 6000), ("psdd", 4000),
                  ("sptrsv", 50_000), ("psdd", 50_000)])
    flat_limit = flat_limit if flat_limit is not None else 8192
    rows = []
    for kind, n in sizes:
        if kind == "sptrsv":
            dag = (large_sptrsv_dag(n, band=48, seed=seed) if n > 8192
                   else sptrsv_dag(n=n, band=32 if n <= 3000 else 48,
                                   seed=seed))
        else:
            dag = large_psdd_dag(n_leaves=max(250, n // 4), depth=16,
                                 seed=seed)
        inst = BspInstance(dag, P=P, g=float(g), L=float(L))
        t0 = time.perf_counter()
        mlv = best_replicated_schedule(
            inst, seed=seed, multilevel=True,
            ml_opts=MultilevelScheduleOptions(flat_guard_n=0))
        t_ml = time.perf_counter() - t0
        assert mlv.validate() == []
        row = {
            "name": dag.name, "n": dag.n, "edges": dag.num_edges, "P": P,
            "g": g, "L": L,
            "ml_seconds": t_ml,
            "vcycle_cost": float(mlv.current_cost()),
            "ml_cost": float(mlv.current_cost()),
            "ml_supersteps": mlv.S,
            "ml_replicas": sum(len(a) - 1 for a in mlv.assign
                               if len(a) > 1),
        }
        if dag.n <= flat_limit:
            t0 = time.perf_counter()
            flat = best_replicated_schedule(inst, seed=seed)
            t_flat = time.perf_counter() - t0
            # what the old guarded driver (flat hedge on) would return and
            # cost -- guarded_seconds keeps the row honest about what
            # achieves ml_cost at which price, and guard_retired_seconds
            # is the flat hedge's wall-clock the guard-retired default
            # (PR 9) no longer pays
            guarded = float(min(mlv.current_cost(), flat.current_cost()))
            row.update(flat_seconds=t_flat,
                       flat_cost=float(flat.current_cost()),
                       ml_cost=guarded,
                       guarded_seconds=t_ml + t_flat,
                       guard_retired_seconds=t_flat,
                       speedup=t_flat / t_ml,
                       vcycle_not_worse=bool(mlv.current_cost()
                                             <= flat.current_cost() + 1e-9),
                       cost_not_worse=bool(guarded
                                           <= flat.current_cost() + 1e-9))
        rows.append(row)
    return rows


def split_scale(P=8, g=4, L=20, sizes=None, seed=0):
    """Guard retirement at scale (PR 9 tentpole).

    Per size, up to three end-to-end ``best_replicated_schedule`` variants
    on the same instance:

    * ``guarded``    -- the pre-PR 9 default (``flat_guard_n=8192``,
      splits off): the V-cycle plus one full flat hedge run.  Only at
      n <= 8192, where the flat path is tractable.
    * ``guard_free`` -- ``flat_guard_n=0``, splits off: what retiring the
      guard *without* the split front would return (capped at n <= 200k
      to keep the section's wall-clock sane).
    * ``split``      -- the new default: guard retired, split front on in
      every per-level refinement.  Runs at every size, including the
      n = 10^6 sptrsv row (FULL) -- the scale gate the guard used to
      make unreachable.

    Asserted per row wherever the guarded variant ran: the new default's
    cost is <= the old guarded cost (the PR 9 acceptance gate), while
    ``guard_retired_seconds`` -- guarded minus split wall-clock, i.e.
    what retiring the hedge saves end to end -- is disclosed.  Variants
    that did not run at a size are absent from the row, never silently
    extrapolated.

    Known non-parity instance (disclosed, not benched as a guarded row):
    psdd_large n=8165 (``large_psdd_dag(n_leaves=2000, depth=16)``) is a
    V-cycle fixpoint at 3814 where the flat trajectory reaches 3795
    (+0.5%); forced-split kicks plus full flat polish close it only to
    3800.  The gap is in the assignment structure, not the superstep
    structure -- the split front cannot reach it.  The psdd guarded row
    here runs n=4080, where the guard-free default beats the flat hedge
    outright (1903 vs 1926).
    """
    if sizes is None:
        sizes = ([("sptrsv", 2000), ("sptrsv", 6000), ("sptrsv", 8192),
                  ("psdd", 4000), ("sptrsv", 50_000), ("sptrsv", 100_000),
                  ("sptrsv", 1_000_000)] if FULL else
                 [("sptrsv", 2000), ("sptrsv", 6000), ("psdd", 4000)])
    rows = []
    for kind, n in sizes:
        if kind == "sptrsv":
            dag = (large_sptrsv_dag(n, band=48, seed=seed) if n > 8192
                   else sptrsv_dag(n=n, band=32 if n <= 3000 else 48,
                                   seed=seed))
        else:
            dag = large_psdd_dag(n_leaves=max(250, n // 4), depth=16,
                                 seed=seed)
        inst = BspInstance(dag, P=P, g=float(g), L=float(L))
        row = {"name": dag.name, "n": dag.n, "edges": dag.num_edges,
               "P": P, "g": g, "L": L}
        t0 = time.perf_counter()
        split = best_replicated_schedule(inst, seed=seed, multilevel=True)
        row["split_seconds"] = time.perf_counter() - t0
        row["split_cost"] = float(split.current_cost())
        row["split_supersteps"] = split.S
        row["split_replicas"] = sum(len(a) - 1 for a in split.assign
                                    if len(a) > 1)
        assert split.validate() == []
        if dag.n <= 200_000:
            t0 = time.perf_counter()
            gf = best_replicated_schedule(
                inst, seed=seed, multilevel=True,
                ml_opts=MultilevelScheduleOptions(superstep_splits=False))
            row["guard_free_seconds"] = time.perf_counter() - t0
            row["guard_free_cost"] = float(gf.current_cost())
        if dag.n <= 8192:
            t0 = time.perf_counter()
            guarded = best_replicated_schedule(
                inst, seed=seed, multilevel=True,
                ml_opts=MultilevelScheduleOptions(flat_guard_n=8192,
                                                  superstep_splits=False))
            row["guarded_seconds"] = time.perf_counter() - t0
            row["guarded_cost"] = float(guarded.current_cost())
            row["guard_retired_seconds"] = (row["guarded_seconds"]
                                            - row["split_seconds"])
            assert row["split_cost"] <= row["guarded_cost"] + 1e-9, row
            row["split_not_worse_than_guarded"] = True
        rows.append(row)
    return rows


def device_scale(P=8, g=4, L=20):
    """Device-window pricing vs numpy on the hill climber (PR 6).

    Integer-weight sptrsv/psdd instances run ``hill_climb`` with the numpy
    pricers and with the device window pricers (``backend="jax"``); both
    are decision-identical, so costs must match and the only deliverable
    difference is wall-clock.  A per-instance instrumented
    ``DeviceScheduleWindows`` records host syncs and full refreshes for
    the ``device_resident`` rows in ``BENCH_schedule.json``.
    """
    try:
        import jax  # noqa: F401
    except ImportError:
        return []
    from repro.core.frontier import device_windows

    instances = ([("sptrsv_6000", sptrsv_dag(n=6000, band=48, seed=0)),
                  ("psdd_2035", psdd_dag(n_leaves=500, depth=16, seed=0))]
                 if FULL else
                 [("sptrsv_3000", sptrsv_dag(n=3000, band=32, seed=0)),
                  ("psdd_2035", psdd_dag(n_leaves=500, depth=16, seed=0))])
    rows = []
    for name, dag in instances:
        inst = BspInstance(dag, P=P, g=float(g), L=float(L))
        base = bspg_schedule(inst, seed=0)
        t0 = time.perf_counter()
        hc_np = hill_climb(base.copy(), seed=0)
        t1 = time.perf_counter()
        hill_climb(base.copy(), seed=0, backend="jax")  # warm the jit cache
        t2 = time.perf_counter()
        hc_dev = hill_climb(base.copy(), seed=0, backend="jax")
        t3 = time.perf_counter()
        assert hc_np.current_cost() == hc_dev.current_cost(), name
        # instrumented sample: one full node-move pricing sweep
        probe = base.copy()
        win = device_windows(probe, "jax")
        syncs = refreshes = None
        if win is not None:
            for v in range(0, probe.inst.dag.n, 7):
                win.price_node_moves(v)
            syncs, refreshes = win.syncs, win.refreshes
        rows.append({
            "name": name, "n": dag.n, "P": P, "g": g, "L": L,
            "seconds_numpy": t1 - t0,
            "seconds_device": t3 - t2,
            "seconds_device_cold": t2 - t1,
            "speedup_vs_numpy": (t1 - t0) / max(t3 - t2, 1e-9),
            "cost": float(hc_np.current_cost()),
            "probe_syncs": syncs, "probe_refreshes": refreshes,
        })
    return rows


def device_smoke(P=4, g=2, L=4):
    """Small-n CI smoke: device-window hill climbing must match numpy
    bit-exactly on every push (floors dropped so the device path fires)."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return {"available": False}
    from repro.kernels import front_pass

    saved = (front_pass.DEVICE_MIN_WINDOW, front_pass.DEVICE_MIN_STEPS)
    front_pass.DEVICE_MIN_WINDOW = front_pass.DEVICE_MIN_STEPS = 1
    try:
        rows = []
        for n in (300, 600):
            dag = sptrsv_dag(n=n, band=16, seed=0)
            inst = BspInstance(dag, P=P, g=float(g), L=float(L))
            base = bspg_schedule(inst, seed=0)
            hc_np = hill_climb(base.copy(), seed=0)
            hc_dev = hill_climb(base.copy(), seed=0, backend="jax")
            assert hc_np.current_cost() == hc_dev.current_cost(), n
            assert hc_np.comms == hc_dev.comms and \
                hc_np.assign == hc_dev.assign, n
            rows.append({"n": dag.n, "cost": float(hc_np.current_cost())})
    finally:
        front_pass.DEVICE_MIN_WINDOW, front_pass.DEVICE_MIN_STEPS = saved
    return {"available": True, "rows": rows}


def multilevel_smoke(P=8, g=4, L=20):
    """Small-n CI smoke: exercise the whole scheduling V-cycle on every
    push -- coarsen, coarse solve, project, refine, replica-prune -- with
    validity and flat-parity asserts at sizes where both run in seconds.
    """
    opts = MultilevelScheduleOptions(coarsest_n=400, flat_guard_n=0)
    rows = []
    for n in (1500, 2500):
        dag = sptrsv_dag(n=n, band=32, seed=0)
        inst = BspInstance(dag, P=P, g=float(g), L=float(L))
        t0 = time.perf_counter()
        mlv = best_replicated_schedule(inst, seed=0, multilevel=True,
                                       ml_opts=opts)
        t_ml = time.perf_counter() - t0
        assert mlv.validate() == []
        flat = best_replicated_schedule(inst, seed=0)
        assert mlv.current_cost() <= flat.current_cost() + 1e-9, \
            (n, mlv.current_cost(), flat.current_cost())
        rows.append({"n": n, "ml_cost": float(mlv.current_cost()),
                     "flat_cost": float(flat.current_cost()),
                     "ml_seconds": t_ml})
    return {"multilevel_smoke": rows}


def split_smoke(P=8, g=4, L=20):
    """Small-n CI smoke (PR 9): on every push, the guard-retired default
    (splits on) must return a schedule no costlier than the old guarded
    driver's on a replication-hungry psdd instance -- the family the flat
    hedge existed for."""
    rows = []
    for n_leaves, depth in ((500, 12), (800, 12)):
        dag = psdd_dag(n_leaves=n_leaves, depth=depth, seed=1)
        inst = BspInstance(dag, P=P, g=float(g), L=float(L))
        t0 = time.perf_counter()
        mlv = best_replicated_schedule(inst, seed=0, multilevel=True)
        t_new = time.perf_counter() - t0
        assert mlv.validate() == []
        t0 = time.perf_counter()
        guarded = best_replicated_schedule(
            inst, seed=0, multilevel=True,
            ml_opts=MultilevelScheduleOptions(flat_guard_n=8192,
                                              superstep_splits=False))
        t_old = time.perf_counter() - t0
        assert mlv.current_cost() <= guarded.current_cost() + 1e-9, \
            (dag.n, mlv.current_cost(), guarded.current_cost())
        rows.append({"n": dag.n,
                     "split_cost": float(mlv.current_cost()),
                     "guarded_cost": float(guarded.current_cost()),
                     "split_seconds": t_new, "guarded_seconds": t_old,
                     "guard_retired_seconds": t_old - t_new})
    return {"split_smoke": rows}


def run_all():
    t0 = time.time()
    results = {
        "table2": table2_p_sweep(),
        "table3": table3_gl_sweep(),
        "table4": table4_ablation(),
        "table13": table13_size_consistency(),
        "engine": engine_scale(),
        "frontier": frontier_scale(),
        "multilevel": multilevel_scale(),
        "split": split_scale(),
        "device": device_scale(),
    }
    results["seconds"] = time.time() - t0
    return results


if __name__ == "__main__":
    import json
    import sys
    if "--schedule-multilevel-smoke" in sys.argv:
        print(json.dumps(multilevel_smoke(), indent=1))
    elif "--schedule-split-smoke" in sys.argv:
        print(json.dumps(split_smoke(), indent=1))
    else:
        print(json.dumps(run_all(), indent=1))
