"""Batched serving with prefill + KV-cache decode (reduced config, CPU).

    PYTHONPATH=src python examples/serve_batch.py [--arch hymba-1.5b]

Drives the same prefill/decode steps the production serving launcher
(repro.launch.serve) jits for the pod; --replicated-placement there adds
the paper's expert placement for MoE archs.
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models.model import Model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch), layers_per_segment=1)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S, G = args.requests, args.prompt_len, args.gen
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.n_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_image_tokens, cfg.d_model)), jnp.float32)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, S + G))
    decode = jax.jit(model.decode_step)
    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    outs = [np.asarray(tok)]
    for i in range(G - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(S + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        outs.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(outs, axis=1)
    print(f"{cfg.name}: {B} requests x (prefill {S} + decode {G}) "
          f"in {dt:.2f}s -> {B * G / dt:.1f} tok/s")
    print("sample continuation ids:", gen[0][:10].tolist())


if __name__ == "__main__":
    main()
