"""The paper's technique end-to-end: replication-aware MoE expert placement.

    PYTHONPATH=src python examples/moe_placement.py

1. Run a (reduced) OLMoE model and profile its router -> expert
   co-activation trace (`Model.route_trace`).
2. Build the moe-8 co-activation hypergraph (paper §B.1).
3. Partition experts over EP shards WITHOUT replication (baseline) and
   WITH replication (the paper's contribution) under the same memory
   budget eps.
4. Report the paper's (lambda_e - 1) communication metric and the
   resulting all_to_all buffer shrinkage the runtime realizes.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.placement.expert_placement import plan_expert_placement
from repro.models.model import Model
from repro.models.moe import a2a_capacities


def main() -> None:
    cfg = reduce_config(get_config("olmoe-1b-7b"), layers_per_segment=2)
    cfg = cfg.with_(n_experts=32, top_k=4)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab, (8, 128)).astype(np.int32)}
    print("profiling router co-activation on warmup traffic ...")
    traces = model.route_trace(params, batch)
    trace = np.sort(np.asarray(traces[0]).reshape(-1, cfg.top_k), axis=1)
    print(f"  trace: {trace.shape[0]} token-routings, top-{cfg.top_k} "
          f"of {cfg.n_experts} experts")

    n_shards = 8
    res = plan_expert_placement(trace, cfg.n_experts, n_shards, eps=0.5,
                                kappa0=min(1000, 4 * len(trace)))
    print(f"\npartitioning experts over {n_shards} EP shards (eps=0.5):")
    print(f"  (lambda-1) cost  no-replication: {res.lambda_cost_no_repl:.1f}")
    print(f"  (lambda-1) cost  with replication: {res.lambda_cost_repl:.1f}"
          f"  (-{(1 - res.lambda_cost_repl / max(res.lambda_cost_no_repl, 1e-9)) * 100:.1f}%)")
    print(f"  local token-choice fraction: {res.local_fraction_no_repl:.3f}"
          f" -> {res.local_fraction_repl:.3f}")
    reps = [res.plan.replicas(e) for e in range(cfg.n_experts)]
    print(f"  replicated experts: {sum(1 for r in reps if r > 1)} "
          f"(max replicas {max(reps)})")

    T_loc = 512
    for name, plan in (("round-robin", res.baseline_plan),
                       ("replicated", res.plan)):
        cl, cs, ci = a2a_capacities(plan, T_loc, cfg.top_k)
        a2a_bytes = 2 * plan.n_shards * cs * cfg.d_model * 2
        print(f"  {name:12s}: all_to_all buffer {a2a_bytes/1e3:.1f} kB/step"
              f" (cap_send={cs})")


if __name__ == "__main__":
    main()
