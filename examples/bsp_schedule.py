"""BSP scheduling with replication on an SpTRSV dependency DAG (paper §6).

    PYTHONPATH=src python examples/bsp_schedule.py [--n 800] [--P 8]
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.schedule import (BspInstance, advanced_heuristic,
                                 baseline_schedule, basic_heuristic)
from repro.datagen import sptrsv_dag


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=800)
    ap.add_argument("--P", type=int, default=8)
    ap.add_argument("--g", type=float, default=4.0)
    ap.add_argument("--L", type=float, default=20.0)
    args = ap.parse_args()

    dag = sptrsv_dag(n=args.n, seed=1)
    print(f"SpTRSV DAG: {dag.n} rows, {dag.num_edges} dependencies")
    inst = BspInstance(dag, P=args.P, g=args.g, L=args.L)

    base = baseline_schedule(inst)
    print(f"baseline (BSPg + hill climbing): cost {base.current_cost():.0f} "
          f"({base.S} supersteps, {len(base.comms)} comms)")
    b = basic_heuristic(base.copy())
    print(f"basic replication heuristic:     cost {b.current_cost():.0f} "
          f"({b.stats()['replicas']} replicas)"
          f"  [-{(1 - b.current_cost() / base.current_cost()) * 100:.2f}%]")
    a = advanced_heuristic(base.copy())
    print(f"advanced (BR+SM+SR):             cost {a.current_cost():.0f} "
          f"({a.S} supersteps, {a.stats()['replicas']} replicas)"
          f"  [-{(1 - a.current_cost() / base.current_cost()) * 100:.2f}%]")
    assert not a.validate(), "schedule invalid!"
    print("validity: OK (precedence + data availability checked)")


if __name__ == "__main__":
    main()
