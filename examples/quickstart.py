"""Quickstart: train a (reduced) assigned architecture end-to-end on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m]
        [--steps 30] [--full-135m]

Uses the real framework path: config registry -> Trainer (fault-tolerant
loop, atomic checkpoints, deterministic data) -> loss curve.  ``--full-135m``
trains the full 135M-parameter SmolLM config (slow on 1 CPU core; the same
command drives a pod via --production-mesh in repro.launch.train).

Multilevel partitioning path (PR 4):

    PYTHONPATH=src python examples/quickstart.py --multilevel [--n 8192]

runs the V-cycle partitioner (coarsen -> coarsest solve -> project ->
refine -> replicate) on a streaming spmv row-net instance and prints the
per-level cost trajectory plus the flat-heuristic comparison.

Multilevel scheduling path (PR 5):

    PYTHONPATH=src python examples/quickstart.py --multilevel-schedule
        [--n 20000] [--no-splits] [--workers W]

runs the acyclic-coarsening scheduling V-cycle (funnel/same-level
clustering -> coarse replicated solve -> schedule projection ->
frontier-priced refinement, superstep-split front included unless
--no-splits) on a streaming sptrsv DAG and prints the per-level cost
trajectory; --workers shards coarsening's scoring pass over a
shared-memory pool (bit-identical result).

Device-resident refinement path (PR 6):

    PYTHONPATH=src python examples/quickstart.py --device --backend jax
        [--n 4096]

runs one FM refinement pass twice -- numpy frontier vs the whole-pass
device-resident program (`kernels/front_pass.py`: persistent jnp state,
fused pricing, one host sync per committed move) -- and prints both
wall-clocks, the sync/commit counters and the bit-identity check.
"""
import argparse
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config, reduce_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def multilevel_demo(n: int, P: int = 8, eps: float = 0.05,
                    workers: int | None = None) -> None:
    """Partition a production-scale spmv row-net with the V-cycle."""
    from repro.core.partition import (is_valid, partition_heuristic,
                                      partition_with_replication_multilevel)
    from repro.datagen import large_row_net

    hg = large_row_net(n, seed=0)
    print(f"multilevel: {hg.name} n={hg.n} edges={len(hg.edges)} "
          f"pins={hg.num_pins} P={P} eps={eps}"
          + (f" workers={workers}" if workers else ""))
    stats: list = []
    t0 = time.perf_counter()
    base, rep = partition_with_replication_multilevel(hg, P, eps, seed=0,
                                                      stats=stats,
                                                      workers=workers)
    dt = time.perf_counter() - t0
    for row in stats:
        print(f"  level {row['level']:2d}  n={row['n']:7d}  "
              f"projected={row['cost_projected']:.0f}  "
              f"refined={row['cost_refined']:.0f}")
    assert is_valid(hg, rep.masks, P, eps)
    red = 100.0 * (1 - rep.cost / base.cost) if base.cost else 0.0
    print(f"V-cycle: base={base.cost:.0f} repl={rep.cost:.0f} "
          f"(-{red:.1f}%) in {dt:.1f}s")
    if n <= 8192:  # flat comparison only where the flat path is tractable
        t0 = time.perf_counter()
        flat = partition_heuristic(hg, P, eps, seed=0)
        print(f"flat baseline: cost={flat.cost:.0f} in "
              f"{time.perf_counter() - t0:.1f}s "
              f"(multilevel {'<=' if base.cost <= flat.cost else '>'} flat)")


def multilevel_schedule_demo(n: int, P: int = 8, g: float = 4.0,
                             L: float = 20.0, splits: bool = True,
                             workers: int | None = None) -> None:
    """Schedule a production-scale sptrsv DAG with the multilevel V-cycle."""
    from repro.core.schedule import (BspInstance,
                                     MultilevelScheduleOptions,
                                     best_replicated_schedule)
    from repro.datagen import large_sptrsv_dag

    dag = large_sptrsv_dag(n, band=48, seed=0)
    print(f"multilevel schedule: {dag.name} n={dag.n} "
          f"edges={dag.num_edges} P={P} g={g} L={L} "
          f"splits={'on' if splits else 'off'}"
          + (f" workers={workers}" if workers else ""))
    stats: list = []
    t0 = time.perf_counter()
    sched = best_replicated_schedule(
        BspInstance(dag, P=P, g=g, L=L), seed=0, multilevel=True,
        stats=stats, workers=workers,
        ml_opts=MultilevelScheduleOptions(superstep_splits=splits))
    dt = time.perf_counter() - t0
    for row in stats:
        if "level" in row:
            print(f"  level {row['level']:2d}  n={row['n']:7d}  "
                  f"S={row['S']:4d}  projected={row['cost_projected']:.0f}  "
                  f"refined={row['cost_refined']:.0f}")
        else:
            print(f"  flat guard: vcycle={row['vcycle_cost']:.0f}  "
                  f"flat={row['flat_cost']:.0f}")
    assert sched.validate() == []
    repl = sum(len(a) - 1 for a in sched.assign if len(a) > 1)
    print(f"V-cycle: cost={sched.current_cost():.0f} S={sched.S} "
          f"replicas={repl} in {dt:.1f}s")


def device_demo(n: int, backend: str = "jax", P: int = 4,
                eps: float = 0.05) -> None:
    """Run FM refinement host-side and device-resident; show bit-identity."""
    import numpy as np

    from repro.core.frontier import device_pass
    from repro.core.partition import PartitionState
    from repro.core.partition.cost import capacity
    from repro.core.partition.heuristic import fm_refine, greedy_initial
    from repro.datagen import large_row_net

    hg = large_row_net(n, seed=0)
    print(f"device demo: {hg.name} n={hg.n} edges={len(hg.edges)} "
          f"P={P} eps={eps} backend={backend}")
    m0 = greedy_initial(hg, P, eps, np.random.default_rng(0))

    st_np = PartitionState(hg, P, masks=m0.copy())
    t0 = time.perf_counter()
    fm_refine(hg, m0.copy(), P, eps, np.random.default_rng(0), state=st_np,
              frontier="numpy")
    t_np = time.perf_counter() - t0
    print(f"numpy frontier:   cost={st_np.cost:.0f} in {t_np:.2f}s")

    st_dev = PartitionState(hg, P, masks=m0.copy())
    dev = device_pass(st_dev, capacity(hg, P, eps) + 1e-9, backend=backend)
    if dev is None:
        print("device path unavailable (no jax / non-integer weights / "
              f"n < DEVICE_MIN_NODES) -- frontier='{backend}' would fall "
              "back to the per-front path")
        return
    t0 = time.perf_counter()
    try:
        dev.run_fm(np.random.default_rng(0), 6)
    finally:
        dev.detach()
    t_dev = time.perf_counter() - t0
    print(f"device-resident:  cost={st_dev.cost:.0f} in {t_dev:.2f}s "
          f"(syncs={dev.syncs} commits={dev.commits} "
          f"scans={dev.pass_scans})")
    same = bool(np.array_equal(st_np.masks, st_dev.masks)
                and st_np.cost == st_dev.cost)
    print(f"bit-identical: {same} "
          f"(<= 1 host sync per committed move + 1 terminal scan/pass)")
    assert same


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-135m", action="store_true")
    ap.add_argument("--multilevel", action="store_true",
                    help="run the multilevel V-cycle partitioning demo")
    ap.add_argument("--multilevel-schedule", action="store_true",
                    help="run the multilevel DAG-scheduling demo")
    ap.add_argument("--device", action="store_true",
                    help="run the device-resident FM refinement demo")
    ap.add_argument("--backend", default="jax",
                    help="frontier backend for --device (default: jax)")
    ap.add_argument("--n", type=int, default=None,
                    help="instance size for --multilevel[-schedule]/--device "
                         "(defaults: 8192 / 20000 / 4096)")
    ap.add_argument("--workers", type=int, default=None,
                    help="shared-memory worker processes for --multilevel / "
                         "--multilevel-schedule (sharded coarsening [+ "
                         "refinement for partitioning]; default serial)")
    ap.add_argument("--no-splits", action="store_true",
                    help="disable the superstep-split refinement front in "
                         "--multilevel-schedule (PR 9 default: on)")
    args = ap.parse_args()

    if args.multilevel:
        multilevel_demo(args.n or 8192, workers=args.workers)
        return
    if args.multilevel_schedule:
        multilevel_schedule_demo(args.n or 20_000,
                                 splits=not args.no_splits,
                                 workers=args.workers)
        return
    if args.device:
        device_demo(args.n or 4096, backend=args.backend)
        return

    cfg = get_config(args.arch)
    if not args.full_135m:
        cfg = reduce_config(cfg, layers_per_segment=2)
    mesh = make_host_mesh()
    print(f"quickstart: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")
    with tempfile.TemporaryDirectory() as ckpt:
        tr = Trainer(cfg, mesh, DataConfig(args.batch, args.seq),
                     TrainerConfig(steps=args.steps, ckpt_every=10,
                                   ckpt_dir=ckpt, log_every=5),
                     adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=args.steps))
        _, hist = tr.run()
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({len(hist)} steps, ckpt/restore exercised)")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not decrease"


if __name__ == "__main__":
    main()
