"""Quickstart: train a (reduced) assigned architecture end-to-end on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m]
        [--steps 30] [--full-135m]

Uses the real framework path: config registry -> Trainer (fault-tolerant
loop, atomic checkpoints, deterministic data) -> loss curve.  ``--full-135m``
trains the full 135M-parameter SmolLM config (slow on 1 CPU core; the same
command drives a pod via --production-mesh in repro.launch.train).
"""
import argparse
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config, reduce_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-135m", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_135m:
        cfg = reduce_config(cfg, layers_per_segment=2)
    mesh = make_host_mesh()
    print(f"quickstart: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")
    with tempfile.TemporaryDirectory() as ckpt:
        tr = Trainer(cfg, mesh, DataConfig(args.batch, args.seq),
                     TrainerConfig(steps=args.steps, ckpt_every=10,
                                   ckpt_dir=ckpt, log_every=5),
                     adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=args.steps))
        _, hist = tr.run()
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({len(hist)} steps, ckpt/restore exercised)")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not decrease"


if __name__ == "__main__":
    main()
