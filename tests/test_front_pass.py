"""Device-resident refinement (kernels/front_pass.py).

The device pass's contract is *bit-identity*: running a whole FM or
replication sweep as one jitted device program -- one host sync per
committed move -- must reproduce the numpy frontier path's final masks,
costs and decision trajectory exactly, never approximately.  These tests
pin that contract on random integer-weight hypergraphs/DAGs and on the
shipped dataset instances, assert the sync-count bound
(``commits <= syncs <= commits + pass_scans``), exercise the Pallas
interpret-mode find path, and check the attach guards (float weights,
unassigned nodes, size floor) fall back to the host path cleanly.
"""
import contextlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.frontier import device_pass, device_windows
from repro.core.hypergraph import Dag, Hypergraph
from repro.core.partition import PartitionState
from repro.core.partition.cost import capacity
from repro.core.partition.heuristic import (fm_refine, greedy_initial,
                                            replicate_local_search)
from repro.core.schedule import BspInstance, bspg_schedule
from repro.core.schedule.list_sched import (comp_rebalance_pass, hill_climb,
                                            node_move_pass, rebalance_comms)
from repro.datagen import spmv_dataset, tiny_dataset
from repro.kernels import front_pass, gain, ops


# ----------------------------------------------------------------- helpers

def int_hypergraph(rng, n=None, m=None):
    """Random hypergraph with integer weights (the device contract)."""
    n = n or int(rng.integers(8, 40))
    m = m or int(rng.integers(5, 60))
    edges = [tuple(rng.choice(n, size=int(rng.integers(2, min(6, n) + 1)),
                              replace=False)) for _ in range(m)]
    return Hypergraph(n=n, edges=edges,
                      omega=rng.integers(1, 5, size=n).astype(float),
                      mu=rng.integers(1, 6, size=m).astype(float))


def random_dag(n, seed, fanin=3, p_edge=0.5, n_src=8, weighted=False):
    rng = np.random.default_rng(seed)
    edges = []
    for v in range(n_src, n):
        for u in rng.choice(v, size=min(fanin, v), replace=False):
            if rng.random() < p_edge:
                edges.append((int(u), v))
    omega = rng.uniform(0.5, 4.0, size=n) if weighted else None
    mu = rng.uniform(0.5, 3.0, size=n) if weighted else None
    return Dag(n=n, edge_list=edges, omega=omega, mu=mu)


@contextlib.contextmanager
def small_device_floors():
    """Drop the size floors so tiny test instances take the device path."""
    saved = (front_pass.DEVICE_MIN_NODES, front_pass.DEVICE_MIN_WINDOW,
             front_pass.DEVICE_MIN_STEPS)
    front_pass.DEVICE_MIN_NODES = 1
    front_pass.DEVICE_MIN_WINDOW = 1
    front_pass.DEVICE_MIN_STEPS = 1
    try:
        yield
    finally:
        (front_pass.DEVICE_MIN_NODES, front_pass.DEVICE_MIN_WINDOW,
         front_pass.DEVICE_MIN_STEPS) = saved


def sched_snap(s):
    """Full observable schedule state: cost, comm plan, assignment rows."""
    return (s.current_cost(), sorted(s.comms.items()),
            [sorted(a.items()) for a in s.assign])


def _fm_pair(hg, P, eps, seed):
    m0 = greedy_initial(hg, P, eps, np.random.default_rng(seed + 1000))
    ma, mb = m0.copy(), m0.copy()
    sta = PartitionState(hg, P, masks=ma)
    stb = PartitionState(hg, P, masks=mb)
    fm_refine(hg, ma, P, eps, np.random.default_rng(seed), state=sta,
              frontier="numpy")
    fm_refine(hg, mb, P, eps, np.random.default_rng(seed), state=stb,
              frontier="jax")
    assert stb.device is None          # detached even on the device path
    return m0, (ma, sta), (mb, stb)


# ------------------------------------------------- partition bit-identity

@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_fm_device_bit_identical(seed):
    """Whole-pass device FM == numpy frontier FM: masks and cost exact."""
    rng = np.random.default_rng(seed)
    hg = int_hypergraph(rng)
    P = int(rng.integers(2, 6))
    with small_device_floors():
        _, (ma, sta), (mb, stb) = _fm_pair(hg, P, 0.3, seed)
    assert np.array_equal(ma, mb)
    assert sta.cost == stb.cost


@given(st.integers(0, 10_000), st.sampled_from([None, 2]))
@settings(max_examples=8, deadline=None)
def test_property_rep_device_bit_identical(seed, max_replicas):
    """Device replication sweep (add/drop with resume protocol) == numpy,
    including the host edge-guided phase reaching the device via the
    engine apply/undo hook."""
    rng = np.random.default_rng(seed)
    hg = int_hypergraph(rng)
    P = int(rng.integers(2, 6))
    m0 = greedy_initial(hg, P, 0.3, np.random.default_rng(seed + 1000))
    with small_device_floors():
        ra = replicate_local_search(hg, m0.copy(), P, 0.3, seed=seed,
                                    max_replicas=max_replicas,
                                    frontier="numpy")
        rb = replicate_local_search(hg, m0.copy(), P, 0.3, seed=seed,
                                    max_replicas=max_replicas,
                                    frontier="jax")
    assert np.array_equal(ra.masks, rb.masks)
    assert ra.cost == rb.cost


def test_shipped_spmv_instance_bit_identical():
    """The device path reproduces the numpy path on a real row-net SpMV
    hypergraph, not just synthetic randoms."""
    hg = spmv_dataset("rn", count=1)[0]
    with small_device_floors():
        _, (ma, sta), (mb, stb) = _fm_pair(hg, 4, 0.3, seed=7)
        assert np.array_equal(ma, mb) and sta.cost == stb.cost
        ra = replicate_local_search(hg, ma.copy(), 4, 0.3, seed=7,
                                    frontier="numpy")
        rb = replicate_local_search(hg, ma.copy(), 4, 0.3, seed=7,
                                    frontier="jax")
    assert np.array_equal(ra.masks, rb.masks) and ra.cost == rb.cost


def test_device_mirror_tracks_engine_hook():
    """Host-engine apply/undo keep the device uncov/lambda/mask buffers in
    lockstep without a refresh (the PR's engine hook)."""
    rng = np.random.default_rng(11)
    hg = int_hypergraph(rng, n=30, m=50)
    m0 = greedy_initial(hg, 4, 0.3, np.random.default_rng(11))
    st_ = PartitionState(hg, 4, masks=m0.copy())
    cap = capacity(hg, 4, 0.3) + 1e-9
    with small_device_floors():
        dev = device_pass(st_, cap, backend="jax")
    assert dev is not None
    try:
        for v in range(0, 12):
            st_.apply(v, int(st_.masks[v]) | (1 << (v % 4)))
            if v % 3 == 0:
                st_.undo()
            else:
                st_.commit()
        # hook mutations are queued for find-fusion; flush forces them
        # down so the buffers can be inspected without a find
        assert len(dev._pending) > 0
        dev.flush()
        assert dev.apply_dispatches > 0 and not dev._pending
        got_uncov = np.asarray(dev._uncov)[:dev.E]
        assert np.array_equal(got_uncov, st_.uncov[:, dev.colmap])
        assert np.array_equal(np.asarray(dev._masks)[:hg.n], st_.masks)
    finally:
        dev.detach()
    assert st_.device is None


def test_sync_accounting_bound():
    """At most one host sync per committed move plus one terminal dry scan
    per pass: commits <= syncs <= commits + pass_scans."""
    rng = np.random.default_rng(7)
    hg = int_hypergraph(rng, n=40, m=80)
    m0 = greedy_initial(hg, 4, 0.3, np.random.default_rng(77))
    cap = capacity(hg, 4, 0.3) + 1e-9
    with small_device_floors():
        st_ = PartitionState(hg, 4, masks=m0.copy())
        dev = device_pass(st_, cap, backend="jax")
        assert dev is not None
        try:
            dev.run_fm(np.random.default_rng(7), 6)
        finally:
            dev.detach()
        assert dev.syncs > 0 and dev.commits > 0
        assert dev.commits <= dev.syncs <= dev.commits + dev.pass_scans
        # fused dispatch: a pure FM sweep never pays a standalone apply --
        # every committed move rides the next find program
        assert dev.apply_dispatches == 0
        # replication sweeps obey the same bound
        st2 = PartitionState(hg, 4, masks=m0.copy())
        dev2 = device_pass(st2, cap, backend="jax")
        try:
            for p in range(4):
                if not dev2.rep_pass(np.random.default_rng(p).permutation(
                        hg.n), None):
                    break
        finally:
            dev2.detach()
        assert dev2.commits <= dev2.syncs <= dev2.commits + dev2.pass_scans
        assert dev2.apply_dispatches == 0   # pure node sweeps fuse too


def test_pallas_interpret_find_identity():
    """The find program's Pallas pricing path (interpret mode on CPU) is
    decision-identical to the jnp path and the numpy frontier."""
    ops.force("pallas")
    try:
        with small_device_floors():
            for seed in (0, 3):
                rng = np.random.default_rng(seed)
                hg = int_hypergraph(rng)
                _, (ma, sta), (mb, stb) = _fm_pair(hg, 4, 0.3, seed)
                assert np.array_equal(ma, mb) and sta.cost == stb.cost
    finally:
        ops.force(None)
    stats = gain.kernel_cache_stats()
    assert stats["dlam"]["size"] >= 1      # the fused kernel actually ran


def test_attach_guards():
    """Attach declines float weights, unassigned nodes, sub-floor sizes and
    non-jax backends -- the host path must keep working untouched."""
    rng = np.random.default_rng(3)
    hg = int_hypergraph(rng, n=30, m=40)
    m0 = greedy_initial(hg, 4, 0.3, rng)
    cap = capacity(hg, 4, 0.3) + 1e-9

    st_ = PartitionState(hg, 4, masks=m0.copy())
    assert device_pass(st_, cap, backend="numpy") is None
    assert front_pass.attach(st_, cap) is None        # below default floor

    hg_f = Hypergraph(n=hg.n, edges=hg.edges, omega=hg.omega,
                      mu=hg.mu + 0.5)                  # non-integer mu
    st_f = PartitionState(hg_f, 4, masks=m0.copy())
    with small_device_floors():
        assert device_pass(st_f, cap, backend="jax") is None

    m_un = m0.copy()
    m_un[0] = 0                                        # unassigned node
    st_u = PartitionState(hg, 4, masks=m_un)
    with small_device_floors():
        assert device_pass(st_u, cap, backend="jax") is None


# ------------------------------------------------------- schedule windows

def test_schedule_passes_bit_identical():
    """rebalance/comp/node passes and full hill_climb produce the same
    schedule through the device window pricers as through numpy."""
    with small_device_floors():
        for seed in range(4):
            n = int(np.random.default_rng(seed).integers(40, 110))
            P = int(np.random.default_rng(seed + 1).integers(2, 6))
            inst = BspInstance(dag=random_dag(n, seed), P=P, g=2.0, L=4.0)
            sa = bspg_schedule(inst, seed=seed)
            sb = bspg_schedule(inst, seed=seed)
            assert device_windows(sb, "jax") is not None
            hill_climb(sa, seed=seed)
            hill_climb(sb, seed=seed, backend="jax")
            assert sched_snap(sa) == sched_snap(sb)

        inst = BspInstance(dag=random_dag(80, 5), P=4, g=2.0, L=4.0)
        sa = bspg_schedule(inst, seed=5)
        sb = bspg_schedule(inst, seed=5)
        rebalance_comms(sa)
        rebalance_comms(sb, backend="jax")
        assert sched_snap(sa) == sched_snap(sb)
        node_move_pass(sa)
        node_move_pass(sb, backend="jax")
        assert sched_snap(sa) == sched_snap(sb)
        comp_rebalance_pass(sa)
        comp_rebalance_pass(sb, backend="jax")
        assert sched_snap(sa) == sched_snap(sb)


def test_shipped_tiny_dag_bit_identical():
    """Device-window hill_climb reproduces numpy on a shipped tiny DAG."""
    dag = tiny_dataset()[0]
    inst = BspInstance(dag=dag, P=4, g=2.0, L=4.0)
    with small_device_floors():
        sa = bspg_schedule(inst, seed=0)
        sb = bspg_schedule(inst, seed=0)
        hill_climb(sa, seed=0)
        hill_climb(sb, seed=0, backend="jax")
    assert sched_snap(sa) == sched_snap(sb)


def test_schedule_float_weights_fall_back():
    """Float-weight DAGs never attach (the sequential accept rule is not an
    argmin for floats); the jax backend must silently use numpy."""
    dag = random_dag(60, 3, weighted=True)
    inst = BspInstance(dag=dag, P=4, g=2.0, L=4.0)
    sa = bspg_schedule(inst, seed=0)
    assert device_windows(sa, "jax") is None
    sb = bspg_schedule(inst, seed=0)
    with small_device_floors():
        hill_climb(sa, seed=0)
        hill_climb(sb, seed=0, backend="jax")
    assert sched_snap(sa) == sched_snap(sb)


# ------------------------------------------------------- kernel satellites

def test_front_dlam_matches_oracle():
    """The fused Pallas delta kernel == the straight-line numpy pricing."""
    import jax.numpy as jnp
    rng = np.random.default_rng(9)
    R, M = 512, 128
    rows = (rng.integers(0, 4, size=(R, M)) > 0).astype(np.int32)
    pc = np.full(M, gain._NO_COVER, dtype=np.int32)
    pc[1:16] = rng.integers(1, 6, size=15)
    lam_old = rng.integers(0, 5, size=R).astype(np.int32)
    got = np.asarray(front_dlam_interp(jnp.asarray(rows), jnp.asarray(pc),
                                       jnp.asarray(lam_old)))
    lam_new = np.where(rows == 0, pc[None, :], gain._NO_COVER).min(axis=1)
    want = np.maximum(lam_new - 1, 0) - np.maximum(lam_old - 1, 0)
    assert np.array_equal(got, want)


def front_dlam_interp(rows, pc, lam_old):
    return gain.front_dlam(rows, pc, lam_old, interpret=True)


def test_padded_rows_reuses_and_reonese():
    """The jnp fallback's pad buffer is reused across fronts and stale rows
    from a larger previous front are re-onesed (the sentinel)."""
    M = 16
    a = np.full((3, M), 5, dtype=np.int32)
    out_a = gain._padded_rows(a, 8)
    assert out_a.shape == (8, M)
    assert np.all(out_a[:3] == 5) and np.all(out_a[3:] == 1)
    b = np.full((1, M), 7, dtype=np.int32)
    out_b = gain._padded_rows(b, 8)
    assert out_b is not None and out_b.base is out_a.base  # same buffer
    assert np.all(out_b[0] == 7)
    assert np.all(out_b[1:] == 1)                          # stale re-onesed


def test_kernel_cache_stats_bounded():
    """Per-shape jitted-call caches are bounded and introspectable."""
    stats = gain.kernel_cache_stats()
    assert set(stats) == {"pallas", "dlam"}
    for rec in stats.values():
        assert rec["maxsize"] == gain._PALLAS_CACHE_SIZE == 64
        assert 0 <= rec["size"] <= rec["maxsize"]
