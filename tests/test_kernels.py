"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable c).  The kernels target TPU; interpret=True executes the kernel
body on CPU with identical semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels import ref


def rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


SHAPES_ATTN = [
    # (B, Sq, Sk, H, KV, hd, bq, bk)
    (1, 8, 8, 1, 1, 4, 8, 8),
    (2, 16, 16, 4, 2, 8, 8, 8),
    (1, 32, 32, 4, 4, 16, 16, 8),
    (2, 24, 24, 6, 2, 8, 8, 12),     # GQA group 3
    (1, 64, 64, 2, 1, 32, 32, 32),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES_ATTN)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, dtype, causal):
    B, Sq, Sk, H, KV, hd, bq, bk = shape
    rng = np.random.default_rng(hash((shape, causal)) % 2**31)
    q = rand(rng, (B, Sq, H, hd), dtype)
    k = rand(rng, (B, Sk, KV, hd), dtype)
    v = rand(rng, (B, Sk, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.attention_reference(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_mixed_vdim():
    """MLA-style: v head dim differs from q/k head dim."""
    rng = np.random.default_rng(0)
    q = rand(rng, (1, 16, 2, 12), jnp.float32)
    k = rand(rng, (1, 16, 2, 12), jnp.float32)
    v = rand(rng, (1, 16, 2, 8), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8,
                          interpret=True)
    want = ref.attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


SHAPES_SCAN = [
    # (B, S, di, N, chunk)
    (1, 8, 4, 2, 4),
    (2, 16, 8, 4, 8),
    (1, 32, 16, 4, 8),
    (2, 64, 8, 16, 16),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES_SCAN)
def test_mamba_scan_matches_ref(shape, dtype):
    B, S, di, N, chunk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    u = rand(rng, (B, S, di), dtype)
    dt = jnp.abs(rand(rng, (B, S, di), dtype)) * 0.1
    A = -jnp.abs(rand(rng, (di, N), jnp.float32)) - 0.1
    Bc = rand(rng, (B, S, N), dtype)
    Cc = rand(rng, (B, S, N), dtype)
    D = rand(rng, (di,), jnp.float32)
    y, last = mamba_scan(u, dt, A, Bc, Cc, D, chunk=chunk, interpret=True)
    y_ref, last_ref = ref.mamba_scan_reference(u, dt, A, Bc, Cc, D)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(last), np.asarray(last_ref),
                               rtol=tol, atol=tol)


SHAPES_GMM = [
    # (G, capacity, D, F, br, bc, bk)
    (2, 8, 16, 16, 8, 8, 16),
    (4, 16, 32, 24, 8, 8, 16),
    (3, 8, 8, 8, 4, 8, 8),
    (8, 32, 16, 48, 16, 16, 16),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES_GMM)
def test_grouped_matmul_matches_ref(shape, dtype):
    G, C, D, F, br, bc, bk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = rand(rng, (G * C, D), dtype)
    w = rand(rng, (G, D, F), dtype)
    out = grouped_matmul(x, w, C, block_rows=br, block_cols=bc, block_k=bk,
                         interpret=True)
    sizes = jnp.full((G,), C, jnp.int32)
    want = ref.grouped_matmul_reference(x, w, sizes)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@given(st.integers(1, 3), st.integers(1, 4), st.booleans())
@settings(max_examples=10, deadline=None)
def test_property_flash_attention_random_shapes(b, g, causal):
    """Property sweep: any (block-divisible) shape matches the oracle."""
    rng = np.random.default_rng(b * 100 + g)
    H, KV, hd = 2 * g, g, 8
    S = 16
    q = rand(rng, (b, S, H, hd), jnp.float32)
    k = rand(rng, (b, S, KV, hd), jnp.float32)
    v = rand(rng, (b, S, KV, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=8, block_k=8,
                          interpret=True)
    want = ref.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-6, atol=3e-6)


def test_ragged_ref_grouped_matmul():
    """The general (non-aligned) reference handles ragged group sizes."""
    rng = np.random.default_rng(5)
    sizes = jnp.asarray([3, 0, 5, 2], jnp.int32)
    T = int(sizes.sum())
    x = rand(rng, (T, 8), jnp.float32)
    w = rand(rng, (4, 8, 6), jnp.float32)
    out = ref.grouped_matmul_reference(x, w, sizes)
    row = 0
    for gi, sz in enumerate(np.asarray(sizes)):
        for _ in range(int(sz)):
            want = np.asarray(x[row]) @ np.asarray(w[gi])
            np.testing.assert_allclose(np.asarray(out[row]), want, rtol=1e-5)
            row += 1
