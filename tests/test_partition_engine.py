"""Engine invariants: incremental deltas must equal full recomputation.

The ``PartitionState`` engine (src/repro/core/partition/engine.py) maintains
per-edge uncovered-subset counts so move evaluation is O(degree); these
tests pin its semantics to the scalar set-cover oracle in ``cost.py`` and to
the preserved seed implementation in ``reference.py``.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hypergraph import Hypergraph
from repro.core.partition import (PartitionState, capacity, edge_lambdas,
                                  is_valid, loads, min_cover, partition_cost,
                                  partition_heuristic,
                                  replicate_local_search)
from repro.core.partition.cost import edge_cost
from repro.core.partition.reference import (partition_heuristic_reference,
                                            replicate_local_search_reference)


def random_hypergraph(rng, n=None, m=None, weighted=True):
    n = n or int(rng.integers(5, 30))
    m = m or int(rng.integers(3, 50))
    edges = [tuple(rng.choice(n, size=int(rng.integers(2, min(6, n) + 1)),
                              replace=False)) for _ in range(m)]
    omega = rng.random(n) + 0.5 if weighted else None
    mu = rng.random(m) + 0.1 if weighted else None
    return Hypergraph(n=n, edges=edges, omega=omega, mu=mu)


class TestCsr:
    # the expected incidence is derived straight from hg.edges: the CSR
    # arrays (xinc/inc_edges) are the contract (the list-of-lists
    # incident_edges() compatibility view was removed in PR 5 -- no
    # in-repo callers since PR 4)
    def test_csr_matches_lists(self):
        rng = np.random.default_rng(0)
        hg = random_hypergraph(rng)
        inc = [[ei for ei, e in enumerate(hg.edges) if v in e]
               for v in range(hg.n)]
        for v in range(hg.n):
            assert hg.inc_edges[hg.xinc[v]:hg.xinc[v + 1]].tolist() == inc[v]
        for ei, e in enumerate(hg.edges):
            assert hg.pins[hg.xpins[ei]:hg.xpins[ei + 1]].tolist() == list(e)
        assert hg.xpins[-1] == hg.num_pins

    def test_pin_adjacency(self):
        rng = np.random.default_rng(1)
        hg = random_hypergraph(rng)
        for v in range(hg.n):
            want = [u for ei, e in enumerate(hg.edges) if v in e
                    for u in e]
            got = hg.adj_nodes[hg.xadj[v]:hg.xadj[v + 1]].tolist()
            assert got == want



class TestVectorizedCost:
    def test_edge_lambdas_match_min_cover(self):
        rng = np.random.default_rng(2)
        for P in (2, 3, 4, 6):
            hg = random_hypergraph(rng)
            masks = rng.integers(1, 1 << P, size=hg.n)
            lam = edge_lambdas(hg, masks, P)
            for ei, e in enumerate(hg.edges):
                assert lam[ei] == min_cover([int(masks[v]) for v in e], P)

    def test_partition_cost_matches_scalar(self):
        rng = np.random.default_rng(3)
        for P in (2, 4):
            hg = random_hypergraph(rng)
            masks = rng.integers(1, 1 << P, size=hg.n)
            want = sum(edge_cost(hg, masks, ei, P)
                       for ei in range(len(hg.edges)))
            assert abs(partition_cost(hg, masks, P) - want) < 1e-9

    def test_empty_edges_including_trailing(self):
        """Empty hyperedges cost 0 wherever they sit -- a trailing one must
        not push the reduceat segmentation off the pins array."""
        P = 2
        for edges in ([(0, 1), ()], [(), (0, 1)], [(0, 1), (), (1, 2), ()]):
            hg = Hypergraph(n=3, edges=edges)
            masks = np.array([1, 2, 2])
            want = sum(edge_cost(hg, masks, ei, P)
                       for ei in range(len(hg.edges)))
            assert abs(partition_cost(hg, masks, P) - want) < 1e-9
            state = PartitionState(hg, P, masks=masks)
            assert abs(state.cost - want) < 1e-9
            state.apply(1, 1)
            assert abs(state.cost - partition_cost(hg, state.masks, P)) < 1e-9

    def test_loads_matches_scalar(self):
        rng = np.random.default_rng(4)
        P = 4
        hg = random_hypergraph(rng)
        masks = rng.integers(1, 1 << P, size=hg.n)
        want = np.zeros(P)
        for v in range(hg.n):
            for p in range(P):
                if (int(masks[v]) >> p) & 1:
                    want[p] += hg.omega[v]
        assert np.allclose(loads(hg, masks, P), want)


@given(st.integers(0, 10_000), st.booleans())
@settings(max_examples=20, deadline=None)
def test_property_delta_matches_recompute(seed, capped):
    """Random move / add-replica / drop-replica sequences: every delta the
    engine reports must equal the full-cost difference, with loads and
    lambdas staying consistent; apply+undo must round-trip exactly.

    ``capped`` exercises the ILP/D-style masks (<= 2 replicas) alongside
    unconstrained ILP/R-style masks.
    """
    rng = np.random.default_rng(seed)
    P = int(rng.integers(2, 5))
    hg = random_hypergraph(rng)
    max_replicas = 2 if capped else P
    # start from a random valid replicated assignment within the cap
    masks = np.array([
        int(np.bitwise_or.reduce(
            1 << rng.choice(P, size=int(rng.integers(1, max_replicas + 1)),
                            replace=False)))
        for _ in range(hg.n)], dtype=np.int64)
    state = PartitionState(hg, P, masks=masks)
    applied = 0
    for _ in range(60):
        v = int(rng.integers(0, hg.n))
        m = int(state.masks[v])
        k = bin(m).count("1")
        op = rng.integers(0, 3)
        if op == 0:  # move
            p_from = int(rng.choice([p for p in range(P) if (m >> p) & 1]))
            p_to = int(rng.integers(0, P))
            new = (m & ~(1 << p_from)) | (1 << p_to)
            d = state.delta_move(v, p_from, p_to)
        elif op == 1 and k < max_replicas:  # add replica
            p = int(rng.integers(0, P))
            new = m | (1 << p)
            d = state.delta_add_replica(v, p)
        elif op == 2 and k > 1:  # drop replica
            p = int(rng.choice([p for p in range(P) if (m >> p) & 1]))
            new = m & ~(1 << p)
            d = state.delta_drop_replica(v, p)
        else:
            continue
        before = state.cost
        d_applied = state.apply(v, new)
        applied += 1
        assert abs(d - d_applied) < 1e-9
        full = partition_cost(hg, state.masks, P)
        assert abs(state.cost - full) < 1e-9, (state.cost, full)
        assert abs((state.cost - before) - d) < 1e-9
        assert np.allclose(state.loads, loads(hg, state.masks, P))
    state.check()
    # undo everything: must restore the exact initial state
    state.undo(applied)
    assert np.array_equal(state.masks, masks)
    assert abs(state.cost - partition_cost(hg, masks, P)) < 1e-9
    state.check()


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_scalar_backend_matches_numpy(seed):
    """The pure-python backend (used by the exact solver) must agree with
    the vectorized backend op-for-op, including unassigned (mask 0) pins."""
    rng = np.random.default_rng(seed)
    P = int(rng.integers(2, 5))
    hg = random_hypergraph(rng)
    masks = rng.integers(0, 1 << P, size=hg.n)  # 0 = unassigned
    sv = PartitionState(hg, P, masks=masks)
    sp = PartitionState(hg, P, masks=masks, backend="python")
    assert abs(sv.cost - sp.cost) < 1e-9
    applied = 0
    for _ in range(40):
        v = int(rng.integers(0, hg.n))
        new = int(rng.integers(0, 1 << P))
        assert abs(sv.delta_set_mask(v, new)
                   - sp.delta_set_mask(v, new)) < 1e-9
        assert abs(sv.apply(v, new) - sp.apply(v, new)) < 1e-9
        applied += 1
        assert abs(sv.cost - sp.cost) < 1e-9
        assert np.allclose(np.asarray(sv.loads), np.asarray(sp.loads))
    for ei in range(len(hg.edges)):
        assert sv.lambda_of(ei) == sp.lambda_of(ei)
    sp.check()
    sp.undo(applied)
    sv.undo(applied)
    assert abs(sv.cost - sp.cost) < 1e-9
    sp.check()


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_batched_deltas_match_single(seed):
    rng = np.random.default_rng(seed)
    P = int(rng.integers(2, 5))
    hg = random_hypergraph(rng)
    masks = rng.integers(1, 1 << P, size=hg.n)
    state = PartitionState(hg, P, masks=masks)
    for _ in range(20):
        v = int(rng.integers(0, hg.n))
        cands = rng.integers(1, 1 << P, size=4)
        batch = state.delta_masks(v, cands)
        single = [state.delta_set_mask(v, int(c)) for c in cands]
        assert np.allclose(batch, single)


class TestHeuristicEquivalence:
    """Refactored heuristics vs the preserved seed implementation."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_partition_heuristic_not_worse(self, seed):
        rng = np.random.default_rng(seed)
        hg = random_hypergraph(rng, n=60, m=90)
        P, eps = 4, 0.1
        new = partition_heuristic(hg, P, eps, seed=seed)
        _, ref_cost = partition_heuristic_reference(hg, P, eps, seed=seed)
        assert is_valid(hg, new.masks, P, eps)
        assert abs(partition_cost(hg, new.masks, P) - new.cost) < 1e-9
        assert new.cost <= ref_cost + 1e-9

    @pytest.mark.parametrize("max_replicas", [2, None])
    def test_replicate_local_search_not_worse(self, max_replicas):
        rng = np.random.default_rng(7)
        hg = random_hypergraph(rng, n=50, m=80)
        P, eps = 4, 0.1
        base = partition_heuristic(hg, P, eps, seed=0)
        new = replicate_local_search(hg, base.masks.copy(), P, eps,
                                     max_replicas=max_replicas, seed=0)
        _, ref_cost = replicate_local_search_reference(
            hg, base.masks.copy(), P, eps, max_replicas=max_replicas, seed=0)
        cap = 2 if max_replicas == 2 else None
        assert is_valid(hg, new.masks, P, eps, max_replicas=cap)
        assert new.cost <= base.cost + 1e-9
        assert new.cost <= ref_cost + 1e-9

    def test_wide_mesh_falls_back_to_reference(self):
        """P beyond the engine's table limit (e.g. 16-way expert placement)
        must still work through the scalar reference path."""
        rng = np.random.default_rng(5)
        hg = random_hypergraph(rng, n=20, m=25)
        P, eps = 16, 0.5
        base = partition_heuristic(hg, P, eps, restarts=1, seed=0)
        assert abs(partition_cost(hg, base.masks, P) - base.cost) < 1e-9
        rep = replicate_local_search(hg, base.masks.copy(), P, eps,
                                     max_replicas=2, max_passes=2, seed=0)
        assert rep.cost <= base.cost + 1e-9
        assert abs(partition_cost(hg, rep.masks, P) - rep.cost) < 1e-9
        # replica cap honored (balance is only as good as the seed greedy
        # start gives on tight P~n instances -- same as pre-engine behavior)
        assert all(bin(int(m)).count("1") <= 2 for m in rep.masks)

    def test_replication_respects_capacity(self):
        rng = np.random.default_rng(11)
        hg = random_hypergraph(rng, n=40, m=70)
        P, eps = 4, 0.05
        base = partition_heuristic(hg, P, eps, seed=3)
        rep = replicate_local_search(hg, base.masks.copy(), P, eps, seed=3)
        cap = capacity(hg, P, eps)
        assert np.all(loads(hg, rep.masks, P) <= cap + 1e-9)
