"""Multilevel DAG scheduling invariants (core/schedule/multilevel.py).

The V-cycle's contract: coarsening is acyclicity-safe and work-conserving,
schedule projection is bit-exact against a from-scratch build of the
expanded schedule (and always valid), per-level refinement never increases
the cost, the end-to-end driver is never worse than the flat heuristic
wherever both run, and at or below the coarsest size it *is* the flat
heuristic.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hypergraph import Dag
from repro.core.frontier import price_comm_moves, price_comp_moves
from repro.core.schedule import (BspInstance, MultilevelScheduleOptions,
                                 Schedule, baseline_schedule,
                                 best_replicated_schedule, bspg_schedule,
                                 basic_heuristic, derive_comms, hill_climb,
                                 multilevel_schedule)
from repro.core.schedule import multilevel as ml
from repro.core.schedule.list_sched import comp_rebalance_pass
from repro.core.schedule.replication import replica_prune_pass
from repro.datagen import (large_psdd_dag, large_sptrsv_dag, psdd_dag,
                           sptrsv_dag)


def random_dag(rng, n=None, weighted=True):
    n = n or int(rng.integers(10, 40))
    edges = []
    for v in range(1, n):
        for u in rng.choice(v, size=min(int(rng.integers(1, 4)), v),
                            replace=False):
            edges.append((int(u), v))
    omega = rng.integers(1, 4, size=n).astype(float) if weighted else None
    mu = rng.integers(1, 4, size=n).astype(float) if weighted else None
    return Dag(n=n, edge_list=edges, omega=omega, mu=mu)


def random_schedule(rng, dag, P=None, g=4.0, L=5.0):
    P = P or int(rng.integers(2, 5))
    inst = BspInstance(dag, P=P, g=g, L=L)
    sched = hill_climb(bspg_schedule(inst, seed=int(rng.integers(100))),
                       seed=0)
    return basic_heuristic(sched)  # adds replicas: exercises replica paths


# ------------------------------------------------------------- contraction

@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_contraction_invariants(seed):
    """Both clustering rules produce acyclic contractions (validated by
    ``Dag.contract`` itself) that conserve work, respect the cluster cap,
    and carry exactly the boundary mu and the image of the cross edges."""
    rng = np.random.default_rng(seed)
    dag = random_dag(rng)
    cap = float(dag.omega.sum()) / 3
    for kind in ("funnel", "level"):
        if kind == "funnel":
            cmap, nc = ml.funnel_clustering(dag, cap)
        else:
            lvl = np.asarray(ml.dag_levels(dag), dtype=np.int64)
            cmap, nc = ml.same_level_matching(dag, lvl, cap, rng)
        assert nc <= dag.n and np.all((cmap >= 0) & (cmap < nc))
        coarse = dag.contract(cmap, nc)  # raises on a cyclic contraction
        assert abs(coarse.omega.sum() - dag.omega.sum()) < 1e-9
        want_omega = np.zeros(nc)
        np.add.at(want_omega, cmap, dag.omega)
        assert np.allclose(coarse.omega, want_omega)
        # cluster work cap: multi-member clusters stay under the cap
        sizes = np.bincount(cmap, minlength=nc)
        assert np.all(want_omega[sizes >= 2] <= cap + 1e-9)
        # coarse edge set is exactly the image of the cross edges
        want_edges = {(int(cmap[u]), int(cmap[v]))
                      for (u, v) in dag.edge_list if cmap[u] != cmap[v]}
        assert set(coarse.edge_list) == want_edges
        # boundary mu: sum over members with an external child
        want_mu = np.zeros(nc)
        for v in range(dag.n):
            if any(cmap[c] != cmap[v] for c in dag.children[v]):
                want_mu[cmap[v]] += dag.mu[v]
        assert np.allclose(coarse.mu, want_mu)


def test_contract_raises_on_cyclic_cmap():
    """Merging across a reconvergent path must be rejected eagerly."""
    dag = Dag(n=4, edge_list=[(0, 1), (0, 2), (1, 3), (2, 3)])
    with pytest.raises(ValueError):
        dag.contract(np.array([0, 1, 2, 0]), 3)


def test_funnel_clusters_are_unique_parent_trees():
    """Every non-root member of a funnel cluster has in-degree 1 with its
    unique parent inside the same cluster (the acyclicity argument)."""
    rng = np.random.default_rng(7)
    dag = random_dag(rng, n=60)
    cmap, nc = ml.funnel_clustering(dag, float(dag.omega.sum()))
    roots = {}
    for v in range(dag.n):
        roots.setdefault(int(cmap[v]), v)  # first member in id order
    for v in range(dag.n):
        if roots[int(cmap[v])] == v:
            continue
        assert len(dag.parents[v]) == 1
        assert cmap[dag.parents[v][0]] == cmap[v]


# -------------------------------------------------------------- projection

def _cluster_and_schedule(rng, dag, P=None):
    cap = max(2.0, float(dag.omega.sum()) / 6)
    if rng.random() < 0.5:
        cmap, nc = ml.funnel_clustering(dag, cap)
    else:
        lvl = np.asarray(ml.dag_levels(dag), dtype=np.int64)
        cmap, nc = ml.same_level_matching(dag, lvl, cap, rng)
    coarse = dag.contract(cmap, nc)
    csched = random_schedule(rng, coarse, P=P)
    return cmap, csched


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_projection_bit_exact(seed):
    """``Schedule.from_projection`` must equal a from-scratch build of the
    expanded schedule -- same assign/comms, bit-equal rows, step costs and
    total (integer weights) -- and be valid whenever the coarse one is."""
    rng = np.random.default_rng(seed)
    dag = random_dag(rng)
    cmap, csched = _cluster_and_schedule(rng, dag)
    assert csched.validate() == []
    inst = BspInstance(dag, csched.inst.P, csched.inst.g, csched.inst.L)
    proj = Schedule.from_projection(inst, csched, cmap)
    proj.check()
    assert proj.validate() == []
    # from-scratch comparator: same expansion through primitive ops
    naive = Schedule(inst, csched.S)
    cl_items = [sorted(a.items()) for a in csched.assign]
    for v in range(dag.n):
        for p, s in cl_items[cmap[v]]:
            naive.add_comp(v, p, s)
    derive_comms(naive)
    assert naive.assign == proj.assign
    assert naive.comms == proj.comms
    assert naive.work == proj.work
    assert naive.sent == proj.sent
    assert naive.recv == proj.recv
    assert naive._scost == proj._scost
    assert naive.current_cost() == proj.current_cost()
    # top-2 triples: equivalent (same maxima, argmax points at a maximum)
    for kind in ("work", "sent", "recv"):
        rows, tops = proj._rows_top(kind)
        _, ntops = naive._rows_top(kind)
        for s in range(proj.S):
            m1, i1, m2 = tops[s]
            assert (m1, m2) == (ntops[s][0], ntops[s][2])
            assert rows[s][i1] == m1


def test_projection_float_weights_cost_exact():
    """Float weights: rows still bit-equal (same accumulation order), the
    incrementally-maintained naive total agrees to float tolerance."""
    rng = np.random.default_rng(3)
    dag = random_dag(rng, n=35, weighted=False)
    dag.omega = rng.random(dag.n) + 0.5
    dag.mu = rng.random(dag.n) + 0.1
    cmap, csched = _cluster_and_schedule(rng, dag)
    inst = BspInstance(dag, csched.inst.P, csched.inst.g, csched.inst.L)
    proj = Schedule.from_projection(inst, csched, cmap)
    naive = Schedule(inst, csched.S)
    for v in range(dag.n):
        for p, s in sorted(csched.assign[cmap[v]].items()):
            naive.add_comp(v, p, s)
    derive_comms(naive)
    assert naive.work == proj.work and naive.sent == proj.sent
    assert naive.comms == proj.comms
    assert abs(naive.current_cost() - proj.current_cost()) < 1e-9
    assert proj.validate() == []


def test_projection_composed_cmaps_match_stepwise():
    """Skip-level projection through a composed cluster map must equal
    projecting one level at a time."""
    rng = np.random.default_rng(11)
    dag = sptrsv_dag(n=1200, band=24, seed=5)
    opts = MultilevelScheduleOptions(coarsest_n=150, cluster_cap_frac=0.05)
    levels, cmaps = ml.build_levels(dag, 4, opts, rng)
    assert len(levels) >= 3, "instance did not coarsen enough to test"
    coarse_inst = BspInstance(levels[2], 4, 4.0, 20.0)
    csched = hill_climb(bspg_schedule(coarse_inst, seed=0), seed=0)
    i1 = BspInstance(levels[1], 4, 4.0, 20.0)
    i0 = BspInstance(levels[0], 4, 4.0, 20.0)
    step = Schedule.from_projection(i1, csched, cmaps[1])
    step = Schedule.from_projection(i0, step, cmaps[0])
    direct = Schedule.from_projection(i0, csched,
                                      ml._compose_cmaps(cmaps, 0, 2))
    assert step.assign == direct.assign
    assert step.comms == direct.comms
    assert step.current_cost() == direct.current_cost()


# ------------------------------------------------- refinement move pricing

@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_comm_move_front_bit_equal(seed):
    """``price_comm_moves`` entries equal scalar ``delta_move_comm``."""
    rng = np.random.default_rng(seed)
    sched = random_schedule(rng, random_dag(rng))
    for (v, dst) in sorted(sched.comms)[:20]:
        ts = np.arange(sched.S)
        deltas = price_comm_moves(sched, v, dst, ts)
        for t in range(sched.S):
            want = sched.delta_move_comm(v, dst, t)
            assert deltas[t] == want, (v, dst, t)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_comp_move_front_bit_equal(seed):
    """``price_comp_moves`` entries equal the scalar two-cell fold."""
    rng = np.random.default_rng(seed)
    sched = random_schedule(rng, random_dag(rng))
    dag = sched.inst.dag
    for v in range(dag.n):
        if len(sched.assign[v]) != 1:
            continue
        (p, s), = sched.assign[v].items()
        ts = np.arange(sched.S)
        deltas = price_comp_moves(sched, v, p, ts)
        om = dag.omega[v]
        for t in range(sched.S):
            if t == s:
                assert deltas[t] == 0.0
                continue
            want = sched._delta_cells([("work", s, p, -om),
                                       ("work", t, p, om)])
            assert deltas[t] == want, (v, t)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_refinement_passes_safe(seed):
    """Compute re-timing and replica pruning keep schedules valid and
    never increase the cost."""
    rng = np.random.default_rng(seed)
    sched = random_schedule(rng, random_dag(rng))
    before = sched.current_cost()
    comp_rebalance_pass(sched, max_passes=2)
    replica_prune_pass(sched, max_passes=2)
    sched.check()
    assert sched.validate() == []
    assert sched.current_cost() <= before + 1e-9


# ----------------------------------------------------------------- V-cycle

def test_refinement_never_increases_cost_per_level():
    dag = sptrsv_dag(n=2500, band=32, seed=3)
    inst = BspInstance(dag, P=4, g=4.0, L=20.0)
    stats = []
    sched = multilevel_schedule(
        inst, seed=0, stats=stats,
        opts=MultilevelScheduleOptions(coarsest_n=400, flat_guard_n=0))
    rows = [r for r in stats if "level" in r]
    assert len(rows) >= 2, "no coarsening happened"
    for row in rows:
        assert row["cost_refined"] <= row["cost_projected"] + 1e-9
    assert sched.validate() == []
    assert abs(sched.current_cost() - sched.cost()) < 1e-9


@pytest.mark.parametrize("n,band", [(2000, 32), (3000, 32)])
def test_multilevel_not_worse_than_flat(n, band):
    """Final-cost parity (<=) against the flat path, on the pure V-cycle
    (guard disabled) -- instances where the projection+refinement beats
    flat outright."""
    dag = sptrsv_dag(n=n, band=band, seed=0)
    inst = BspInstance(dag, P=8, g=4.0, L=20.0)
    flat = best_replicated_schedule(inst, seed=0)
    mlv = best_replicated_schedule(
        inst, seed=0, multilevel=True,
        ml_opts=MultilevelScheduleOptions(flat_guard_n=0))
    assert mlv.validate() == []
    assert mlv.current_cost() <= flat.current_cost() + 1e-9


def test_flat_guard_enforces_not_worse():
    """With the guard opted back in (``flat_guard_n`` positive -- it is
    retired by default since the split front landed), the driver returns
    the cheaper of the V-cycle and the flat path -- never worse than flat
    by construction, even on basin-unfriendly instances."""
    dag = psdd_dag(n_leaves=500, depth=12, seed=1)
    inst = BspInstance(dag, P=8, g=4.0, L=20.0)
    flat = best_replicated_schedule(inst, seed=0)
    stats = []
    mlv = best_replicated_schedule(
        inst, seed=0, multilevel=True, stats=stats,
        ml_opts=MultilevelScheduleOptions(flat_guard_n=8192))
    assert mlv.current_cost() <= flat.current_cost() + 1e-9
    guard_rows = [r for r in stats if r.get("flat_guard")]
    assert len(guard_rows) == 1
    assert guard_rows[0]["flat_cost"] == flat.current_cost()


def test_guard_off_not_worse_on_psdd():
    """PR 9 acceptance: the pure V-cycle (guard retired, splits on) is not
    worse than flat on the psdd family that used to need the hedge."""
    dag = psdd_dag(n_leaves=500, depth=12, seed=1)
    inst = BspInstance(dag, P=8, g=4.0, L=20.0)
    flat = best_replicated_schedule(inst, seed=0)
    stats = []
    mlv = best_replicated_schedule(inst, seed=0, multilevel=True,
                                   stats=stats)
    assert not any(r.get("flat_guard") for r in stats), \
        "guard must be off by default"
    assert mlv.validate() == []
    assert mlv.current_cost() <= flat.current_cost() + 1e-9


def test_multilevel_fallthrough_exact_equality():
    """At or below ``coarsest_n`` the driver is literally the flat path."""
    dag = sptrsv_dag(n=900, band=24, seed=0)
    inst = BspInstance(dag, P=4, g=4.0, L=20.0)
    flat = best_replicated_schedule(inst, seed=0)
    mlv = best_replicated_schedule(inst, seed=0, multilevel=True)
    assert mlv.current_cost() == flat.current_cost()
    assert mlv.assign == flat.assign
    assert mlv.comms == flat.comms


def test_multilevel_immediate_stagnation_falls_through():
    """A DAG no clustering rule can shrink (a wide antichain of isolated
    heavy fan-in stars above the fanout cap) must degenerate to flat."""
    n = 900
    hub_in = 40
    edges = []
    for h in range(n // (hub_in + 1)):
        base = h * (hub_in + 1)
        for i in range(hub_in):
            edges.append((base + i, base + hub_in))
    dag = Dag(n=n, edge_list=edges)
    inst = BspInstance(dag, P=4, g=4.0, L=20.0)
    opts = MultilevelScheduleOptions(coarsest_n=64, max_fanout=8,
                                     cluster_cap_frac=1e-9, flat_guard_n=0)
    flat = best_replicated_schedule(inst, seed=0)
    mlv = best_replicated_schedule(inst, seed=0, multilevel=True,
                                   ml_opts=opts)
    assert mlv.current_cost() == flat.current_cost()


# ------------------------------------------------------------- datagen knob

def test_large_sptrsv_dag_structure():
    dag = large_sptrsv_dag(20_000, band=32, seed=9)
    assert dag.n == 20_000
    assert dag.topo_order()  # acyclic
    assert all(u < v for (u, v) in dag.edge_list[:100])
    again = large_sptrsv_dag(20_000, band=32, seed=9)
    assert again.edge_list == dag.edge_list
    assert np.array_equal(dag.edge_src, np.asarray(
        [u for u, _ in dag.edge_list]))


def test_large_psdd_dag_structure():
    dag = large_psdd_dag(n_leaves=2000, depth=12, seed=4)
    assert dag.topo_order()
    assert all(u < v for (u, v) in dag.edge_list[:100])
    indeg = np.diff(dag.xpar)
    assert int(indeg[:2000].sum()) == 0          # leaves have no parents
    assert np.all(indeg[2000:] >= 1)             # every unit has inputs
    again = large_psdd_dag(n_leaves=2000, depth=12, seed=4)
    assert again.edge_list == dag.edge_list


def test_dag_from_arrays_matches_loop_constructor():
    rng = np.random.default_rng(2)
    dag = random_dag(rng, n=50)
    src = np.array([u for u, _ in dag.edge_list])
    dst = np.array([v for _, v in dag.edge_list])
    fast = Dag.from_arrays(dag.n, src, dst, omega=dag.omega, mu=dag.mu)
    # from_arrays adjacency is sorted; the loop constructor preserves
    # edge_list insertion order -- same sets, and no consumer is
    # order-sensitive (every engine path sorts or reduces over them)
    assert [sorted(x) for x in fast.parents] == \
        [sorted(x) for x in dag.parents]
    assert [sorted(x) for x in fast.children] == \
        [sorted(x) for x in dag.children]
    assert sorted(fast.edge_list) == sorted(dag.edge_list)
    assert fast.topo_order() is not None
