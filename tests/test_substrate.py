"""Substrate tests: data pipeline, checkpointing, fault-tolerant trainer,
optimizer, expert-placement integration."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, reduce_config
from repro.core.placement.expert_placement import (evaluate_plan,
                                                   plan_expert_placement)
from repro.data.pipeline import DataConfig, SyntheticTokenStream
from repro.datagen import synthetic_trace
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def test_data_determinism_and_resume():
    cfg = reduce_config(get_config("smollm-135m"))
    dc = DataConfig(global_batch=4, seq_len=16, seed=3)
    a = SyntheticTokenStream(cfg, dc)
    batches = [a.next_batch() for _ in range(5)]
    b = SyntheticTokenStream(cfg, dc)
    b.restore({"step": 3})
    resumed = b.next_batch()
    np.testing.assert_array_equal(batches[3]["tokens"], resumed["tokens"])
    assert batches[0]["tokens"].max() < cfg.vocab
    # different steps differ
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.int32)]}
    for step in (1, 2, 3):
        ck.save(step, tree, extra={"step": step, "data": {"step": step}})
    assert ck.latest_step() == 3
    # keep=2 -> step 1 collected
    assert not (pathlib.Path(tmp_path) / "step_00000001").exists()
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, extra = ck.restore(3, abstract)
    assert extra["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"][0].dtype == jnp.bfloat16


def test_checkpoint_async_atomic(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    tree = {"w": jnp.ones((8, 8))}
    ck.save_async(5, tree, extra={"step": 5, "data": {"step": 5}})
    ck.wait()
    assert ck.latest_step() == 5
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))


def test_trainer_loss_decreases(tmp_path):
    cfg = reduce_config(get_config("smollm-135m"), layers_per_segment=2)
    mesh = make_host_mesh()
    tcfg = TrainerConfig(steps=12, ckpt_every=6, ckpt_dir=str(tmp_path),
                         log_every=100)
    ocfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=12)
    tr = Trainer(cfg, mesh, DataConfig(4, 32), tcfg, ocfg)
    _, hist = tr.run()
    assert len(hist) == 12
    assert hist[-1]["loss"] < hist[0]["loss"], \
        f"{hist[0]['loss']} -> {hist[-1]['loss']}"


def test_trainer_restart_after_failure(tmp_path):
    """Inject a failure mid-run; trainer must restore from checkpoint and
    finish, and the metric history must cover all steps after restart."""
    cfg = reduce_config(get_config("smollm-135m"), layers_per_segment=1)
    mesh = make_host_mesh()
    boom = {"armed": True}

    def failure_hook(step):
        if step == 8 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected chip failure")

    tcfg = TrainerConfig(steps=10, ckpt_every=4, ckpt_dir=str(tmp_path),
                         max_failures=2, log_every=100)
    tr = Trainer(cfg, mesh, DataConfig(2, 16), tcfg,
                 adamw.AdamWConfig(lr=1e-3, total_steps=10),
                 failure_hook=failure_hook)
    _, hist = tr.run()
    assert not boom["armed"]          # failure fired
    steps = [h["step"] for h in hist]
    assert steps[-1] == 9             # ran to completion
    assert 8 in steps                 # the failed step was re-executed
    # restart resumed from step 8 (last ckpt), not from scratch
    assert steps.count(8) >= 1 and 0 not in steps[steps.index(8):]


def test_trainer_resume_from_disk(tmp_path):
    """A brand-new Trainer process picks up where the old one stopped."""
    cfg = reduce_config(get_config("smollm-135m"), layers_per_segment=1)
    mesh = make_host_mesh()
    dc = DataConfig(2, 16)
    t1 = Trainer(cfg, mesh, dc,
                 TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                               log_every=100),
                 adamw.AdamWConfig(total_steps=12))
    t1.run()
    t2 = Trainer(cfg, mesh, dc,
                 TrainerConfig(steps=10, ckpt_every=3, ckpt_dir=str(tmp_path),
                               log_every=100),
                 adamw.AdamWConfig(total_steps=12))
    _, hist = t2.run()
    assert hist[0]["step"] == 6       # resumed, not restarted


def test_straggler_detection():
    cfg = reduce_config(get_config("smollm-135m"), layers_per_segment=1)
    mesh = make_host_mesh()
    tr = Trainer(cfg, mesh, DataConfig(2, 16),
                 TrainerConfig(steps=1, ckpt_dir="/tmp/_unused_ck"),
                 adamw.AdamWConfig())
    tr.step_times = [0.1] * 10
    tr._watch_straggler(0.5, 11)      # 5x median
    assert tr.stragglers == 1
    tr._watch_straggler(0.11, 12)
    assert tr.stragglers == 1


def test_adamw_converges_quadratic():
    ocfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                             weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init_state(ocfg, params)
    for _ in range(150):
        g = {"w": 2 * state["master"]["w"]}
        params, state, _ = adamw.apply_updates(ocfg, state, g, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_expert_placement_reduces_lambda_cost():
    """End-to-end paper pipeline: trace -> hypergraph -> replication plan;
    the replicated plan must cost no more than the baseline and raise the
    local fraction."""
    trace = synthetic_trace(n_experts=32, n_tokens=5000, top_k=4, seed=0)
    res = plan_expert_placement(trace, 32, 4, eps=0.5, kappa0=400)
    assert res.lambda_cost_repl <= res.lambda_cost_no_repl + 1e-9
    assert res.local_fraction_repl >= res.local_fraction_no_repl
    ev = evaluate_plan(res.plan, trace, kappa0=400)
    assert ev["replicated_experts"] >= 1
    # the plan covers every expert
    local = np.array(res.plan.local_slot)
    assert np.all((local >= 0).sum(axis=0) >= 1)


def test_route_trace_shapes():
    cfg = reduce_config(get_config("olmoe-1b-7b"), layers_per_segment=2)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    traces = model.route_trace(params, batch)
    assert len(traces) == 1
    L, T, k = traces[0].shape
    assert (L, T, k) == (2, 32, cfg.top_k)
    assert int(traces[0].max()) < cfg.n_experts


def test_plan_remat_directions():
    """BSP-replication->remat bridge: big models at long seq must choose
    recompute (replication); tiny models with headroom must not."""
    from repro.core.placement import plan_remat
    big = plan_remat(get_config("yi-34b"), B=256, S=4096, dp=16, tp=16)
    assert big.policy == "full"
    assert big.save_bytes > 8e9 or big.recompute_seconds < big.save_seconds
    small = plan_remat(reduce_config(get_config("smollm-135m")),
                       B=2, S=64, dp=1, tp=1)
    assert small.policy == "none"
    assert small.fits_budget
