"""Schedule-engine invariants: deltas must equal full recomputation, and
transactions must round-trip exactly.

The ``ScheduleState`` engine (src/repro/core/schedule/engine.py) maintains
per-superstep top-2 load maxima, cached superstep costs and an undo log so
heuristic trial moves are O(touched supersteps).  These tests pin it to
full recomputation (``cost()`` over the raw rows) and to the preserved seed
implementation in ``reference.py`` -- the engine-backed heuristics must
reproduce the oracle's final costs exactly, not just approximately.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hypergraph import Dag
from repro.core.schedule import (BspInstance, Schedule, advanced_heuristic,
                                 basic_heuristic, bspg_schedule, exact_schedule,
                                 hill_climb)
from repro.core.schedule import reference as ref


def random_dag(n, seed, fanin=3, p_edge=0.5, n_src=8, weighted=False):
    rng = np.random.default_rng(seed)
    edges = []
    for v in range(n_src, n):
        for u in rng.choice(v, size=min(fanin, v), replace=False):
            if rng.random() < p_edge:
                edges.append((int(u), v))
    omega = rng.uniform(0.5, 4.0, size=n) if weighted else None
    mu = rng.uniform(0.5, 3.0, size=n) if weighted else None
    return Dag(n=n, edge_list=edges, omega=omega, mu=mu)


def random_schedule(inst, rng, S=6):
    """Structurally legal (not necessarily precedence-valid) schedule --
    engine cost invariants do not depend on DAG validity."""
    sched = Schedule(inst, S)
    for v in range(inst.dag.n):
        sched.add_comp(v, int(rng.integers(inst.P)), int(rng.integers(S)))
    for _ in range(inst.dag.n // 2):
        v = int(rng.integers(inst.dag.n))
        src = next(iter(sched.assign[v]))
        dst = int(rng.integers(inst.P))
        if dst != src and (v, dst) not in sched.comms:
            sched.add_comm(v, src, dst, int(rng.integers(S)))
    return sched


def snapshot(sched):
    return (
        sched.S,
        [[frozenset(ps) for ps in row] for row in sched.comp],
        dict(sched.comms),
        {k: frozenset(v) for k, v in sched.src_index.items() if v},
        [dict(a) for a in sched.assign],
        [list(r) for r in sched.work],
        [list(r) for r in sched.sent],
        [list(r) for r in sched.recv],
        list(sched._scost),
        sched._total,
    )


def _random_op(sched, rng):
    """One random structurally legal primitive mutation; returns the pure
    delta that was priced for it (or None if no op was possible)."""
    P, S = sched.inst.P, sched.S
    for _ in range(20):
        kind = int(rng.integers(5))
        v = int(rng.integers(sched.inst.dag.n))
        if kind == 0:  # add_comp
            free = [p for p in range(P) if p not in sched.assign[v]]
            if not free:
                continue
            p, s = int(rng.choice(free)), int(rng.integers(S))
            d = sched.delta_add_comp(v, p, s)
            sched.add_comp(v, p, s)
            return d
        if kind == 1 and len(sched.assign[v]) > 1:  # remove_comp
            p = int(rng.choice(list(sched.assign[v])))
            d = sched.delta_remove_comp(v, p)
            sched.remove_comp(v, p)
            return d
        if kind == 2:  # add_comm
            if not sched.assign[v]:
                continue
            src = int(rng.choice(list(sched.assign[v])))
            dst = int(rng.integers(P))
            if dst == src or (v, dst) in sched.comms:
                continue
            s = int(rng.integers(S))
            d = sched.delta_add_comm(v, src, dst, s)
            sched.add_comm(v, src, dst, s)
            return d
        if kind == 3 and sched.comms:  # remove_comm
            keys = sorted(sched.comms.keys())
            v, dst = keys[int(rng.integers(len(keys)))]
            d = sched.delta_remove_comm(v, dst)
            sched.remove_comm(v, dst)
            return d
        if kind == 4 and sched.comms:  # move_comm
            keys = sorted(sched.comms.keys())
            v, dst = keys[int(rng.integers(len(keys)))]
            t = int(rng.integers(S))
            d = sched.delta_move_comm(v, dst, t)
            sched.move_comm(v, dst, t)
            return d
    return None


@given(st.integers(0, 10_000), st.booleans())
@settings(max_examples=20, deadline=None)
def test_property_delta_matches_recompute(seed, weighted):
    """Every pure delta_* must equal the full-recompute cost difference of
    actually applying the move; the maintained total, step costs and top-2
    maxima must stay consistent throughout (``check()``)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(12, 40))
    dag = random_dag(n, seed, weighted=weighted)
    inst = BspInstance(dag, P=int(rng.integers(2, 6)),
                       g=float(rng.integers(1, 6)), L=float(rng.integers(0, 25)))
    sched = random_schedule(inst, rng)
    assert abs(sched.current_cost() - sched.cost()) < 1e-9
    for _ in range(40):
        before = sched.cost()
        d = _random_op(sched, rng)
        if d is None:
            continue
        after = sched.cost()
        assert abs((after - before) - d) < 1e-9, "delta != recompute"
        assert abs(sched.current_cost() - after) < 1e-9, "total drifted"
    sched.check()


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_property_rollback_roundtrip(seed):
    """begin + random mutations + rollback must restore the entire state
    bit-for-bit (containers and floats), even with irrational weights and
    nested frames."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(12, 40))
    dag = random_dag(n, seed, weighted=True)
    inst = BspInstance(dag, P=int(rng.integers(2, 6)),
                       g=float(rng.random() * 5), L=float(rng.random() * 20))
    sched = random_schedule(inst, rng)
    snap0 = snapshot(sched)
    sched.begin()
    for _ in range(25):
        _random_op(sched, rng)
    if rng.random() < 0.5:  # nested frame: commit folds into the outer one
        sched.begin()
        for _ in range(10):
            _random_op(sched, rng)
        sched.rollback() if rng.random() < 0.5 else sched.commit()
    sched.rollback()
    assert snapshot(sched) == snap0
    sched.check()
    # committed mutations survive
    sched.begin()
    d = _random_op(sched, rng)
    snap1 = snapshot(sched)
    sched.commit()
    assert snapshot(sched) == snap1
    sched.check()


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_node_move_delta(seed):
    """delta_node_move must price exactly what apply_node_move changes."""
    rng = np.random.default_rng(seed)
    dag = random_dag(int(rng.integers(20, 60)), seed, weighted=bool(seed % 2))
    inst = BspInstance(dag, P=int(rng.integers(2, 6)),
                       g=float(rng.integers(1, 6)), L=float(rng.integers(0, 25)))
    sched = bspg_schedule(inst, seed=seed)
    moved = 0
    for _ in range(30):
        v = int(rng.integers(dag.n))
        q = int(rng.integers(inst.P))
        if len(sched.assign[v]) != 1:
            continue
        (p, s), = sched.assign[v].items()
        if q == p:
            continue
        if any(not sched.present_at(u, q, s) for u in dag.parents[v]):
            continue
        uses_p = sched.uses_on(v, p)
        if uses_p and min(uses_p) <= s:
            continue
        before = sched.cost()
        d = sched.delta_node_move(v, q)
        sched.apply_node_move(v, q)
        assert abs((sched.cost() - before) - d) < 1e-9
        assert abs(sched.current_cost() - sched.cost()) < 1e-9
        moved += 1
    sched.check()
    assert not sched.validate()


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_compact_and_copy_preserve_state(seed):
    rng = np.random.default_rng(seed)
    dag = random_dag(int(rng.integers(20, 50)), seed)
    inst = BspInstance(dag, P=4, g=2.0, L=5.0)
    sched = random_schedule(inst, rng, S=8)
    c = sched.cost()
    cp = sched.copy()
    cp.check()
    assert abs(cp.cost() - c) < 1e-9
    sched.compact()
    sched.check()
    assert abs(sched.cost() - c) < 1e-9  # only empty supersteps removed
    assert abs(cp.cost() - c) < 1e-9     # copy untouched by compact


class TestOracleEquivalence:
    """Engine-backed heuristics vs the preserved seed implementation: same
    decisions, hence identical final costs (integer weights => exact)."""

    @pytest.mark.parametrize("seed,P,g,L", [
        (0, 4, 4, 20), (1, 8, 2, 5), (2, 4, 16, 40), (3, 2, 1, 0),
        (4, 8, 4, 20), (5, 3, 8, 100),
    ])
    def test_pipeline_costs_identical(self, seed, P, g, L):
        dag = random_dag(110 + 10 * seed, seed)
        inst = BspInstance(dag, P=P, g=float(g), L=float(L))
        new_hc = hill_climb(bspg_schedule(inst, seed=seed), seed=seed)
        ref_hc = ref.hill_climb(ref.bspg_schedule(inst, seed=seed), seed=seed)
        assert new_hc.current_cost() == ref_hc.current_cost()
        new_b = basic_heuristic(new_hc.copy())
        ref_b = ref.basic_heuristic(ref_hc.copy())
        assert new_b.current_cost() == ref_b.current_cost()
        new_a = advanced_heuristic(new_hc.copy())
        ref_a = ref.advanced_heuristic(ref_hc.copy())
        assert new_a.current_cost() == ref_a.current_cost()
        # same trajectory => same shape, not just same cost
        assert new_a.S == ref_a.S
        assert new_a.stats()["replicas"] == ref_a.stats()["replicas"]
        assert new_a.stats()["comms"] == ref_a.stats()["comms"]
        assert not new_a.validate()

    def test_dataset_instance_identical(self):
        from repro.datagen import hdb_dataset
        dag = hdb_dataset(scale=1)[4]  # CG: deepest structure of the mix
        inst = BspInstance(dag, P=8, g=4.0, L=20.0)
        new_a = advanced_heuristic(
            hill_climb(bspg_schedule(inst, seed=0), seed=0))
        ref_a = ref.advanced_heuristic(
            ref.hill_climb(ref.bspg_schedule(inst, seed=0), seed=0))
        assert new_a.current_cost() == ref_a.current_cost()

    def test_exact_uses_engine_schedule(self):
        dag = Dag(n=8, edge_list=[(0, 4), (1, 4), (2, 5), (3, 6), (4, 7),
                                  (5, 7)])
        inst = BspInstance(dag, P=2, g=3.0, L=4.0)
        out = exact_schedule(inst, max_supersteps=3, time_limit=20)
        assert out.assignments_optimal
        assert isinstance(out.schedule, Schedule)
        out.schedule.check()
        assert not out.schedule.validate()


def test_eps_shared_constant():
    """The stack's cost tolerance lives in one place (bsp.EPS)."""
    from repro.core.schedule import EPS
    from repro.core.schedule import bsp, engine
    assert EPS == bsp.EPS == engine.EPS == ref.EPS == 1e-12
