"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and absence of NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduce_config
from repro.models.model import Model

ARCHS = ["hubert-xlarge", "yi-34b", "deepseek-coder-33b", "smollm-135m",
         "deepseek-7b", "olmoe-1b-7b", "deepseek-v3-671b",
         "llama-3.2-vision-11b", "falcon-mamba-7b", "hymba-1.5b"]

B, S = 2, 32


def make_batch(cfg, rng):
    batch = {}
    if cfg.frame_input:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                      jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.n_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
    return batch


def test_registry_complete():
    assert set(ARCHS) <= set(list_archs())


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = reduce_config(get_config(arch))
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0

    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), \
        f"{arch}: non-finite grads"
    # at least one nonzero gradient leaf
    assert any(float(jnp.abs(g.astype(jnp.float32)).sum()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ["smollm-135m", "olmoe-1b-7b",
                                  "deepseek-v3-671b", "falcon-mamba-7b",
                                  "hymba-1.5b", "llama-3.2-vision-11b"])
def test_decode_matches_prefill(arch):
    """Greedy decode over the same tokens must equal teacher-forced logits."""
    cfg = reduce_config(get_config(arch))
    model = Model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, rng)
    max_len = S + 4

    # teacher-forced forward
    x, _ = model.forward(params, batch, mode="dense")
    full_logits = model.logits_fn(params, x)

    # prefill on the first S-1 tokens, then decode token S-1
    pre_batch = dict(batch)
    if not cfg.frame_input:
        pre_batch["tokens"] = batch["tokens"][:, :S - 1]
    else:
        pre_batch["frames"] = batch["frames"][:, :S - 1]
    logits_last, caches = jax.jit(
        lambda p, b: model.prefill(p, b, max_len))(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits_last[:, 0]), np.asarray(full_logits[:, S - 2]),
        rtol=2e-2, atol=2e-2)

    tok = (batch["tokens"][:, S - 1:S] if not cfg.frame_input
           else batch["frames"][:, S - 1:S])
    step_logits, _ = jax.jit(
        lambda p, t, c: model.decode_step(p, t, c, jnp.int32(S - 1))
    )(params, tok, caches)
    # bf16 accumulation (absorbed-MLA decode is exact in f32 but ~3e-2 in
    # bf16); verified exact with absorb=False in layer-level tests
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, S - 1]),
        rtol=6e-2, atol=5e-2)


def test_param_counts_match_published():
    """Full configs must land near the published parameter counts."""
    expect = {
        "yi-34b": 34.4e9,
        "deepseek-coder-33b": 33.3e9,
        "smollm-135m": 0.135e9,
        "deepseek-7b": 6.9e9,
        "olmoe-1b-7b": 6.9e9,
        "deepseek-v3-671b": 671e9,
        "falcon-mamba-7b": 7.3e9,
        "hymba-1.5b": 1.5e9,
    }
    for name, target in expect.items():
        n = get_config(name).param_count()
        assert abs(n - target) / target < 0.15, \
            f"{name}: {n/1e9:.2f}B vs published {target/1e9:.2f}B"


def test_moe_active_params():
    cfg = get_config("deepseek-v3-671b")
    active = cfg.active_param_count()
    assert abs(active - 37e9) / 37e9 < 0.25, f"{active/1e9:.1f}B active"
