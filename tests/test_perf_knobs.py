"""Correctness of the beyond-paper perf knobs (EXPERIMENTS.md SS-Perf)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import layers as L
from repro.models.config import Segment
from repro.models.model import Model


def test_head_padding_masks_pad_heads():
    """Padded q-heads must not contribute: corrupting their wq/wo rows
    leaves the output unchanged (arch-faithfulness of the cell-A knob)."""
    cfg = reduce_config(get_config("yi-34b"))          # 4 heads, kv 2
    cfg = cfg.with_(n_heads=3, n_kv_heads=1, n_heads_padded=4)
    seg = Segment("dense", 1)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda w: w[0], params["segments"][0])["attn"]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)

    out = L.gqa_attention(lp, x, cfg, seg)
    # corrupt the pad head (head index 3 = last in its kv group of 4)
    hd = cfg.hd
    wq = np.asarray(lp["wq"], np.float32)
    wq[:, 3 * hd:4 * hd] = 1e3
    lp2 = dict(lp, wq=jnp.asarray(wq, lp["wq"].dtype))
    out2 = L.gqa_attention(lp2, x, cfg, seg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_head_padding_decode_consistency():
    cfg = reduce_config(get_config("yi-34b")).with_(
        n_heads=3, n_kv_heads=1, n_heads_padded=4)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    x, _ = model.forward(params, batch, mode="dense")
    full = model.logits_fn(params, x)
    pre = {"tokens": batch["tokens"][:, :S - 1]}
    _, caches = model.prefill(params, pre, S + 2)
    step, _ = model.decode_step(params, batch["tokens"][:, S - 1:S], caches,
                                jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, S - 1]),
                               rtol=5e-2, atol=5e-2)


def test_mla_absorb_equivalence():
    """Absorbed-weight MLA decode == naive expansion (cell-hillclimb knob
    for decode cells), exact in f32."""
    cfg = reduce_config(get_config("deepseek-v3-671b")).with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    lp = jax.tree.map(lambda w: w[0], params["segments"][0])["attn"]
    rng = np.random.default_rng(2)
    B, S = 2, 8
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    cache = L.mla_prefill_cache(lp, x[:, :S - 1], cfg, S + 2)
    outs = {}
    for absorb in (True, False):
        y, _ = L.mla_attention_decode(lp, x[:, S - 1:], cfg, cache,
                                      jnp.int32(S - 1), absorb=absorb)
        outs[absorb] = np.asarray(y)
    np.testing.assert_allclose(outs[True], outs[False], rtol=1e-4, atol=1e-5)


def test_analytic_cost_model_knob_directions():
    """Napkin-math engine: each knob must move its term the right way."""
    from repro.roofline.model import step_cost
    cfg = get_config("yi-34b")
    base = step_cost(cfg, 256, 4096, 4096, 16, 16, "train")
    padded = step_cost(cfg.with_(n_heads_padded=64), 256, 4096, 4096,
                       16, 16, "train")
    assert padded["flops"] < base["flops"] * 0.5

    v3 = get_config("deepseek-v3-671b")
    b = step_cost(v3, 256, 4096, 4096, 32, 16, "train")
    z = step_cost(v3.with_(zero_opt_state=True), 256, 4096, 4096,
                  32, 16, "train")
    assert z["coll_bytes"] < b["coll_bytes"]
    assert z["hbm_bytes"] < b["hbm_bytes"]

    moe = get_config("olmoe-1b-7b")
    b = step_cost(moe, 256, 4096, 4096, 16, 16, "train")
    pl = step_cost(moe.with_(expert_placement=(0.3, 1.25)), 256, 4096, 4096,
                   16, 16, "train")
    assert pl["coll_bytes"] < b["coll_bytes"]


def test_cost_model_monotonicity_properties():
    """Roofline cost model invariants used by the hillclimb napkin math."""
    import dataclasses
    from repro.roofline.model import step_cost
    cfg = get_config("deepseek-7b")
    # more layers -> proportionally more flops
    seg = cfg.segments[0]
    c30 = step_cost(cfg, 64, 1024, 1024, 8, 8, "prefill")
    c60 = step_cost(cfg.with_(segments=(
        dataclasses.replace(seg, n_layers=60),)), 64, 1024, 1024, 8, 8,
        "prefill")
    assert c60["flops"] > 1.8 * c30["flops"]
    # train >= 3x prefill flops (fwd+bwd+remat)
    t = step_cost(cfg, 64, 1024, 1024, 8, 8, "train")
    p = step_cost(cfg, 64, 1024, 1024, 8, 8, "prefill")
    assert t["flops"] >= 3 * p["flops"]
    # decode flops << prefill flops at same context
    d = step_cost(cfg, 64, 1, 1024, 8, 8, "decode")
    assert d["flops"] < p["flops"] / 100
    # more dp -> fewer per-device flops
    half = step_cost(cfg, 64, 1024, 1024, 16, 8, "prefill")
    assert half["flops"] < p["flops"]
    # sliding window cheaper than full attention at long K
    hy = get_config("hymba-1.5b")
    full = step_cost(hy.with_(segments=tuple(
        dataclasses.replace(s, sliding_window=0) for s in hy.segments)),
        8, 32768, 32768, 8, 8, "prefill")
    swa = step_cost(hy, 8, 32768, 32768, 8, 8, "prefill")
    assert swa["flops"] < full["flops"]
