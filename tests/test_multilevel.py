"""Multilevel V-cycle invariants (core/partition/multilevel.py).

The V-cycle's contract is that coarsening/projection change *where* the
search runs, never what anything costs: contraction conserves weights and
pin structure, mask projection is bit-exactly cost-preserving against a
from-scratch fine-level ``PartitionState``, refinement only ever lowers
the cost, and the end-to-end result is never worse than the flat
heuristic wherever both run.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hypergraph import Hypergraph
from repro.core.partition import (MultilevelOptions, PartitionState,
                                  is_valid, multilevel_partition,
                                  partition_cost, partition_heuristic,
                                  partition_with_replication,
                                  partition_with_replication_multilevel)
from repro.core.partition import multilevel as ml
from repro.datagen import large_row_net, spmv_dataset


def random_hypergraph(rng, n=None, m=None):
    n = n or int(rng.integers(8, 40))
    m = m or int(rng.integers(5, 60))
    edges = [tuple(rng.choice(n, size=int(rng.integers(2, min(6, n) + 1)),
                              replace=False)) for _ in range(m)]
    return Hypergraph(n=n, edges=edges, omega=rng.random(n) + 0.5,
                      mu=rng.random(m) + 0.1)


# ------------------------------------------------------------- contraction

@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_contraction_invariants(seed):
    """Weight conservation, pin-set correctness, identical-net collapsing
    and the edge prolongation map, for random matchings."""
    rng = np.random.default_rng(seed)
    hg = random_hypergraph(rng)
    cmap, nc = ml.heavy_pin_matching(hg, max_weight=np.inf, rng=rng)
    assert nc <= hg.n and np.all((cmap >= 0) & (cmap < nc))
    coarse, emap = hg.contract(cmap, nc)
    # node weight conservation (cluster sums)
    assert abs(coarse.omega.sum() - hg.omega.sum()) < 1e-9
    want_omega = np.zeros(nc)
    np.add.at(want_omega, cmap, hg.omega)
    assert np.allclose(coarse.omega, want_omega)
    # per-edge pin sets and the prolongation map
    mu_sums = np.zeros(len(coarse.edges))
    for ei, e in enumerate(hg.edges):
        mapped = sorted({int(cmap[v]) for v in e})
        if len(mapped) < 2:
            assert emap[ei] == -1       # dropped: can never cost anything
        else:
            assert coarse.edges[emap[ei]] == tuple(mapped)
            mu_sums[emap[ei]] += hg.mu[ei]
    # identical-net collapsing: coarse mu is the sum of its fine edges
    assert np.allclose(coarse.mu, mu_sums)
    # prolongation round trip: coarse masks -> fine -> per-cluster constant
    masks_c = rng.integers(1, 16, size=nc)
    fine = ml.project_masks(cmap, masks_c)
    for v in range(hg.n):
        assert fine[v] == masks_c[cmap[v]]


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_projection_bit_exact(seed):
    """``PartitionState.from_projection`` must equal a from-scratch build
    on the projected masks -- same uncov, lambdas, cost (bit-equal) and
    loads -- and the coarse cost must equal the projected fine cost."""
    rng = np.random.default_rng(seed)
    hg = random_hypergraph(rng)
    P = int(rng.integers(2, 5))
    cmap, nc = ml.heavy_pin_matching(hg, max_weight=np.inf, rng=rng)
    coarse, emap = hg.contract(cmap, nc)
    masks_c = rng.integers(1, 1 << P, size=nc)
    cst = PartitionState(coarse, P, masks=masks_c)
    proj = PartitionState.from_projection(hg, P, cst, cmap, emap)
    fresh = PartitionState(hg, P, masks=masks_c[cmap])
    assert np.array_equal(proj.uncov, fresh.uncov)
    assert np.array_equal(proj.edge_lambda, fresh.edge_lambda)
    assert proj.cost == fresh.cost          # bit-equal, same reduction
    assert np.allclose(proj.loads, fresh.loads)
    # the multilevel cost identity (float tolerance: mu sums regroup)
    assert abs(cst.cost - proj.cost) < 1e-9 * max(1.0, abs(cst.cost))
    # projection with unassigned coarse nodes (exact-solver style masks)
    masks_c0 = masks_c.copy()
    masks_c0[rng.integers(0, nc)] = 0
    cst0 = PartitionState(coarse, P, masks=masks_c0)
    proj0 = PartitionState.from_projection(hg, P, cst0, cmap, emap)
    fresh0 = PartitionState(hg, P, masks=masks_c0[cmap])
    assert np.array_equal(proj0.edge_lambda, fresh0.edge_lambda)
    assert proj0.cost == fresh0.cost


def test_uncov_rows_chunking_exact(monkeypatch):
    """The memory-bounded blocked uncov build must equal the monolithic
    one (integer sums, any block split)."""
    from repro.core.partition import engine
    rng = np.random.default_rng(3)
    hg = random_hypergraph(rng, n=30, m=80)
    P = 4
    masks = rng.integers(0, 1 << P, size=hg.n)
    big = PartitionState(hg, P, masks=masks).uncov
    monkeypatch.setattr(engine, "_UNCOV_CHUNK_ELEMS", 32)
    small = PartitionState(hg, P, masks=masks).uncov
    assert np.array_equal(big, small)


def test_composed_maps_match_stepwise():
    """Skip-level projection (composed cmaps/edge_maps) must match
    projecting one level at a time."""
    rng = np.random.default_rng(11)
    hg = large_row_net(1024, seed=5)
    P = 4
    opts = MultilevelOptions(coarsest_n=64)
    levels, cmaps, emaps = ml.build_levels(hg, P, 0.1, opts, rng)
    assert len(levels) >= 3, "instance did not coarsen enough to test"
    masks_c = rng.integers(1, 1 << P, size=levels[2].n)
    cst = PartitionState(levels[2], P, masks=masks_c)
    step = PartitionState.from_projection(levels[1], P, cst, cmaps[1],
                                          emaps[1])
    step = PartitionState.from_projection(levels[0], P, step, cmaps[0],
                                          emaps[0])
    cmap, emap = ml._compose_maps(cmaps, emaps, 0, 2)
    direct = PartitionState.from_projection(levels[0], P, cst, cmap, emap)
    assert np.array_equal(step.masks, direct.masks)
    assert np.array_equal(step.edge_lambda, direct.edge_lambda)
    assert step.cost == direct.cost


# ------------------------------------------------- candidate front pruning

@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_connected_pruning_decision_safe(seed):
    """Candidates dropped by the connected-targets restriction must never
    be strictly improving (so restricting fronts cannot change decisions)."""
    from repro.core.frontier import (connected_targets, fm_move_candidates,
                                     move_candidates, price_mask_front)
    rng = np.random.default_rng(seed)
    hg = random_hypergraph(rng)
    P = int(rng.integers(2, 5))
    masks = rng.integers(1, 1 << P, size=hg.n)
    state = PartitionState(hg, P, masks=masks)
    vs = np.arange(hg.n)
    conn = connected_targets(state, vs)
    full_c, full_x = move_candidates(state, vs)
    deltas = price_mask_front(state, vs, full_c, full_x)
    for i, v in enumerate(vs):
        for j in range(full_x[i], full_x[i + 1]):
            q = int(full_c[j]).bit_length() - 1
            if not conn[i, q]:
                assert deltas[j] >= -1e-12, (v, q, deltas[j])
    # and the restricted builder emits exactly the connected subset
    sub_c, sub_x = fm_move_candidates(state, vs)
    for i in range(len(vs)):
        got = list(sub_c[sub_x[i]:sub_x[i + 1]])
        want = [c for c in full_c[full_x[i]:full_x[i + 1]]
                if conn[i, int(c).bit_length() - 1]]
        assert got == want


# ----------------------------------------------------------------- V-cycle

def test_refinement_never_increases_cost_per_level():
    hg = large_row_net(2048, seed=3)
    P, eps = 4, 0.1
    stats = []
    res = multilevel_partition(hg, P, eps, seed=0, stats=stats)
    assert len(stats) >= 2, "no coarsening happened"
    for row in stats:
        assert row["cost_refined"] <= row["cost_projected"] + 1e-9
    # consecutive levels chain: next projection starts from this cost
    for a, b in zip(stats[1:], stats[2:]):
        assert abs(b["cost_projected"] - a["cost_refined"]) < 1e-6
    assert is_valid(hg, res.masks, P, eps)
    assert abs(partition_cost(hg, res.masks, P) - res.cost) < 1e-9


@pytest.mark.parametrize("n,P,eps", [(1536, 4, 0.1), (2048, 8, 0.05)])
def test_multilevel_not_worse_than_flat(n, P, eps):
    """Final-cost parity (<=) against the flat heuristic on streaming
    row-net instances large enough for a real V-cycle."""
    hg = large_row_net(n, seed=1)
    flat = partition_heuristic(hg, P, eps, seed=0)
    mlr = multilevel_partition(hg, P, eps, seed=0)
    assert is_valid(hg, mlr.masks, P, eps)
    assert mlr.cost <= flat.cost + 1e-9


def test_multilevel_matches_flat_on_shipped_datasets():
    """Shipped spmv datasets sit below the coarsest-level threshold: the
    V-cycle falls through to the flat heuristic there, so parity is exact
    equality (the <= criterion holds with equality by construction)."""
    for hg in spmv_dataset("rn", count=2, seed=0):
        flat = partition_heuristic(hg, 4, 0.1, seed=0)
        mlr = multilevel_partition(hg, 4, 0.1, seed=0)
        assert mlr.cost == flat.cost
        assert np.array_equal(mlr.masks, flat.masks)


def test_multilevel_replication_end_to_end():
    """The replication V-cycle returns a valid replicated solution at or
    below the non-replicating base, and the multilevel entry of
    partition_with_replication routes to it."""
    hg = large_row_net(2048, seed=2)
    P, eps = 4, 0.1
    base, rep = partition_with_replication_multilevel(hg, P, eps, seed=0)
    assert is_valid(hg, base.masks, P, eps)
    assert is_valid(hg, rep.masks, P, eps)
    assert rep.cost <= base.cost + 1e-9
    assert abs(partition_cost(hg, rep.masks, P) - rep.cost) < 1e-9
    # the public entry point routes through the same driver
    base2, rep2 = partition_with_replication(hg, P, eps, seed=0,
                                             multilevel=True)
    assert base2.cost == base.cost and rep2.cost == rep.cost


def test_immediate_stagnation_falls_through_to_flat():
    """When matching cannot pair anything (every edge above the scoring
    size cap), no coarse level exists and both drivers must degenerate to
    the flat path instead of crashing."""
    rng = np.random.default_rng(0)
    n = 480
    edges = [tuple(rng.choice(n, size=30, replace=False)) for _ in range(90)]
    hg = Hypergraph(n=n, edges=edges)
    res = multilevel_partition(hg, 4, 0.05, seed=0)
    flat = partition_heuristic(hg, 4, 0.05, seed=0)
    assert res.cost == flat.cost
    base, rep = partition_with_replication_multilevel(hg, 4, 0.05, seed=0)
    assert is_valid(hg, rep.masks, 4, 0.05)
    assert rep.cost <= base.cost + 1e-9


def test_multilevel_entry_keeps_exact_small_instance_path():
    """partition_with_replication(multilevel=True) must still solve tiny
    instances exactly (the base-ILP comparison precedes V-cycle routing)."""
    hg = Hypergraph(n=10, edges=[(0, 1, 2), (3, 4), (5, 6, 7), (8, 9),
                                 (1, 5)])
    flat = partition_with_replication(hg, 2, 0.3, seed=0)
    mlv = partition_with_replication(hg, 2, 0.3, seed=0, multilevel=True)
    assert (flat[0].cost, flat[1].cost) == (mlv[0].cost, mlv[1].cost)


def test_multilevel_dup_mode_caps_replicas():
    hg = large_row_net(1536, seed=4)
    P, eps = 4, 0.1
    _, rep = partition_with_replication_multilevel(hg, P, eps, mode="dup",
                                                   seed=0)
    assert is_valid(hg, rep.masks, P, eps, max_replicas=2)


# ------------------------------------------------------------- datagen knob

def test_large_row_net_structure():
    """Streaming generator: sorted unique in-range pins, >= 2 pins per
    edge, column-nnz node weights, deterministic in (n, seed)."""
    hg = large_row_net(4096, seed=9)
    assert hg.n <= 4096 and len(hg.edges) > 0
    for e in (hg.edges[0], hg.edges[len(hg.edges) // 2], hg.edges[-1]):
        assert list(e) == sorted(set(e))
        assert len(e) >= 2
        assert all(0 <= v < hg.n for v in e)
    assert np.all(hg.omega >= 1.0)
    again = large_row_net(4096, seed=9)
    assert again.edges == hg.edges
    assert np.array_equal(again.omega, hg.omega)
