"""Test-suite bootstrap.

``hypothesis`` is an optional dependency: when it is absent (the container
does not ship it) we install a minimal, deterministic fallback that covers
the subset of the API the tests use -- ``given``, ``settings`` and the
``integers`` / ``booleans`` / ``lists`` / ``data`` strategies.  Examples are
drawn from a fixed-seed ``numpy`` generator, so the fallback behaves like
hypothesis with ``derandomize=True`` (fewer examples, but the property tests
still collect and exercise the code instead of erroring the whole suite).
"""
from __future__ import annotations

import functools
import inspect
import sys
import types


def _install_hypothesis_fallback() -> None:
    import numpy as np

    class _Strategy:
        def __init__(self, draw_fn, name="strategy"):
            self._draw = draw_fn
            self._name = name

        def draw(self, rng):
            return self._draw(rng)

        def __repr__(self):
            return f"<fallback {self._name}>"

    def integers(min_value=None, max_value=None):
        lo = -(2 ** 31) if min_value is None else int(min_value)
        hi = 2 ** 31 - 1 if max_value is None else int(max_value)
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)),
                         f"integers({lo},{hi})")

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans")

    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: float(lo + (hi - lo) * rng.random()),
                         f"floats({lo},{hi})")

    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]
        return _Strategy(draw, f"lists[{min_size},{max_size}]")

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))],
                         "sampled_from")

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    _DATA = object()  # sentinel: "pass a DataObject for this argument"

    def data():
        return _DATA

    def settings(**kw):
        def deco(fn):
            fn._fallback_settings = kw
            return fn
        return deco

    def given(*gargs, **gkwargs):
        def deco(fn):
            cfg = getattr(fn, "_fallback_settings", {})
            n_examples = min(int(cfg.get("max_examples", 100) or 100), 25)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for i in range(n_examples):
                    rng = np.random.default_rng(0xC0FFEE + 7919 * i)
                    drawn = [
                        _DataObject(rng) if s is _DATA else s.draw(rng)
                        for s in gargs
                    ]
                    kw_drawn = {
                        k: (_DataObject(rng) if s is _DATA else s.draw(rng))
                        for k, s in gkwargs.items()
                    }
                    fn(*args, *drawn, **kwargs, **kw_drawn)

            # Hide the drawn parameters from pytest's fixture resolution
            # (they are filled by the wrapper, last positionals first).
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if gargs:
                params = params[:-len(gargs)]
            params = [p for p in params if p.name not in gkwargs]
            wrapper.__signature__ = sig.replace(parameters=params)
            wrapper.hypothesis_fallback = True
            return wrapper
        return deco

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.booleans = booleans
    st.floats = floats
    st.lists = lists
    st.sampled_from = sampled_from
    st.data = data

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    hyp.__is_fallback__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - exercised implicitly by collection
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()


# Environments without the jax toolchain (e.g. the CI runner) still test the
# pure-python core; the accelerator-facing modules need jax at import time.
try:  # pragma: no cover
    import jax  # noqa: F401
except ImportError:
    collect_ignore = [
        "test_elastic.py",
        "test_front_pass.py",
        "test_kernels.py",
        "test_models_smoke.py",
        "test_perf_knobs.py",
        "test_sharding.py",
        "test_substrate.py",
    ]
