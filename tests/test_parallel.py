"""Process-parallel shared-memory V-cycles (core/partition/parallel.py).

The parallel layer's contract has three legs, each pinned here:

  * **Bit-identity where promised.**  Sharded heavy-pin scoring must
    reproduce the serial ``pref``/``cmap`` byte for byte at every worker
    count; chunked ``contract`` and chunked ``large_row_net`` must equal
    their one-shot forms; CSR-backed hypergraphs must behave like
    tuple-edge ones (equality, pickling, rebuild).
  * **Cost-not-worse where bit-identity is impossible.**  Sharded
    refinement reconciles through accept-only-improving replay, so the
    final cost never exceeds the starting cost, at any worker count, for
    both FM and replication -- and the reconciled state passes the
    engine's full invariant check.
  * **No leaks, both start methods.**  Shared segments are unlinked even
    when workers crash mid-task; fork and spawn pools both work (lazy CSR
    caches are dropped from pickles, attach caches rebuild per process).

Everything that needs a pool is skipped when POSIX shared memory is
unavailable (e.g. /dev/shm-less sandboxes).
"""
import pickle

import numpy as np
import pytest

from repro.core.hypergraph import (Hypergraph, _collapse_ids_dict,
                                   _collapse_ids_hash)
from repro.core.partition import PartitionState
from repro.core.partition.cost import is_valid
from repro.core.partition.heuristic import (fm_refine, partition_heuristic,
                                            partition_with_replication,
                                            replicate_local_search)
from repro.core.partition.multilevel import _match_pref, heavy_pin_matching
from repro.core.partition import parallel as par
from repro.core.partition.parallel import (ParallelContext, ShmRegistry,
                                           boundary_nodes, parallel_refine,
                                           plan_shards, shm_available)
from repro.datagen.spmv import large_row_net

needs_shm = pytest.mark.skipif(not shm_available(),
                               reason="POSIX shared memory unavailable")

START_METHODS = ["fork", "spawn"]


def small_hg(n=1200, seed=1):
    return large_row_net(n, seed=seed)


# ------------------------------------------------------------ CSR plumbing

def test_from_csr_equals_tuple_edges():
    hg = small_hg()
    view = Hypergraph.from_csr(hg.n, hg.xpins, hg.pins, omega=hg.omega,
                               mu=hg.mu)
    tup = Hypergraph(n=hg.n, edges=[tuple(e) for e in hg.edges],
                     omega=hg.omega, mu=hg.mu, presorted=True)
    assert view.edges == tup.edges and tup.edges == list(view.edges)
    assert view.num_pins == tup.num_pins
    for a, b in zip(view._build_csr(), tup._build_csr()):
        assert np.array_equal(a, b)


def test_hypergraph_pickle_drops_csr_cache():
    """Fork/spawn safety: pickles never carry the lazy CSR cache (a
    10^7-pin instance would ship every pin twice), and the cache rebuilds
    bit-identically after unpickling -- for both edge representations."""
    for hg in (small_hg(), Hypergraph.from_csr(
            small_hg().n, small_hg().xpins, small_hg().pins)):
        csr0 = hg._build_csr()
        clone = pickle.loads(pickle.dumps(hg))
        assert clone._csr is None           # cache not shipped
        for a, b in zip(csr0, clone._build_csr()):
            assert np.array_equal(a, b)
        assert clone.edges == hg.edges


def test_dag_pickle_drops_lazy_caches():
    """Same fork/spawn-safety contract for Dag: the lazy CSR and topo-order
    caches are dropped from pickles and rebuild bit-identically."""
    from repro.core.hypergraph import Dag
    rng = np.random.default_rng(3)
    src = rng.integers(0, 50, size=200)
    dst = src + 1 + rng.integers(0, 10, size=200)
    keep = dst < 60
    dag = Dag.from_arrays(60, src[keep], dst[keep])
    csr0 = dag._build_csr()
    clone = pickle.loads(pickle.dumps(dag))
    assert clone._csr is None and clone._topo is None
    for a, b in zip(csr0, clone._build_csr()):
        assert np.array_equal(a, b)


def test_contract_chunked_equals_monolithic():
    hg = small_hg()
    rng = np.random.default_rng(0)
    cmap, nc = heavy_pin_matching(hg, 50.0, rng)
    full, emap_full = hg.contract(cmap, nc)
    for chunk in (64, 1000, 10**9):
        part, emap_part = hg.contract(cmap, nc, chunk_pins=chunk)
        assert part.n == full.n and len(part.edges) == len(full.edges)
        assert np.array_equal(part.xpins, full.xpins)
        assert np.array_equal(part.pins, full.pins)
        assert np.array_equal(part.mu, full.mu)
        assert np.array_equal(emap_part, emap_full)


def test_collapse_hash_equals_dict():
    """The dual-hash identical-net collapse assigns the same coarse ids as
    the byte-key dict reference, including duplicate-heavy inputs."""
    rng = np.random.default_rng(5)
    for _ in range(20):
        m = int(rng.integers(3, 40))
        pool = [tuple(sorted(rng.choice(12, size=int(rng.integers(2, 5)),
                                        replace=False)))
                for _ in range(max(2, m // 3))]
        edges = [pool[int(rng.integers(len(pool)))] for _ in range(m)]
        cp = np.concatenate([np.asarray(e, dtype=np.int64) for e in edges])
        lens = np.array([len(e) for e in edges], dtype=np.int64)
        xk = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(lens, out=xk[1:])
        kept = np.arange(m, dtype=np.int64)
        got = _collapse_ids_hash(cp, xk, kept, lens)
        assert got is not None
        assert np.array_equal(got, _collapse_ids_dict(cp, xk, kept))


def test_large_row_net_chunked_and_alloc_bit_identical():
    one = large_row_net(2000, seed=4)
    chunked = large_row_net(2000, seed=4, chunk_rows=137)
    assert np.array_equal(one.xpins, chunked.xpins)
    assert np.array_equal(one.pins, chunked.pins)
    assert np.array_equal(one.omega, chunked.omega)
    with ShmRegistry() as reg:
        shm = large_row_net(2000, seed=4, chunk_rows=500, alloc=reg.alloc)
        assert np.array_equal(one.xpins, shm.xpins)
        assert np.array_equal(one.pins, shm.pins)
        # zero-copy contract: share() recognizes registry-born arrays
        arr, ref = reg.share(shm.pins)
        assert arr is shm.pins and ref.name is not None


# -------------------------------------------------------------- sharding

def test_plan_shards_partitions_node_range():
    hg = small_hg()
    for W in (1, 2, 3, 8, 10_000):
        b = plan_shards(hg, W)
        assert b[0] == 0 and b[-1] == hg.n
        assert np.all(np.diff(b) >= 0)


def test_boundary_nodes_cover_cross_shard_edges():
    hg = small_hg()
    bounds = plan_shards(hg, 4)
    bnd = set(boundary_nodes(hg, bounds).tolist())
    shard_of = np.searchsorted(bounds[1:-1], np.arange(hg.n), side="right")
    for e in range(len(hg.xpins) - 1):
        pins = hg.pins[hg.xpins[e]:hg.xpins[e + 1]]
        if len(set(shard_of[pins].tolist())) > 1:
            assert set(pins.tolist()) <= bnd


def test_match_pref_shards_bit_identical():
    """The sharding contract of the scorer, without any pool: per-range
    results concatenate into exactly the serial pref."""
    hg = small_hg()
    serial = _match_pref(hg, 24)
    for W in (2, 3, 7):
        b = plan_shards(hg, W)
        parts = [_match_pref(hg, 24, int(b[i]), int(b[i + 1]))
                 for i in range(W) if b[i + 1] > b[i]]
        assert np.array_equal(np.concatenate(parts), serial)


@needs_shm
@pytest.mark.parametrize("W", [1, 2, 4])
def test_pooled_matching_cmap_bit_identical(W):
    hg = small_hg()
    with ParallelContext(W, min_nodes=64) as ctx:
        cm_p, nc_p = heavy_pin_matching(hg, 50.0,
                                        np.random.default_rng(7), ctx=ctx)
        assert not ctx.failed
    cm_s, nc_s = heavy_pin_matching(hg, 50.0, np.random.default_rng(7))
    assert nc_p == nc_s
    assert np.array_equal(cm_p, cm_s)


# ------------------------------------------------- restricted refinement

def test_nodes_restriction_confines_moves():
    """fm_refine/replicate_local_search with ``nodes=`` never touch masks
    outside the allowed set (the worker-shard discipline)."""
    hg = small_hg()
    res = partition_heuristic(hg, 4, 0.1, seed=0)
    allowed = np.arange(0, hg.n // 3, dtype=np.int64)
    outside = np.ones(hg.n, dtype=bool)
    outside[allowed] = False

    st = PartitionState(hg, 4, masks=res.masks.copy())
    fm_refine(hg, st.masks, 4, 0.1, np.random.default_rng(1), passes=2,
              state=st, frontier="numpy", nodes=allowed)
    assert np.array_equal(st.masks[outside], res.masks[outside])
    assert st.cost <= res.cost + 1e-9

    st2 = PartitionState(hg, 4, masks=res.masks.copy())
    replicate_local_search(hg, st2.masks, 4, 0.1, max_passes=2, seed=1,
                           frontier="numpy", state=st2, nodes=allowed)
    assert np.array_equal(st2.masks[outside], res.masks[outside])
    assert st2.cost <= res.cost + 1e-9


@needs_shm
@pytest.mark.parametrize("kind", ["fm", "rep"])
@pytest.mark.parametrize("W", [1, 2, 4])
def test_parallel_refine_cost_not_worse(kind, W):
    """Reconciled sharded refinement never worsens cost and leaves a state
    that passes the engine's full invariant check -- W = 1 exercises the
    serial-fallback leg of the same entry point."""
    hg = small_hg()
    res = partition_heuristic(hg, 4, 0.1, seed=0)
    st = PartitionState(hg, 4, masks=res.masks.copy())
    c0 = st.cost
    with ParallelContext(W, min_nodes=64) as ctx:
        stats = parallel_refine(hg, st, 4, 0.1, ctx, kind, 2, seed=3)
        assert not ctx.failed
    assert st.cost <= c0 + 1e-9
    st.check()
    assert is_valid(hg, st.masks, 4, 0.1,
                    max_replicas=1 if kind == "fm" else None)
    if W > 1:
        assert stats["workers"] == W and not stats["serial_fallback"]


@needs_shm
@pytest.mark.parametrize("method", START_METHODS)
def test_both_start_methods(method):
    import multiprocessing as mp
    if method not in mp.get_all_start_methods():
        pytest.skip(f"{method} start method unavailable")
    hg = small_hg()
    res = partition_heuristic(hg, 4, 0.1, seed=0)
    st = PartitionState(hg, 4, masks=res.masks.copy())
    c0 = st.cost
    with ParallelContext(2, start_method=method, min_nodes=64) as ctx:
        parallel_refine(hg, st, 4, 0.1, ctx, "rep", 2, seed=3)
        assert not ctx.failed
        # matching through the same pool: still bit-identical
        cm_p, _ = heavy_pin_matching(hg, 50.0, np.random.default_rng(7),
                                     ctx=ctx)
    cm_s, _ = heavy_pin_matching(hg, 50.0, np.random.default_rng(7))
    assert np.array_equal(cm_p, cm_s)
    assert st.cost <= c0 + 1e-9
    st.check()


@needs_shm
def test_fork_and_spawn_agree():
    """Same worker count, same seeds -> the two start methods commit the
    same reconciled masks (worker results do not depend on how the
    process got its memory image)."""
    import multiprocessing as mp
    if "fork" not in mp.get_all_start_methods():
        pytest.skip("fork unavailable")
    hg = small_hg()
    res = partition_heuristic(hg, 4, 0.1, seed=0)
    outs = []
    for method in ("fork", "spawn"):
        st = PartitionState(hg, 4, masks=res.masks.copy())
        with ParallelContext(2, start_method=method, min_nodes=64) as ctx:
            parallel_refine(hg, st, 4, 0.1, ctx, "rep", 2, seed=3)
            assert not ctx.failed
        outs.append(st.masks.copy())
    assert np.array_equal(outs[0], outs[1])


# ----------------------------------------------------- lifecycle / safety

@needs_shm
def test_crash_cleanup_no_leaked_segments():
    """A worker dying mid-task must not leak segments: the registry owns
    them and unlinks on close regardless of worker fate."""
    from multiprocessing import shared_memory
    hg = small_hg()
    ctx = ParallelContext(2, min_nodes=64)
    ctx.export_hg(hg)
    with pytest.raises(Exception):
        ctx.run(par._crash_task, [(None,), (None,)])
    names = list(ctx.reg.created)
    assert names
    ctx.close()
    for nm in names:
        with pytest.raises(FileNotFoundError):
            seg = shared_memory.SharedMemory(name=nm)
            seg.close()


@needs_shm
def test_pool_failure_falls_back_serial():
    """After a broken pool, parallel_refine still refines (serially) and
    the context reports failed."""
    hg = small_hg()
    res = partition_heuristic(hg, 4, 0.1, seed=0)
    st = PartitionState(hg, 4, masks=res.masks.copy())
    c0 = st.cost
    with ParallelContext(2, min_nodes=64) as ctx:
        with pytest.raises(Exception):
            ctx.run(par._crash_task, [(None,)])
        stats = parallel_refine(hg, st, 4, 0.1, ctx, "rep", 2, seed=3)
    assert stats["serial_fallback"] or ctx.failed
    assert st.cost <= c0 + 1e-9
    st.check()


@needs_shm
def test_state_usable_after_context_close():
    """adopt_state re-backs live arrays with shared segments; close() must
    hand back private copies so the state survives the context."""
    hg = small_hg()
    res = partition_heuristic(hg, 4, 0.1, seed=0)
    st = PartitionState(hg, 4, masks=res.masks.copy())
    ctx = ParallelContext(2, min_nodes=64)
    parallel_refine(hg, st, 4, 0.1, ctx, "fm", 1, seed=0)
    ctx.close()
    st.check()                       # would touch unmapped memory if stale
    st.apply(0, int(st.masks[0]))
    st.undo()


# ------------------------------------------------------------- end to end

@needs_shm
def test_end_to_end_workers(monkeypatch):
    """The public entry point with workers=2: valid masks, rep <= base,
    and the parallel path actually engaged (floor lowered)."""
    monkeypatch.setattr(par, "PARALLEL_MIN_NODES", 256)
    hg = small_hg(2000, seed=2)
    base, rep = partition_with_replication(hg, 4, 0.1, multilevel=True,
                                           workers=2, seed=0)
    assert is_valid(hg, base.masks, 4, 0.1, max_replicas=1)
    assert is_valid(hg, rep.masks, 4, 0.1)
    assert rep.cost <= base.cost + 1e-9


# ------------------------------------------- sharded scheduling coarsening

def _sched_pair_fixture(n=6000, seed=3):
    from repro.core.schedule.list_sched import dag_levels
    from repro.datagen import large_sptrsv_dag
    dag = large_sptrsv_dag(n, seed=seed)
    level = np.asarray(dag_levels(dag), dtype=np.int64)
    xch = np.zeros(dag.n + 1, dtype=np.int64)
    np.cumsum(np.bincount(dag.edge_src, minlength=dag.n), out=xch[1:])
    return dag, xch, level


def test_sched_pair_parts_shards_bit_identical():
    """The scheduling V-cycle's pair generator, without any pool: shard
    blocks (child blocks then parent blocks, shard order) concatenate into
    exactly the serial arrays."""
    from repro.core.schedule.multilevel import _pair_parts
    dag, xch, level = _sched_pair_fixture()
    mu = np.asarray(dag.mu, dtype=np.float64)
    serial = _pair_parts(xch, dag.edge_dst, dag.xpar, dag.par_arr, mu,
                         level, 16, 0, dag.n)
    for W in (2, 3, 5):
        bounds = np.linspace(0, dag.n, W + 1).astype(np.int64)
        blocks = [_pair_parts(xch, dag.edge_dst, dag.xpar, dag.par_arr, mu,
                              level, 16, int(bounds[i]), int(bounds[i + 1]))
                  for i in range(W)]
        for k in range(6):
            got = np.concatenate([b[k] for b in blocks])
            assert np.array_equal(got, serial[k]), (W, k)


@needs_shm
@pytest.mark.parametrize("W", [2, 4])
def test_pooled_same_level_matching_bit_identical(W):
    """Pool-backed scoring must yield the identical cmap for every worker
    count (the V-cycle bit-identity contract)."""
    from repro.core.schedule.multilevel import same_level_matching
    dag, xch, level = _sched_pair_fixture()
    cap = float(dag.omega.sum())
    cm_s, nc_s = same_level_matching(dag, level, cap,
                                     np.random.default_rng(5))
    with ParallelContext(W, min_nodes=64) as ctx:
        cm_p, nc_p = same_level_matching(dag, level, cap,
                                         np.random.default_rng(5), ctx=ctx)
        assert not ctx.failed
    assert nc_p == nc_s
    assert np.array_equal(cm_p, cm_s)


@needs_shm
def test_multilevel_schedule_workers_bit_identical():
    """End to end: ``multilevel_schedule(workers=2)`` equals the serial
    V-cycle exactly (sharded scoring changes wall-clock, not results)."""
    from repro.core.schedule import (BspInstance, MultilevelScheduleOptions,
                                     multilevel_schedule)
    from repro.datagen import large_sptrsv_dag
    dag = large_sptrsv_dag(5000, seed=1)
    inst = BspInstance(dag, 4, 2.0, 10.0)
    opts = MultilevelScheduleOptions(coarsest_n=512)
    s1 = multilevel_schedule(inst, opts=opts, seed=0)
    s2 = multilevel_schedule(inst, opts=opts, seed=0, workers=2)
    assert s1.current_cost() == s2.current_cost()
    assert s1.assign == s2.assign
    assert s1.comms == s2.comms
