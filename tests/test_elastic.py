"""Elastic re-scaling: a checkpoint written under one mesh restores under a
different mesh (different device count / axis split), and training
continues bit-compatibly.  This is the restart path a pod-failure
resize takes (DESIGN.md §2)."""
import json
import os
import pathlib
import subprocess
import sys
import tempfile

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduce_config
from repro.launch.mesh import make_mesh
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

ckpt_dir = sys.argv[1]
phase = sys.argv[2]
mesh_shape = (2, 4) if phase == "write" else (8, 1)   # elastic re-split
cfg = reduce_config(get_config("smollm-135m"), layers_per_segment=1)
mesh = make_mesh(mesh_shape, ("data", "model"))
steps = 4 if phase == "write" else 8
tr = Trainer(cfg, mesh, DataConfig(8, 16),
             TrainerConfig(steps=steps, ckpt_every=4, ckpt_dir=ckpt_dir,
                           log_every=100),
             adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=8))
state, hist = tr.run()
out = {"first_step": hist[0]["step"] if hist else None,
       "last_loss": hist[-1]["loss"] if hist else None,
       "mesh": list(mesh_shape)}
print("RESULT" + json.dumps(out))
"""


def _run(ckpt_dir: str, phase: str) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT, ckpt_dir, phase],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_elastic_restore_across_meshes():
    with tempfile.TemporaryDirectory() as ckpt:
        w = _run(ckpt, "write")          # train 4 steps on (2,4), checkpoint
        assert w["first_step"] == 0
        r = _run(ckpt, "resume")         # resume on (8,1) to step 8
        assert r["first_step"] == 4, r   # resumed, not restarted
        assert r["last_loss"] == r["last_loss"]  # finite
        assert r["mesh"] == [8, 1]
