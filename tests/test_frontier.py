"""Frontier-pricing layer invariants (core/frontier/).

The whole contract of the layer is *bit-equality with the scalar engine
deltas*: pricing a candidate as part of an arbitrary front must produce
exactly the float the engine's per-node ``delta_masks`` /
``delta_node_move`` would produce, on every backend -- otherwise batched
heuristic passes could drift off the scalar search trajectory.  These
tests pin that, the output-sensitive ``GainCache`` (consistency with
brute-force best gain after arbitrary apply/undo/refresh interleavings),
the SR front's pure pricing against the transactional trial, and the
explicit tie-breaking rule (ties go to the lowest processor id).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.frontier import (GainCache, add_replica_candidates,
                                 move_candidates, node_move_targets,
                                 price_mask_front, price_node_moves,
                                 price_superstep_replication, sr_front)
from repro.core.hypergraph import Dag, Hypergraph
from repro.core.partition import PartitionState, partition_heuristic
from repro.core.schedule import BspInstance, bspg_schedule
from repro.core.schedule.engine import EPS


def random_hypergraph(rng, n=None, m=None):
    n = n or int(rng.integers(5, 30))
    m = m or int(rng.integers(3, 50))
    edges = [tuple(rng.choice(n, size=int(rng.integers(2, min(6, n) + 1)),
                              replace=False)) for _ in range(m)]
    return Hypergraph(n=n, edges=edges, omega=rng.random(n) + 0.5,
                      mu=rng.random(m) + 0.1)


def random_dag(n, seed, fanin=3, p_edge=0.5, n_src=8, weighted=False):
    rng = np.random.default_rng(seed)
    edges = []
    for v in range(n_src, n):
        for u in rng.choice(v, size=min(fanin, v), replace=False):
            if rng.random() < p_edge:
                edges.append((int(u), v))
    omega = rng.uniform(0.5, 4.0, size=n) if weighted else None
    mu = rng.uniform(0.5, 3.0, size=n) if weighted else None
    return Dag(n=n, edge_list=edges, omega=omega, mu=mu)


def _backends():
    yield "numpy"
    try:
        import jax  # noqa: F401
        yield "jax"
    except ImportError:
        pass


# ---------------------------------------------------------- partition front

@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_front_equals_per_node_delta_masks(seed):
    """A ragged multi-node front must reproduce per-node ``delta_masks``
    bit-for-bit, on every available backend."""
    rng = np.random.default_rng(seed)
    hg = random_hypergraph(rng)
    P = int(rng.integers(2, 5))
    masks = rng.integers(1, 1 << P, size=hg.n)
    state = PartitionState(hg, P, masks=masks)
    vs = np.sort(rng.choice(hg.n, size=int(rng.integers(1, hg.n + 1)),
                            replace=False))
    for builder in (move_candidates, add_replica_candidates):
        cands, xcand = builder(state, vs)
        want = np.concatenate(
            [state.delta_masks(int(v), cands[xcand[i]:xcand[i + 1]])
             for i, v in enumerate(vs)]) if len(cands) else np.zeros(0)
        for backend in _backends():
            got = price_mask_front(state, vs, cands, xcand, backend=backend)
            assert np.array_equal(got, want), (builder.__name__, backend)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_gain_cache_consistent_after_mutations(seed):
    """After arbitrary apply/undo sequences with adjacency invalidation,
    every cache read must equal a fresh engine pricing, and the cached
    best gain must match brute force over the candidate set."""
    rng = np.random.default_rng(seed)
    hg = random_hypergraph(rng)
    P = int(rng.integers(2, 5))
    masks = rng.integers(1, 1 << P, size=hg.n)
    state = PartitionState(hg, P, masks=masks)
    cache = GainCache(state, add_replica_candidates)
    cache.refresh_dirty()
    for _ in range(30):
        op = rng.integers(0, 4)
        v = int(rng.integers(hg.n))
        if op == 0:  # apply a random mask change
            state.apply(v, int(rng.integers(1, 1 << P)))
            state.commit()
            cache.invalidate_move(v)
        elif op == 1 and state.depth == 0:  # apply + undo = no net change
            state.apply(v, int(rng.integers(1, 1 << P)))
            state.undo()
        elif op == 2:
            cache.refresh_dirty()
        else:  # read check
            cands, deltas = cache.get(v)
            fresh = state.delta_masks(v, cands)
            assert np.array_equal(deltas, fresh)
            if len(cands):
                best = int(np.argmin(deltas))
                brute = min(range(len(cands)),
                            key=lambda j: (fresh[j], j))
                assert best == brute
    # full-front check at the end
    cache.refresh_dirty()
    for v in range(hg.n):
        cands, deltas = cache.get(v)
        assert np.array_equal(deltas, state.delta_masks(v, cands))


def test_tie_break_lowest_processor():
    """Ties go to the lowest processor id: candidates are generated in
    ascending-q order and the first minimum wins (np.argmin first hit).
    A fully symmetric instance makes every target equally good."""
    hg = Hypergraph(n=4, edges=[(0, 1), (2, 3)])
    P = 4
    state = PartitionState(hg, P, masks=np.array([1, 1, 2, 2]))
    vs = np.array([0])
    cands, xcand = move_candidates(state, vs)
    # node 0 sits on processor 0: candidates must be q = 1, 2, 3 ascending
    assert cands.tolist() == [2, 4, 8]
    deltas = price_mask_front(state, vs, cands, xcand)
    # moving 0 anywhere except to its partner's processor costs +1; ties
    # between q=2 and q=3 resolve to q=2 via first-hit argmin
    assert deltas[1] == deltas[2]
    assert int(np.argmin(deltas[1:])) == 0
    # end-to-end: the heuristic must stay deterministic across repeat runs
    rng = np.random.default_rng(0)
    hg2 = random_hypergraph(rng, n=40, m=60)
    a = partition_heuristic(hg2, 4, 0.1, seed=3)
    b = partition_heuristic(hg2, 4, 0.1, seed=3)
    assert a.cost == b.cost and np.array_equal(a.masks, b.masks)


@pytest.mark.parametrize("frontier", ["off", "numpy"])
def test_fm_paths_identical(frontier):
    """The output-sensitive cached path and the per-node rescan must take
    identical decisions (same masks, not just same cost)."""
    rng = np.random.default_rng(5)
    hg = random_hypergraph(rng, n=80, m=120)
    got = partition_heuristic(hg, 4, 0.1, seed=1, frontier=frontier)
    want = partition_heuristic(hg, 4, 0.1, seed=1, frontier="off")
    assert got.cost == want.cost
    assert np.array_equal(got.masks, want.masks)


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_gain_kernel_matches_numpy_lambda(seed):
    """kernels.gain lambdas == engine._lambda_from_rows, jnp path and
    Pallas kernel in interpret mode (small fronts bypass the jax backend
    inside price_mask_front, so the kernel is pinned directly here)."""
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.partition.engine import _lambda_from_rows
    from repro.kernels import gain, ops
    rng = np.random.default_rng(seed)
    hg = random_hypergraph(rng)
    P = int(rng.integers(2, 6))
    masks = rng.integers(0, 1 << P, size=hg.n)  # incl. unassigned pins
    state = PartitionState(hg, P, masks=masks)
    rows = state.uncov
    want = _lambda_from_rows(rows, state._order, state._order_pc)
    got = gain.min_cover_lambdas(rows, state._order, state._order_pc)
    assert np.array_equal(want, got)
    ops.force("pallas")
    try:
        got_pl = gain.min_cover_lambdas(rows, state._order, state._order_pc,
                                        interpret=True)
    finally:
        ops.force(None)
    assert np.array_equal(want, got_pl)


# ----------------------------------------------------------- schedule front

@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_node_move_front_equals_delta(seed):
    """price_node_moves must equal delta_node_move bit-for-bit per target."""
    rng = np.random.default_rng(seed)
    dag = random_dag(int(rng.integers(20, 60)), seed, weighted=bool(seed % 2))
    inst = BspInstance(dag, P=int(rng.integers(2, 6)),
                       g=float(rng.integers(1, 6)), L=float(rng.integers(0, 25)))
    sched = bspg_schedule(inst, seed=seed)
    for v in range(dag.n):
        if len(sched.assign[v]) != 1:
            continue
        (p, _), = sched.assign[v].items()
        deltas = price_node_moves(sched, v)
        assert deltas[p] == 0.0
        for q in range(inst.P):
            if q != p:
                assert deltas[q] == sched.delta_node_move(v, q)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_node_move_targets_mirror_guards(seed):
    """Feasibility vector == try_node_move's guard conditions."""
    rng = np.random.default_rng(seed)
    dag = random_dag(int(rng.integers(20, 60)), seed)
    inst = BspInstance(dag, P=int(rng.integers(2, 6)),
                       g=2.0, L=5.0)
    sched = bspg_schedule(inst, seed=seed)
    for v in range(dag.n):
        if len(sched.assign[v]) != 1:
            continue
        (p, s), = sched.assign[v].items()
        feas = node_move_targets(sched, v)
        uses_p = sched.uses_on(v, p)
        blocked = bool(uses_p and min(uses_p) <= s)
        for q in range(inst.P):
            want = (q != p and not blocked
                    and all(sched.present_at(u, q, s)
                            for u in dag.parents[v]))
            assert feas[q] == want


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_sr_pricing_equals_trial(seed):
    """Pure SR pricing == the transactional trial's pre-prune cost delta,
    and the front enumeration == the scalar eligibility filter."""
    rng = np.random.default_rng(seed)
    dag = random_dag(int(rng.integers(30, 80)), seed)
    inst = BspInstance(dag, P=int(rng.integers(2, 6)),
                       g=float(rng.integers(1, 6)), L=float(rng.integers(0, 25)))
    sched = bspg_schedule(inst, seed=seed)
    for s in range(sched.S):
        seen = set()
        for (p1, p2, nodes) in sr_front(sched, s):
            seen.add((p1, p2))
            want_nodes = [v for v in sorted(sched.comp[s][p1])
                          if p2 not in sched.assign[v]
                          and sched.has_use_on(v, p2)]
            assert nodes == want_nodes
            priced = price_superstep_replication(sched, s, p1, p2, nodes)
            if priced is None:
                continue
            # replay the same mutations in a transaction and compare
            before = sched.current_cost()
            node_set = set(nodes)
            sched.begin()
            for v in nodes:
                for u in dag.parents[v]:
                    if sched.present_at(u, p2, s):
                        continue
                    if u in node_set and sched.assign[u].get(p1) == s:
                        continue
                    src = min(sched.assign[u],
                              key=lambda p: (sched.assign[u][p], p))
                    sched.add_comm(u, src, p2, s - 1)
                if (v, p2) in sched.comms and sched.comms[(v, p2)][1] >= s:
                    sched.remove_comm(v, p2)
                sched.add_comp(v, p2, s)
            actual = sched.current_cost() - before
            sched.rollback()
            assert abs(actual - priced) < 1e-9
        # pairs the front skipped must be empty candidates
        for p1 in range(inst.P):
            for p2 in range(inst.P):
                if p1 == p2 or (p1, p2) in seen:
                    continue
                assert not any(p2 not in sched.assign[v]
                               and sched.has_use_on(v, p2)
                               for v in sched.comp[s][p1])


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_sm_pricing_equals_trial(seed):
    """Pure SM pricing == the transactional trial's pre-prune cost delta,
    including the infeasibility verdict, for every adjacent pair."""
    from repro.core.frontier import apply_sm_mutations, price_superstep_merge
    rng = np.random.default_rng(seed)
    dag = random_dag(int(rng.integers(30, 80)), seed, weighted=bool(seed % 2))
    inst = BspInstance(dag, P=int(rng.integers(2, 6)),
                       g=float(rng.integers(1, 6)), L=float(rng.integers(0, 25)))
    sched = bspg_schedule(inst, seed=seed)
    for s in range(sched.S - 1):
        priced = price_superstep_merge(sched, s)
        before = sched.current_cost()
        snapshot_cost = sched.cost()
        sched.begin()
        ok = apply_sm_mutations(sched, s)
        actual = sched.current_cost() - before if ok else None
        sched.rollback()
        assert abs(sched.cost() - snapshot_cost) < 1e-9  # exact rollback
        if priced is None:
            assert actual is None
        else:
            assert actual is not None and abs(actual - priced) < 1e-9


def test_sm_winner_pass_engine_matches_oracle():
    """The SM winner rule must walk engine and oracle through identical
    trajectories (same costs, shapes and replica counts)."""
    from repro.core.schedule import reference as ref
    from repro.core.schedule.replication import superstep_merge_pass
    for seed in (0, 1, 2, 5):
        dag = random_dag(90 + 10 * seed, seed)
        inst = BspInstance(dag, P=4, g=4.0, L=20.0)
        eng = bspg_schedule(inst, seed=seed)
        orc = ref.bspg_schedule(inst, seed=seed)
        assert eng.current_cost() == orc.current_cost()
        eng, imp_e = superstep_merge_pass(eng)
        orc, imp_o = ref.superstep_merge_pass(orc)
        assert imp_e == imp_o
        assert eng.current_cost() == orc.current_cost()
        assert eng.S == orc.S
        assert eng.comms == orc.comms
        eng.check()


def test_sm_winner_pass_never_increases_cost():
    from repro.core.schedule.replication import superstep_merge_pass
    from repro.datagen import sptrsv_dag
    dag = sptrsv_dag(n=400, band=16, seed=0)
    inst = BspInstance(dag, P=4, g=4.0, L=20.0)
    sched = bspg_schedule(inst, seed=0)
    before = sched.current_cost()
    sched, _ = superstep_merge_pass(sched)
    assert sched.current_cost() <= before + EPS
    sched.check()


def test_node_move_pass_paths_identical():
    """hill_climb with and without fronts must produce identical schedules."""
    from repro.core.schedule import hill_climb
    for seed in (0, 1, 2):
        dag = random_dag(120, seed)
        inst = BspInstance(dag, P=4, g=4.0, L=20.0)
        on = hill_climb(bspg_schedule(inst, seed=seed), seed=seed)
        off = hill_climb(bspg_schedule(inst, seed=seed), seed=seed,
                         use_fronts=False)
        assert on.current_cost() == off.current_cost()
        assert on.comms == off.comms
        assert [dict(a) for a in on.assign] == [dict(a) for a in off.assign]


def test_sr_winner_improves_and_stays_valid():
    """The winner-rule SR pass must only ever lower the cost and keep the
    schedule valid on a real dataset instance."""
    from repro.core.schedule import advanced_heuristic, hill_climb
    from repro.datagen import sptrsv_dag
    dag = sptrsv_dag(n=400, band=16, seed=0)
    inst = BspInstance(dag, P=4, g=4.0, L=20.0)
    hc = hill_climb(bspg_schedule(inst, seed=0), seed=0)
    adv = advanced_heuristic(hc.copy())
    assert adv.current_cost() <= hc.current_cost() + EPS
    adv.check()
    assert not adv.validate()
