"""Tests for hypergraph partitioning with replication (paper §3.2, §5)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hypergraph import Hypergraph
from repro.core.partition import (exact_partition, is_valid, min_cover,
                                  partition_cost, partition_heuristic,
                                  partition_with_replication,
                                  replicate_local_search)


def two_clique(n, eps):
    """Paper Appendix A.1: two cliques of size (1+eps)/2*n sharing eps*n nodes."""
    k = int((1 + eps) / 2 * n)
    inter = int(eps * n)
    A = list(range(k))
    B = list(range(k - inter, min(2 * k - inter, n)))
    edges = []
    for S in (A, B):
        for i in range(len(S)):
            for j in range(i + 1, len(S)):
                edges.append((S[i], S[j]))
    return Hypergraph(n=n, edges=edges)


class TestMinCover:
    def test_paper_example(self):
        # e=(u,v,w): u in V1,V2; v in V2,V3; w in V3,V4 -> lambda=2 (V1+V3 etc.)
        masks = [0b0011, 0b0110, 0b1100]
        assert min_cover(masks, 4) == 2

    def test_single(self):
        assert min_cover([1, 1, 1], 4) == 1
        assert min_cover([1, 2], 4) == 2
        assert min_cover([1, 2, 4, 8], 4) == 4

    def test_shared_processor(self):
        assert min_cover([0b01, 0b11], 2) == 1

    @given(st.lists(st.integers(min_value=1, max_value=15), min_size=1, max_size=6))
    @settings(max_examples=200, deadline=None)
    def test_cover_bounds(self, masks):
        lam = min_cover(masks, 4)
        assert 1 <= lam <= 4
        # replication flexibility: adding a processor to any pin can't raise lambda
        wider = [m | 1 for m in masks]
        assert min_cover(wider, 4) <= lam


class TestExact:
    def test_two_clique_replication_zero(self):
        hg = two_clique(16, 0.25)
        base = exact_partition(hg, 2, 0.25, mode="none", time_limit=60)
        rep = exact_partition(hg, 2, 0.25, mode="rep", time_limit=60)
        assert base.optimal and rep.optimal
        assert base.cost > 0
        assert rep.cost == 0  # paper: replication removes all communication

    def test_modes_ordering(self):
        rng = np.random.default_rng(3)
        hg = Hypergraph(n=12, edges=[tuple(rng.choice(12, size=3, replace=False))
                                     for _ in range(16)])
        b = exact_partition(hg, 2, 0.2, mode="none", time_limit=60)
        d = exact_partition(hg, 2, 0.2, mode="dup", time_limit=60, ub_masks=b.masks)
        r = exact_partition(hg, 2, 0.2, mode="rep", time_limit=60, ub_masks=d.masks)
        assert r.cost <= d.cost + 1e-9 <= b.cost + 1e-9
        for res, mode in ((b, "none"), (d, "dup"), (r, "rep")):
            max_rep = {"none": 1, "dup": 2, "rep": None}[mode]
            assert is_valid(hg, res.masks, 2, 0.2, max_replicas=max_rep)

    def test_matches_bruteforce_p2(self):
        from itertools import product
        rng = np.random.default_rng(7)
        hg = Hypergraph(n=7, edges=[tuple(rng.choice(7, size=rng.integers(2, 4),
                                                     replace=False))
                                    for _ in range(9)])
        best = {"none": np.inf, "rep": np.inf}
        for assign in product([1, 2, 3], repeat=7):
            masks = np.array(assign)
            if not is_valid(hg, masks, 2, 0.3):
                continue
            c = partition_cost(hg, masks, 2)
            if all(m in (1, 2) for m in assign):
                best["none"] = min(best["none"], c)
            best["rep"] = min(best["rep"], c)
        for mode in ("none", "rep"):
            r = exact_partition(hg, 2, 0.3, mode=mode, time_limit=60)
            assert r.optimal
            assert abs(r.cost - best[mode]) < 1e-9

    def test_weighted_balance(self):
        hg = Hypergraph(n=6, edges=[(0, 1), (2, 3), (4, 5)],
                        omega=np.array([5, 1, 1, 1, 1, 1.0]))
        res = exact_partition(hg, 2, 0.1, mode="none", time_limit=30)
        assert is_valid(hg, res.masks, 2, 0.1)


class TestHeuristic:
    def test_replication_never_hurts(self):
        rng = np.random.default_rng(0)
        hg = Hypergraph(n=80, edges=[tuple(rng.choice(80, size=rng.integers(2, 6),
                                                      replace=False))
                                     for _ in range(120)])
        base = partition_heuristic(hg, 4, 0.05, seed=0)
        rep = replicate_local_search(hg, base.masks.copy(), 4, 0.05, seed=0)
        assert rep.cost <= base.cost + 1e-9
        assert is_valid(hg, rep.masks, 4, 0.05)

    def test_dup_mode_respects_cap(self):
        rng = np.random.default_rng(1)
        hg = Hypergraph(n=60, edges=[tuple(rng.choice(60, size=3, replace=False))
                                     for _ in range(90)])
        base = partition_heuristic(hg, 4, 0.1, seed=0)
        rep = replicate_local_search(hg, base.masks.copy(), 4, 0.1,
                                     max_replicas=2, seed=0)
        assert is_valid(hg, rep.masks, 4, 0.1, max_replicas=2)

    def test_end_to_end_small_uses_exact(self):
        hg = two_clique(14, 0.25)
        base, rep = partition_with_replication(hg, 2, 0.25, exact_node_limit=20,
                                               time_limit=60)
        assert rep.cost <= base.cost


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_property_rep_leq_none(data):
    """Optimal cost with replication never exceeds optimum without."""
    n = data.draw(st.integers(min_value=5, max_value=9))
    n_edges = data.draw(st.integers(min_value=3, max_value=8))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    edges = [tuple(rng.choice(n, size=int(rng.integers(2, min(4, n))),
                              replace=False)) for _ in range(n_edges)]
    hg = Hypergraph(n=n, edges=edges)
    base = exact_partition(hg, 2, 0.4, mode="none", time_limit=20)
    rep = exact_partition(hg, 2, 0.4, mode="rep", time_limit=20,
                          ub_masks=base.masks)
    assert rep.cost <= base.cost + 1e-9
    assert is_valid(hg, rep.masks, 2, 0.4)
