"""Superstep-split front invariants (PR 9 tentpole).

The split move bipartitions one superstep's compute phase at a level cut
(late nodes delay one step, tail supersteps renumber, comms re-derive
canonically for every touched value).  Its contract mirrors the SM/SR
machinery: pure pre-commit pricing through ``_SplitSim`` cells must be
bit-equal to a transactional replay of the same mutation, the engine-side
winner-commit pass must stay in lockstep with the ``reference.py`` oracle
on integer weights, split followed by the merge pass must never increase
cost, and every committed round compacts (no empty supersteps survive,
enforced by ``check(require_compact=True)``).  The canonical comm-plan
vectorization and the sharded coarsening scoring pass are pinned
bit-identical to their scalar/serial seeds here too.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.frontier import (commit_superstep_split,
                                 price_superstep_split, split_front)
from repro.core.hypergraph import Dag
from repro.core.schedule import (BspInstance, Schedule, ScheduleState,
                                 advanced_heuristic, bspg_schedule,
                                 hill_climb, superstep_split_pass)
from repro.core.schedule import reference as ref
from repro.core.schedule.engine import (_canonical_comm_plan_scalar,
                                        apply_split_mutations,
                                        canonical_comm_plan)
from repro.core.schedule.list_sched import dag_levels
from repro.core.schedule.replication import (AdvancedOptions,
                                             superstep_merge_pass)
from repro.datagen import psdd_dag, sptrsv_dag


def random_dag(n, seed, fanin=3, p_edge=0.5, n_src=8):
    rng = np.random.default_rng(seed)
    edges = []
    for v in range(n_src, n):
        for u in rng.choice(v, size=min(fanin, v), replace=False):
            if rng.random() < p_edge:
                edges.append((int(u), v))
    return Dag(n=n, edge_list=edges)


def merged_state(dag, P=4, g=4.0, L=20.0, seed=0):
    """An advanced-heuristic schedule (merges ran, so supersteps hold more
    than one topological level and split candidates exist).  ``Schedule``
    *is* a ``ScheduleState`` -- the engine transaction API is live on it."""
    inst = BspInstance(dag, P=P, g=g, L=L)
    return advanced_heuristic(
        hill_climb(bspg_schedule(inst, seed=seed), seed=seed))


def all_candidates(sched):
    level = np.asarray(dag_levels(sched.inst.dag), dtype=np.int64)
    out = []
    for s in range(sched.S):
        for cut, late in split_front(sched, s, level):
            out.append((s, cut, late))
    return out


# ------------------------------------------------------- pricing bit-equality

@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_split_pricing_equals_replay(seed):
    """Pure ``_SplitSim`` pricing must equal the engine delta of a
    transactional replay of the same split, bit-for-bit, and rollback must
    restore the pre-split cost exactly."""
    sched = merged_state(random_dag(60, seed))
    base_cost = sched.current_cost()
    pre = sorted(sched.comms.items())
    for s, _cut, late in all_candidates(sched):
        priced = price_superstep_split(sched, s, late, pre=pre)
        if priced is None:
            continue
        sched.begin()
        ok = apply_split_mutations(sched, s, late, pre=pre)
        assert ok, "feasible candidate refused in replay"
        replayed = sched.current_cost() - base_cost
        assert priced == replayed, (s, late, priced, replayed)
        sched.rollback()
        assert sched.current_cost() == base_cost
    sched.check()


def test_split_candidates_exist_after_merging():
    """Merging packs multiple topological levels into a superstep, so the
    front must enumerate candidates there (the flat baseline has one level
    per superstep and none -- both by construction)."""
    sched = merged_state(sptrsv_dag(n=300, band=12, seed=0))
    cands = all_candidates(sched)
    assert cands, "no split candidates on a merged sptrsv schedule"
    # every candidate is feasible on a copy (level cuts cannot starve a
    # child of a parent delayed past it)
    for s, _cut, late in cands:
        trial = sched.copy()
        assert apply_split_mutations(trial, s, late)
        trial.check()


def test_commit_applies_winner_and_compacts():
    """``commit_superstep_split`` lands exactly the priced delta and leaves
    a compact, consistent engine state."""
    sched = merged_state(psdd_dag(n_leaves=120, depth=8, seed=2))
    base = sched.current_cost()
    pre = sorted(sched.comms.items())
    best = None
    for s, _cut, late in all_candidates(sched):
        priced = price_superstep_split(sched, s, late, pre=pre)
        if priced is not None and (best is None or priced < best[0]):
            best = (priced, s, late)
    if best is None:
        pytest.skip("instance yielded no feasible split candidate")
    priced, s, late = best
    commit_superstep_split(sched, s, late)
    assert sched.current_cost() == base + priced
    sched.check(require_compact=True)


# --------------------------------------------------------- engine vs oracle

@pytest.mark.parametrize("seed", [0, 1, 2, 5])
def test_lockstep_random_dags(seed):
    """Engine and oracle advanced heuristics with splits enabled must land
    on identical schedules (costs, assigns, comms) on integer weights."""
    dag = random_dag(70, seed)
    inst = BspInstance(dag, P=4, g=4.0, L=20.0)
    eng = advanced_heuristic(hill_climb(bspg_schedule(inst, seed=0), seed=0),
                             AdvancedOptions(superstep_splitting=True))
    orc = ref.advanced_heuristic(
        ref.hill_climb(ref.bspg_schedule(inst, seed=0), seed=0),
        ref.AdvancedOptions(True, True, True, 8, True))
    assert eng.current_cost() == orc.current_cost()
    assert eng.S == orc.S
    assert eng.assign == orc.assign
    assert eng.comms == orc.comms
    eng.check(require_compact=True)


@pytest.mark.parametrize("make", [
    lambda: sptrsv_dag(n=260, band=10, seed=1),
    lambda: psdd_dag(n_leaves=100, depth=8, seed=3),
])
def test_lockstep_shipped_instances(make):
    """Same lockstep pin on the paper's instance families."""
    inst = BspInstance(make(), P=4, g=4.0, L=20.0)
    eng = advanced_heuristic(hill_climb(bspg_schedule(inst, seed=0), seed=0),
                             AdvancedOptions(superstep_splitting=True))
    orc = ref.advanced_heuristic(
        ref.hill_climb(ref.bspg_schedule(inst, seed=0), seed=0),
        ref.AdvancedOptions(True, True, True, 8, True))
    assert eng.current_cost() == orc.current_cost()
    assert eng.assign == orc.assign
    assert eng.comms == orc.comms


def test_split_pass_lockstep_and_compact():
    """The standalone winner-commit split passes (engine and oracle) agree
    and leave no empty supersteps behind."""
    dag = sptrsv_dag(n=220, band=10, seed=4)
    inst = BspInstance(dag, P=4, g=4.0, L=20.0)
    merged = advanced_heuristic(
        hill_climb(bspg_schedule(inst, seed=0), seed=0))
    ref_merged = ref.advanced_heuristic(
        ref.hill_climb(ref.bspg_schedule(inst, seed=0), seed=0))
    assert merged.assign == ref_merged.assign  # identical starting points
    eng, ech = superstep_split_pass(merged)
    orc, och = ref.superstep_split_pass(ref_merged)
    assert ech == och
    assert eng.current_cost() == orc.current_cost()
    assert eng.assign == orc.assign
    assert eng.comms == orc.comms
    eng.check(require_compact=True)


# -------------------------------------------------------------- cost safety

@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_property_split_then_merge_never_worse(seed):
    """Split followed by the merge pass is cost-safe: both passes commit
    only strictly improving winners, so the round trip never regresses."""
    sched = merged_state(random_dag(55, seed))
    before = sched.current_cost()
    sched, _ = superstep_split_pass(sched)
    mid = sched.current_cost()
    assert mid <= before
    sched, _ = superstep_merge_pass(sched)
    assert sched.current_cost() <= mid
    sched.check(require_compact=True)
    assert sched.validate() == []


def test_require_compact_catches_empty_superstep():
    """The new ``check(require_compact=True)`` invariant actually bites:
    a hand-built schedule with a hollow middle superstep must fail it and
    pass after ``compact()``."""
    dag = Dag(n=2, edge_list=[(0, 1)])
    inst = BspInstance(dag, P=2, g=1.0, L=1.0)
    sched = ScheduleState(inst, 3)
    sched.add_comp(0, 0, 0)
    sched.add_comp(1, 0, 2)
    sched.check()  # base invariants hold
    with pytest.raises(AssertionError):
        sched.check(require_compact=True)
    sched.compact()
    sched.check(require_compact=True)


# -------------------------------------------------- canonical-plan pinning

@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_comm_plan_vectorized_matches_scalar(seed):
    """The bincount/lexsort ``canonical_comm_plan`` must reproduce the
    scalar seed implementation entry-for-entry."""
    sched = merged_state(random_dag(50, seed))
    dag, assign = sched.inst.dag, sched.assign
    fast = canonical_comm_plan(dag, assign)
    slow = _canonical_comm_plan_scalar(dag, assign)
    assert fast == slow
