"""Tests for BSP scheduling with replication (paper §3.3, §6)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hypergraph import Dag
from repro.core.schedule import (AdvancedOptions, BspInstance, Schedule,
                                 advanced_heuristic, baseline_schedule,
                                 basic_heuristic, bspg_schedule, exact_schedule,
                                 hill_climb)


def random_dag(n, seed, fanin=3, p_edge=0.5, n_src=10):
    rng = np.random.default_rng(seed)
    edges = []
    for v in range(n_src, n):
        for u in rng.choice(v, size=min(fanin, v), replace=False):
            if rng.random() < p_edge:
                edges.append((int(u), v))
    return Dag(n=n, edge_list=edges)


class TestCostModel:
    def test_figure2_example(self):
        """Paper Fig. 2: replicating v on p2 removes the comm, cost drops."""
        # DAG: a -> v, b -> v, v -> c ; a,b also needed by p2's own chain.
        # 0=a 1=b 2=v 3=c(on p2) 4,5 fillers on p2
        dag = Dag(n=6, edge_list=[(0, 2), (1, 2), (2, 3), (4, 5)])
        inst = BspInstance(dag, P=2, g=2.0, L=1.0)
        s = Schedule(inst, 3)
        s.add_comp(0, 0, 0); s.add_comp(1, 0, 0)   # a, b on p1 s1
        s.add_comp(4, 1, 0)                        # filler on p2
        s.add_comm(0, 0, 1, 0); s.add_comm(1, 0, 1, 0)  # send a, b to p2
        s.add_comp(2, 0, 1)                        # v on p1 s2
        s.add_comm(2, 0, 1, 1)                     # send v to p2
        s.add_comp(5, 1, 1)
        s.add_comp(3, 1, 2)                        # c on p2 s3 uses v
        assert not s.validate()
        cost_comm = s.cost()
        # now replicate v on p2 in superstep 3 instead of communicating
        s.remove_comm(2, 1)
        s.add_comp(2, 1, 2)
        assert not s.validate()
        assert s.cost() < cost_comm

    def test_h_relation_max(self):
        dag = Dag(n=4, edge_list=[])
        inst = BspInstance(dag, P=2, g=3.0, L=5.0)
        s = Schedule(inst, 1)
        for v in range(4):
            s.add_comp(v, v % 2, 0)
        assert s.cost() == 2.0  # pure compute, no L charged
        s.add_comm(0, 0, 1, 0)
        s.add_comm(1, 0, 1, 0)
        # h = max(sent p0, recv p1) = 2 -> L + g*2 = 11
        assert s.cost() == 2.0 + 5.0 + 3.0 * 2

    def test_incremental_cost_matches_full(self):
        dag = random_dag(60, 0)
        inst = BspInstance(dag, P=4, g=2.0, L=3.0)
        s = bspg_schedule(inst)
        assert abs(s.current_cost() - s.cost()) < 1e-9
        s2 = basic_heuristic(s.copy())
        assert abs(s2.current_cost() - s2.cost()) < 1e-9


class TestBaseline:
    def test_valid_and_complete(self):
        dag = random_dag(200, 1)
        inst = BspInstance(dag, P=4, g=4.0, L=20.0)
        s = baseline_schedule(inst)
        assert not s.validate()

    def test_sequential_candidate(self):
        # with huge g, baseline should fall back to the sequential schedule
        dag = Dag(n=8, edge_list=[(i, i + 4) for i in range(4)])
        inst = BspInstance(dag, P=4, g=1e6, L=1e6)
        s = baseline_schedule(inst)
        assert s.current_cost() <= 8.0 + 1e-9

    def test_weighted_nodes(self):
        rng = np.random.default_rng(2)
        dag = random_dag(100, 2)
        dag.omega = rng.uniform(1, 5, size=100)
        dag.mu = rng.uniform(1, 3, size=100)
        inst = BspInstance(dag, P=4, g=2.0, L=10.0)
        s = baseline_schedule(inst)
        assert not s.validate()
        assert abs(s.current_cost() - s.cost()) < 1e-9


class TestReplication:
    def test_appendix_a1_bipartite(self):
        """Replication parallelizes the complete-bipartite DAG (App. A.1)."""
        P, c, m = 4, 4, 6
        n = m * (c * P + 1)
        edges = [(u, v) for u in range(m) for v in range(m, n)]
        dag = Dag(n=n, edge_list=edges)
        inst = BspInstance(dag, P=P, g=float(P * (P * c + 1) + 1), L=1.0)
        base = baseline_schedule(inst)
        from repro.core.schedule import best_replicated_schedule
        rep = best_replicated_schedule(inst, baseline=base)
        assert not rep.validate()
        # without replication optimum is ~n (sequential); with replication
        # the U-set is replicated everywhere and the cost drops to ~(c+1)*m
        assert base.current_cost() >= n * 0.9
        assert rep.current_cost() <= (c + 1) * m * 1.5
        # theoretical ratio (P*c+1)/(c+1) = 3.4 for these parameters
        assert base.current_cost() / rep.current_cost() >= 2.5

    def test_basic_never_hurts(self):
        dag = random_dag(150, 3)
        inst = BspInstance(dag, P=8, g=4.0, L=20.0)
        base = baseline_schedule(inst)
        rep = basic_heuristic(base.copy())
        assert rep.current_cost() <= base.current_cost() + 1e-9
        assert not rep.validate()

    def test_advanced_beats_basic(self):
        dag = random_dag(300, 4)
        inst = BspInstance(dag, P=8, g=4.0, L=20.0)
        base = baseline_schedule(inst)
        b = basic_heuristic(base.copy())
        a = advanced_heuristic(base.copy())
        assert a.current_cost() <= b.current_cost() + 1e-9
        assert not a.validate()

    def test_components_isolated(self):
        dag = random_dag(200, 5)
        inst = BspInstance(dag, P=4, g=8.0, L=40.0)
        base = baseline_schedule(inst)
        for key in ("batch_replication", "superstep_merging",
                    "superstep_replication"):
            opts = AdvancedOptions(batch_replication=False,
                                   superstep_merging=False,
                                   superstep_replication=False)
            setattr(opts, key, True)
            out = advanced_heuristic(base.copy(), opts)
            assert not out.validate(), key
            assert out.current_cost() <= base.current_cost() + 1e-9


class TestExact:
    def test_exact_beats_or_ties_heuristic(self):
        dag = Dag(n=10, edge_list=[(0, 3), (1, 3), (1, 4), (2, 4), (3, 5),
                                   (4, 6), (5, 7), (6, 7), (3, 8), (4, 9)])
        inst = BspInstance(dag, P=2, g=4.0, L=5.0)
        ex = exact_schedule(inst, max_supersteps=3, time_limit=30)
        heur = baseline_schedule(inst)
        assert ex.assignments_optimal
        assert ex.cost <= heur.current_cost() + 1e-9
        assert not ex.schedule.validate()

    def test_chain_dag_sequential(self):
        # chain DAGs: replication never helps (paper Lemma 4.3);
        # the optimum on one processor is n (no comm possible anyway).
        dag = Dag(n=6, edge_list=[(i, i + 1) for i in range(5)])
        inst = BspInstance(dag, P=2, g=2.0, L=1.0)
        ex = exact_schedule(inst, max_supersteps=3, time_limit=30)
        assert abs(ex.cost - 6.0) < 1e-9


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_property_pipeline_validity_and_monotonicity(seed):
    """Every stage of the pipeline yields a valid schedule and never
    increases cost."""
    dag = random_dag(80, seed, fanin=2)
    inst = BspInstance(dag, P=4, g=float(1 + seed % 5), L=float(seed % 30))
    s0 = bspg_schedule(inst, seed=seed)
    assert not s0.validate()
    c0 = s0.current_cost()
    s1 = hill_climb(s0, seed=seed)
    assert not s1.validate()
    c1 = s1.current_cost()
    s2 = advanced_heuristic(s1.copy())
    assert not s2.validate()
    c2 = s2.current_cost()
    assert c1 <= c0 + 1e-9
    assert c2 <= c1 + 1e-9
    # replication semantics: every node computed somewhere; cost matches
    assert abs(s2.current_cost() - s2.cost()) < 1e-6


def test_surplus_cost_definition():
    """Paper Definition 4.4: surplus = cost - omega(V)/P; zero for a
    perfectly balanced communication-free schedule."""
    from repro.core.schedule import Schedule
    dag = Dag(n=8, edge_list=[])
    inst = BspInstance(dag, P=4, g=2.0, L=5.0)
    s = Schedule(inst, 1)
    for v in range(8):
        s.add_comp(v, v % 4, 0)
    assert abs(s.surplus_cost() - 0.0) < 1e-9
    s.add_comm(0, 0, 1, 0)
    assert s.surplus_cost() == 5.0 + 2.0  # L + g*1
