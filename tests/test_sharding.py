"""Distribution tests under a real multi-device (host) mesh.

Runs in a subprocess so XLA_FLAGS can force 8 host devices without
polluting the single-device test session (same pattern as the dry-run).
"""
import json
import os
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduce_config
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.models.moe import plan_from_masks
from repro.parallel import sharding as shd
from repro.train import step as step_lib
from repro.optim import adamw

out = {}
mesh = make_mesh((2, 4), ("data", "model"))
shd.set_active_mesh(mesh)

# 1) sharded train step compiles AND runs for a dense + a MoE arch
for arch in ("smollm-135m", "olmoe-1b-7b"):
    cfg = reduce_config(get_config(arch)).with_(strategy="tp")
    with shd.use_mesh(mesh):
        ts = step_lib.build_train_step(cfg, mesh,
                                       adamw.AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=8))
        from repro.models.model import Model as M
        model = M(cfg, n_ep_shards=4)
        params = jax.jit(model.init,
                         out_shardings=ts.state_shardings["params"])(
            jax.random.PRNGKey(0))
        opt = jax.jit(lambda p: adamw.init_state(
            adamw.AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=8), p),
            out_shardings=ts.state_shardings["opt"])(params)
        state = {"params": params, "opt": opt}
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                       jnp.int32)}
        losses = []
        for _ in range(3):
            state, metrics = ts.step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        out[arch] = losses

# 2) replication-aware placement runs and matches dense numerics
cfg = reduce_config(get_config("olmoe-1b-7b")).with_(strategy="tp")
model_ref = Model(cfg)
params = model_ref.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
shd.set_active_mesh(None)
loss_ref, _ = model_ref.loss(params, batch)
shd.set_active_mesh(mesh)
masks = np.array([0b1111 if e < 2 else (1 << (e % 4))
                  for e in range(cfg.n_experts)])
plan = plan_from_masks(masks, cfg.n_experts, 4, capacity_factor=8.0)
with shd.use_mesh(mesh):
    model_r = Model(cfg, plan=plan)
    loss_rep, _ = jax.jit(model_r.loss)(params, batch)
out["placement"] = [float(loss_ref), float(loss_rep)]
print("RESULT" + json.dumps(out))
"""


def test_sharded_training_and_placement():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    out = json.loads(line[len("RESULT"):])
    for arch in ("smollm-135m", "olmoe-1b-7b"):
        losses = out[arch]
        assert all(l > 0 and l == l for l in losses), losses
        assert losses[-1] < losses[0], f"{arch}: no learning {losses}"
    ref, rep = out["placement"]
    assert abs(ref - rep) < 0.12, f"placement path diverges: {ref} vs {rep}"
