"""Dataset generators must mirror the paper's construction (§B)."""
import numpy as np

from repro.datagen import (hdb_dataset, moe_dataset, psdd_dataset,
                           spmv_dataset, sptrsv_dataset, synthetic_trace,
                           tiny_dataset, trace_to_moe2, trace_to_moe8)


def test_moe8_statistics():
    trace = synthetic_trace(n_experts=128, n_tokens=20_000, seed=0)
    hg = trace_to_moe8(trace, kappa0=1000)
    assert hg.num_pins >= 1000            # pin-limit rule of §B.1
    assert hg.num_pins <= 1000 + 8        # "or only slightly above"
    assert 60 <= hg.n <= 128              # covers a large share of experts
    assert np.all(hg.mu >= 1.0) and np.all(hg.mu <= 10.0)  # weights in [1,10]
    assert all(len(e) == 8 for e in hg.edges)


def test_moe2_is_simple_graph():
    trace = synthetic_trace(n_experts=128, n_tokens=20_000, seed=1)
    hg = trace_to_moe2(trace, kappa0=1000)
    assert all(len(e) == 2 for e in hg.edges)
    assert hg.num_pins >= 1000
    # no isolated nodes after cleanup
    seen = {v for e in hg.edges for v in e}
    assert seen == set(range(hg.n))


def test_spmv_models():
    fg = spmv_dataset("fg", count=2)
    rn = spmv_dataset("rn", count=2)
    for hg in fg:
        assert all(len(e) >= 2 for e in hg.edges)
        assert np.all(hg.omega == 1.0)        # fine-grained: unit node weight
    for hg in rn:
        assert np.all(hg.omega >= 1.0)        # row-net: weight = column nnz


def test_dags_are_acyclic_and_sized():
    for d in hdb_dataset() + sptrsv_dataset() + psdd_dataset():
        order = d.topo_order()            # raises on cycles
        assert len(order) == d.n
        assert d.num_edges > 0
    for d in tiny_dataset():
        assert 20 <= d.n <= 90            # §C.2.2 tiny range (scaled)


def test_trace_determinism():
    a = synthetic_trace(n_tokens=1000, seed=42)
    b = synthetic_trace(n_tokens=1000, seed=42)
    assert np.array_equal(a, b)
